"""Paper Figure 1: communication cost to reach tau = 0.85 as a function of
the compression ratio, under the ALIE attack with varying Byzantine counts.

Quick mode (default, used by ``benchmarks.run``): ratios {0.05, 1.0} x
f in {0, 5}. Full mode (--full): ratios {0.01, 0.05, 0.1, 0.3, 0.5, 1.0} x
f in {0, 1, 3, 5, 9} — the paper's grid.
"""

from __future__ import annotations

import json
import os
import sys

from benchmarks.common import TAU, comm_cost_to_tau, emit
import time


def run(full: bool = False, out: str | None = None):
    ratios = [0.01, 0.05, 0.1, 0.3, 0.5, 1.0] if full else [0.05, 1.0]
    fs = [0, 1, 3, 5, 9] if full else [0, 5]
    rows = []
    base = {}
    for f in fs:
        for ratio in ratios:
            t0 = time.perf_counter()
            r = comm_cost_to_tau(ratio=ratio, f=f, attack="alie",
                                 steps=600 if full else 400)
            wall = (time.perf_counter() - t0) * 1e6
            rows.append(r)
            key = (f,)
            if ratio == 1.0:
                base[key] = r["comm_bytes_to_tau"]
            saving = ""
            if key in base and base[key] not in (0, float("inf")) \
                    and r["comm_bytes_to_tau"] != float("inf"):
                saving = "saving=%.1f%%" % (
                    100 * (1 - r["comm_bytes_to_tau"] / base[key]))
            emit(f"fig1/ratio={ratio}/f={f}", wall,
                 f"bytes_to_tau={r['comm_bytes_to_tau']:.3g} "
                 f"acc={r['final_acc']:.3f} rounds={r['rounds']} {saving}")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as fh:
            json.dump(rows, fh, indent=2)
    return rows


if __name__ == "__main__":
    run(full="--full" in sys.argv,
        out="results/fig1_full.json" if "--full" in sys.argv
        else "results/fig1_quick.json")
