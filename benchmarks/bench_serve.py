"""Benchmark the streaming byzantine-robust parameter server (repro.serve).

Three gated sections, JSON'd to results/BENCH_serve.json after each one:

  parity_gate   full participation + zero timeout: the served parameter
                trajectory must equal ``Simulator.rollout``'s bit for bit
                (the serve split is op-for-op the simulator's round).
  one_compile   ONE server driven by full / dropping / late client pools:
                the jitted aggregate-and-apply step must compile exactly
                once across every participation level it sees
                (participation and staleness are traced data, not shapes).
  throughput    quorum sweep at n=13, f=3: sustained updates/sec and
                rounds/sec, p50/p99 round latency, participation and
                staleness histograms from ``ServeMetrics``.

Run: PYTHONPATH=src:. python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import Simulator
from repro.core.sweep import grid_scenarios, quadratic_testbed
from repro.serve import (
    ByzantineRobustServer, ClientBehavior, ClientPool, ServeConfig,
    ServeMetrics, run_service,
)

D = 256
PARITY_ROUNDS = 30
THROUGHPUT_ROUNDS = 120
N_HONEST, F = 10, 3


def _cfg(algo="rosdhb", attack="alie", agg="cwtm", **kw):
    return grid_scenarios((algo,), (attack,), (agg,),
                          n_honest=N_HONEST, f=F, **kw)[0].cfg


def _parity_gate():
    """Serve vs simulator, bit for bit, across algorithm/attack/aggregator
    variety (rosdhb is the paper's algorithm and the hard gate; dgd is
    excluded here — XLA's scalar-hoist reassociation in the fused simulator
    program makes it a documented 1-ulp case, see tests/test_serve.py)."""
    out = {}
    for algo, attack, agg in (("rosdhb", "alie", "cwtm"),
                              ("rosdhb", "foe", "median"),
                              ("robust_dgd", "signflip", "cwtm")):
        cfg = _cfg(algo, attack, agg)
        loss_fn, params0, batch_fn, _ = quadratic_testbed(cfg.n_workers, d=D)
        sim = Simulator(loss_fn, params0, cfg)
        final, _ = sim.rollout(sim.init(0), batch_fn, PARITY_ROUNDS)
        server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
        pool = ClientPool(loss_fn, params0, cfg, batch_fn)
        run_service(server, pool, PARITY_ROUNDS)
        diff = float(np.max(np.abs(np.asarray(final.params_flat)
                                   - np.asarray(server.params_flat))))
        key = f"{algo}/{attack}/{agg}"
        out[key] = {"rounds": PARITY_ROUNDS, "max_abs_diff": diff,
                    "exact": diff == 0.0,
                    "step_traces": server.step_traces}
        emit(f"serve/parity/{key}", 0.0,
             f"max_abs_diff={diff} traces={server.step_traces}")
        assert diff == 0.0, f"serve/sim parity broken for {key}: {diff}"
        assert server.step_traces == 1
    return out


def _one_compile_gate():
    """One server, three pool behaviours (full, 30% drop, byzantine always
    late), timeout-fired partial rounds included: step_traces must stay 1."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = quadratic_testbed(cfg.n_workers, d=D)
    serve = ServeConfig(quorum=2 * F + 1, timeout_s=0.05,
                        staleness_window=2, stale_policy="discount")
    server = ByzantineRobustServer(cfg, params0, serve, seed=0)
    behaviours = {
        "full": None,
        "drop30": ClientBehavior(drop_prob=0.3, seed=1),
        "byz_late": ClientBehavior(stragglers=tuple(range(F)),
                                   straggle_rounds=1, seed=2),
    }
    for name, beh in behaviours.items():
        pool = ClientPool(loss_fn, params0, cfg, batch_fn, behavior=beh)
        run_service(server, pool, 20, stop=False)
    server.stop()
    part = server.metrics.participation_histogram()
    levels = sorted(part)
    emit("serve/one_compile", 0.0,
         f"traces={server.step_traces} participation_levels={levels}")
    assert server.step_traces == 1, (
        f"step retraced across participation levels: {server.step_traces}")
    assert len(levels) > 1, "bench never exercised partial participation"
    return {"step_traces": server.step_traces,
            "participation_histogram": part,
            "staleness_histogram": server.metrics.staleness_histogram()}


def _throughput_sweep():
    """Sustained service rate vs quorum (the buffer's firing size) at n=13,
    f=3 (all quorums >= 2f+1), with two permanent stragglers delivering one
    round late. Smaller quorums fire earlier and pipeline the apply against
    still-arriving updates (classified stale for the NEXT round and kept
    under the discount policy), trading per-round freshness for round
    rate; a full quorum can only complete with the stragglers' discounted
    stale updates. A short warmup excludes compile from the latency tail."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = quadratic_testbed(cfg.n_workers, d=D)
    out = {}
    for quorum in (13, 11, 7):
        serve = ServeConfig(quorum=quorum, timeout_s=0.25,
                            staleness_window=1, stale_policy="discount")
        server = ByzantineRobustServer(cfg, params0, serve, seed=0)
        beh = ClientBehavior(stragglers=(11, 12), straggle_rounds=1, seed=0)
        pool = ClientPool(loss_fn, params0, cfg, batch_fn, behavior=beh)
        run_service(server, pool, 5, stop=False)   # compile + settle
        server.metrics = ServeMetrics()
        run_service(server, pool, THROUGHPUT_ROUNDS)
        s = server.metrics.summary()
        s["step_traces"] = server.step_traces
        s["final_honest_loss"] = float(pool.last_losses[F:].mean())
        out[f"quorum{quorum}"] = s
        emit(f"serve/throughput/quorum{quorum}",
             s["latency_p50_ms"] * 1e3,
             f"updates/s={s['updates_per_sec']:.0f} "
             f"rounds/s={s['rounds_per_sec']:.1f} "
             f"p50={s['latency_p50_ms']:.2f}ms "
             f"p99={s['latency_p99_ms']:.2f}ms")
        assert server.step_traces == 1
        # the clock can fire extra rounds beyond the 120 driven ones from
        # leftover stale updates — continuous batching, not an error
        assert s["rounds"] >= THROUGHPUT_ROUNDS
    return out


def run(out: str = "results/BENCH_serve.json",
        out_root: str = "BENCH_serve.json"):
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    # same persistence discipline as bench_sweep: rewrite the JSON after
    # every section so a failed gate still leaves partial results behind
    # (CI uploads with if: always()), with a root copy tracked in-tree
    results = {}

    def record(name, fn):
        try:
            results[name] = fn()
        finally:
            for path in (out, out_root):
                if path:
                    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                    with open(path, "w") as fh:
                        json.dump(results, fh, indent=2)

    record("parity_gate", _parity_gate)
    record("one_compile", _one_compile_gate)
    record("throughput", _throughput_sweep)
    return results


if __name__ == "__main__":
    run()
