"""Breakdown-point and heterogeneity study.

The paper's theory (via [3]) bounds the tolerable Byzantine fraction by
f/n < 1/(2+B^2) and predicts the non-vanishing error floor kappa*G^2.
Two sweeps on the controlled quadratic testbed:

  * breakdown: fix heterogeneity, sweep f/n under ALIE at k/d = 0.1 —
    the distance should stay flat until near n/2 and then explode;
  * heterogeneity: fix f = 3/13, sweep the spread G of worker optima —
    the error floor should grow ~linearly in G (kappa G^2 in distance^2).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        server_round)

D = 48


def _run(n, f, spread, seed=0, steps=700, gamma=0.05):
    tg = jax.random.normal(jax.random.PRNGKey(1), (n, D)) * spread + 1.0
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=n, f=f, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.1),
        aggregator=AggregatorConfig(name="cwtm", f=max(f, 1), pre_nnm=True),
        attack=AttackConfig(name="alie", z=1.5))
    st = init_state(cfg, D)
    th = jnp.zeros(D)
    k = jax.random.PRNGKey(seed)

    @jax.jit
    def one(th, st, k):
        k, sk = jax.random.split(k)
        r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
        return apply_direction(th, r, cfg.gamma), st, k

    for _ in range(steps):
        th, st, k = one(th, st, k)
    d = float(jnp.linalg.norm(th - jnp.mean(tg[f:], 0)))
    return d if np.isfinite(d) else float("inf")


def run():
    n = 13
    # breakdown sweep
    for f in (0, 2, 4, 5, 6):
        t0 = time.perf_counter()
        d = _run(n, f, spread=0.2)
        emit(f"breakdown/f={f}_of_{n}", (time.perf_counter() - t0) * 1e6,
             f"dist={d:.4f} frac={f/n:.2f}")
    # heterogeneity sweep (G grows with the spread of worker optima)
    base = None
    for spread in (0.05, 0.2, 0.8, 2.0):
        t0 = time.perf_counter()
        d = _run(n, 3, spread=spread)
        if base is None:
            base = max(d, 1e-9)
        emit(f"heterogeneity/G~{spread}", (time.perf_counter() - t0) * 1e6,
             f"dist={d:.4f} vs_G0.05={d/base:.1f}x")


if __name__ == "__main__":
    run()
