"""Batched robust-aggregation pass benchmarks at grid-engine shapes.

The hot path under test is the one the fused grid engine actually runs: a
``[n_cells * n_seeds, n, d]`` stack of worker gradients reduced to
``[n_cells * n_seeds, d]`` per round, per aggregation rule. For every rule
in ``repro.core.aggregators.KERNEL_RULES`` (plus the NNM pre-aggregation
composition) we time

* the jnp reference path (``use_pallas=False`` — the XLA rules), and
* the dispatch path (``use_pallas=None`` — Pallas kernels on TPU, the same
  jnp rules elsewhere),

warm (compile excluded), and record bytes-moved, achieved GB/s, and the
roofline floor from :func:`repro.launch.roofline.aggregation_roofline`.

Gates (written into ``results/BENCH_kernels.json`` + a repo-root mirror,
like bench_sweep):

* every backend: dispatch parity — the auto path matches the jnp path to
  rtol 1e-5 at every benched shape (on CPU they are the same code path, so
  this is exact; on TPU it is the kernel-vs-XLA parity gate);
* TPU only: the kernel path is never slower than the jnp path at Table-1
  shapes and beats it outright (>1x warm) at ``d >= 1e6`` — on other
  backends the roofline memory-bound floor is recorded instead (the
  "whichever gate is tighter on the available backend" clause of ISSUE 7).

Shapes: Table-1 quadratic grid (B=84 fused lanes, n=13, d=64), CNN-scale
(d=33k), and an LLM-block-scale column (B=8, n=13, d=1,048,576 — the
memory-bound regime the kernels exist for). Interpret-mode timings are
deliberately NOT benched: interpret mode is a correctness tool (see
tests/test_kernels.py) and is orders of magnitude off any real rate.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import aggregators as G
from repro.kernels.flash_attention import attention_ref
from repro.kernels.randk import block_compress_ref
from repro.launch.roofline import aggregation_roofline, detect_hardware

#: (label, B, n, f, d, iters) — B is the fused n_cells * n_seeds axis.
SHAPES = (
    ("table1", 84, 13, 3, 64, 20),
    ("cnn", 12, 13, 3, 33_450, 10),
    ("llm1m", 8, 13, 3, 1_048_576, 3),
)

RULES = (
    ("cwtm", False),
    ("median", False),
    ("krum", False),
    ("cwtm", True),  # NNM pre-aggregation exercises the pairdist kernel
)


def _batched_agg(name: str, f: int, pre_nnm: bool,
                 use_pallas: Optional[bool]):
    cfg = G.AggregatorConfig(name=name, f=f, pre_nnm=pre_nnm,
                             use_pallas=use_pallas)
    return jax.jit(jax.vmap(G.make_aggregator(cfg)))


def bench_rule(name: str, pre_nnm: bool, *, shape, spec, on_tpu: bool):
    label, b, n, f, d, iters = shape
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, n, d), jnp.float32)
    jnp_fn = _batched_agg(name, f, pre_nnm, use_pallas=False)
    auto_fn = _batched_agg(name, f, pre_nnm, use_pallas=None)

    y_jnp, y_auto = jnp_fn(x), auto_fn(x)
    scale = float(jnp.max(jnp.abs(y_jnp))) + 1e-12
    parity = float(jnp.max(jnp.abs(y_jnp - y_auto))) / scale

    us_jnp = time_fn(jnp_fn, x, iters=iters)
    us_auto = time_fn(auto_fn, x, iters=iters)

    rl = aggregation_roofline(batch=b, n=n, d=d, spec=spec)
    bytes_moved = b * (n * d + d) * 4
    gbs = bytes_moved / (us_auto / 1e6) / 1e9
    floor_us = rl.memory_s * 1e6
    rule = f"{name}{'+nnm' if pre_nnm else ''}"
    emit(f"kernels/{rule}/{label}", us_auto,
         f"jnp={us_jnp:.1f}us speedup={us_jnp / us_auto:.2f}x "
         f"GB/s={gbs:.1f} floor={floor_us:.1f}us parity={parity:.1e}")
    return {
        "shape": {"B": b, "n": n, "f": f, "d": d},
        "backend": G.kernel_backend_label(None),
        "jnp_us": us_jnp, "dispatch_us": us_auto,
        "speedup_vs_jnp": us_jnp / us_auto,
        "bytes_moved": bytes_moved, "achieved_gb_s": gbs,
        "roofline_floor_us": floor_us,
        "roofline_bottleneck": rl.bottleneck,
        "floor_ratio": us_auto / floor_us if floor_us > 0 else None,
        "dispatch_parity_rel": parity,
        "parity_ok": bool(parity <= 1e-5),
        # hard perf gates only where the kernel path is live (TPU); on CPU
        # the dispatch path IS the jnp path and timing ratios are noise
        "gated": bool(on_tpu),
    }


def _legacy_micro(results):
    """The pre-PR-7 single-op micro timings, kept for cross-PR trajectory
    (randk compressor + flash-attention reference paths)."""
    key = jax.random.PRNGKey(0)
    d, bs = 1 << 20, 512
    g = jax.random.normal(key, (d,))
    idx = jnp.arange(0, d // bs, 16, dtype=jnp.int32)
    us = time_fn(jax.jit(lambda a: block_compress_ref(a, idx, bs, 16.0)), g,
                 iters=5)
    emit("kernels/randk_compress_ref/d1M", us, f"k={idx.shape[0] * bs}")
    results["randk_compress_ref_us"] = us

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    us = time_fn(jax.jit(lambda a, b2: attention_ref(a, b2, b2)), q, k,
                 iters=3)
    emit("kernels/attention_ref/s1024", us, "")
    results["attention_ref_us"] = us
    return results


def run(out: str = "results/BENCH_kernels.json",
        out_root: str = "BENCH_kernels.json",
        hardware: Optional[str] = None):
    spec = detect_hardware(hardware)
    on_tpu = jax.default_backend() == "tpu"
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    results = {"hardware": spec.name,
               "backend": G.kernel_backend_label(None),
               "aggregation": {}}

    def flush():
        for path in (out, out_root):
            if path:
                os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                with open(path, "w") as fh:
                    json.dump(results, fh, indent=2)

    failures = []
    try:
        for shape in SHAPES:
            for name, pre in RULES:
                rule = f"{name}{'+nnm' if pre else ''}"
                row = bench_rule(name, pre, shape=shape, spec=spec,
                                 on_tpu=on_tpu)
                results["aggregation"][f"{rule}/{shape[0]}"] = row
                if not row["parity_ok"]:
                    failures.append(
                        f"{rule}/{shape[0]}: dispatch parity "
                        f"{row['dispatch_parity_rel']:.2e} > 1e-5")
                if row["gated"]:
                    # TPU gates: never slower at Table-1, >1x at d >= 1e6
                    if shape[0] == "table1" and row["speedup_vs_jnp"] < 0.95:
                        failures.append(
                            f"{rule}/table1: kernel path slower than jnp "
                            f"({row['speedup_vs_jnp']:.2f}x)")
                    if shape[3] >= 1_000_000 and row["speedup_vs_jnp"] <= 1.0:
                        failures.append(
                            f"{rule}/{shape[0]}: no speedup at d>=1e6 "
                            f"({row['speedup_vs_jnp']:.2f}x)")
        _legacy_micro(results)
        results["gates"] = {"ok": not failures, "failures": failures,
                            "perf_gated": on_tpu}
    finally:
        flush()
    if failures:
        raise SystemExit("bench_kernels gate failures:\n  "
                         + "\n  ".join(failures))
    return results


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--hardware", default=None,
                   choices=[None, "tpu-v5e", "tpu-v4", "tpu-v5p", "tpu-v6e",
                            "cpu"],
                   help="roofline hardware spec override (default: detect "
                        "from the JAX backend)")
    p.add_argument("--out", default="results/BENCH_kernels.json")
    p.add_argument("--out-root", default="BENCH_kernels.json")
    args = p.parse_args(argv)
    return run(out=args.out, out_root=args.out_root, hardware=args.hardware)


if __name__ == "__main__":
    main()
