"""Kernel-path microbenchmarks: XLA oracle timings for the three Pallas
kernels' reference paths (the TPU kernels themselves are compile-validated in
interpret mode; wall numbers here track the CPU oracle for regression)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.cwtm import cwtm_ref
from repro.kernels.flash_attention import attention_ref
from repro.kernels.randk import block_compress_ref, momentum_scatter_ref


def run():
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (16, 1_000_000))
    us = time_fn(jax.jit(lambda a: cwtm_ref(a, 3)), x, iters=5)
    emit("kernels/cwtm_ref/n16_d1e6", us,
         f"GB/s={(x.size*4/(us/1e6))/1e9:.2f}")

    d, bs = 1 << 20, 512
    g = jax.random.normal(key, (d,))
    idx = jnp.arange(0, d // bs, 16, dtype=jnp.int32)  # 1/16 of blocks
    us = time_fn(jax.jit(lambda a: block_compress_ref(a, idx, bs, 16.0)), g,
                 iters=5)
    emit("kernels/randk_compress_ref/d1M", us, f"k={idx.shape[0]*bs}")

    payload = jax.random.normal(key, (idx.shape[0] * bs,))
    us = time_fn(jax.jit(
        lambda a, p: momentum_scatter_ref(a, p, idx, bs, 0.9)), g, payload,
        iters=5)
    emit("kernels/momentum_scatter_ref/d1M", us, "")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    us = time_fn(jax.jit(lambda a, b: attention_ref(a, b, b)), q, k, iters=3)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    emit("kernels/attention_ref/s1024", us,
         f"GFLOP/s={(flops/(us/1e6))/1e9:.1f}")


if __name__ == "__main__":
    run()
