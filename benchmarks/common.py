"""Shared benchmark utilities: timing, CSV output, the paper's protocol."""

from __future__ import annotations

import sys
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

TAU = 0.85  # the paper's target accuracy threshold


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV line per measurement: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.2f},{derived}")
    sys.stdout.flush()


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall microseconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


# --------------------------------------------------------------------------
# the paper's Section-4 protocol: train to tau, report communication bytes
# --------------------------------------------------------------------------

# learning rates tuned per compression ratio under f=0 (the paper's own
# tuning protocol, Section 4)
GAMMA_BY_RATIO: Dict[float, float] = {
    0.01: 0.01, 0.05: 0.05, 0.1: 0.05, 0.3: 0.1, 0.5: 0.1, 1.0: 0.2,
}


def comm_cost_to_tau(*, ratio: float, f: int, attack: str = "alie",
                     algo: str = "rosdhb", agg: str = "cwtm",
                     n_honest: int = 10, steps: int = 600,
                     per_worker: int = 800, batch: int = 60,
                     gamma: Optional[float] = None, seed: int = 0,
                     tau: float = TAU) -> Dict:
    """Run the paper's experiment for one (ratio, f) cell.

    Runs on the batched engine: ``Simulator.run`` executes the trajectory as
    lax.scan chunks between eval rounds (see core/simulator.py), so one cell
    pays host dispatch per eval instead of per round. Multi-cell grids are
    cheaper still through ``repro.core.sweep`` (vmapped seeds + fused attack
    axis; see benchmarks/bench_sweep.py).

    Returns dict with comm bytes to reach tau (or inf), final accuracy,
    rounds used.
    """
    from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                            Simulator, SparsifierConfig)
    from repro.data import SyntheticMNIST
    from repro.models import cnn_accuracy, cnn_init, cnn_loss

    n = n_honest + f
    gamma = gamma if gamma is not None else GAMMA_BY_RATIO.get(ratio, 0.05)
    ds = SyntheticMNIST(n_workers=n, per_worker=per_worker, seed=seed)
    cfg = AlgorithmConfig(
        name=algo, n_workers=n, f=f, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=(AggregatorConfig(name="mean") if agg == "mean"
                    else AggregatorConfig(name=agg, f=max(f, 1))),
        attack=AttackConfig(name=attack))
    sim = Simulator(loss_fn=cnn_loss, params0=cnn_init(jax.random.PRNGKey(0)),
                    cfg=cfg, eval_fn=lambda p, b: {"acc": cnn_accuracy(p, b)})
    st = sim.init(seed)
    reached = {}

    def stop(m):
        if m.get("acc", 0.0) >= tau and not reached:
            reached["bytes"] = m["comm_bytes"]
        return bool(reached)

    st, hist = sim.run(st, ds.worker_batches(batch), steps=steps,
                       eval_every=20, eval_batch=ds.eval_batch, stop_fn=stop)
    return {
        "ratio": ratio, "f": f, "gamma": gamma,
        "comm_bytes_to_tau": reached.get("bytes", float("inf")),
        "final_acc": hist["acc"][-1] if hist["acc"] else 0.0,
        "rounds": hist["step"][-1] + 1 if hist["step"] else 0,
    }
