"""Benchmark + gate the fault-injected serving stack (repro.serve.chaos).

Three gated sections, JSON'd to results/BENCH_chaos.json after each one:

  loopback_parity  fault-free chaos over the loopback transport vs the
                   in-process server on the bench_serve parity cells: the
                   framed byte path (encode -> CRC -> decode) must be
                   bit-for-bit invisible (max_abs_diff == 0.0), and the
                   jitted step must compile exactly once per server.
  chaos_matrix     every registered chaos scenario at n=13, f=3 Byzantine
                   (ALIE vs CWTM+NNM). Gates: every driven round
                   terminates, no unresolved liveness-watchdog fires,
                   step_traces == 1 per server instance (restarts
                   included), kill-restart resumes bit-for-bit, and the
                   combined-fault scenario (drop + duplicate + corrupt +
                   delay + reset + straggler + mid-round kill-and-restart)
                   lands its final honest loss within rtol 0.1 of the
                   fault-free run.
  tcp_parity       fault-free chaos over real TCP sockets — same bitwise
                   parity gate; skipped (recorded, not failed) where the
                   sandbox forbids sockets.

Run: PYTHONPATH=src:. python -m benchmarks.bench_chaos
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.sweep import grid_scenarios, quadratic_testbed
from repro.serve import (
    CHAOS_REGISTRY, ByzantineRobustServer, ClientPool, ServeConfig,
    get_chaos, run_chaos, run_service,
)
from repro.utils import tree as T

D = 256
PARITY_ROUNDS = 30
CHAOS_ROUNDS = 30
N_HONEST, F = 10, 3
LOSS_RTOL = 0.1


def _cfg(algo="rosdhb", attack="alie", agg="cwtm", **kw):
    return grid_scenarios((algo,), (attack,), (agg,),
                          n_honest=N_HONEST, f=F, **kw)[0].cfg


def _honest_loss(flat, targets, spec, f):
    w = np.asarray(flat)[:spec.size]
    t = np.asarray(targets)[f:]
    return float(0.5 * np.mean(np.sum((w[None, :] - t) ** 2, axis=1)))


def _transport_parity(transport: str):
    """Fault-free chaos over ``transport`` vs the in-process server: the
    transport boundary must be bit-for-bit invisible."""
    out = {}
    chaos = dataclasses.replace(get_chaos("fault-free"),
                                transport=transport)
    for algo, attack, agg in (("rosdhb", "alie", "cwtm"),
                              ("rosdhb", "foe", "median"),
                              ("robust_dgd", "signflip", "cwtm")):
        cfg = _cfg(algo, attack, agg)
        loss_fn, params0, batch_fn, _ = quadratic_testbed(cfg.n_workers, d=D)
        server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
        pool = ClientPool(loss_fn, params0, cfg, batch_fn)
        run_service(server, pool, PARITY_ROUNDS)
        res = run_chaos(cfg, params0, batch_fn, loss_fn, chaos,
                        PARITY_ROUNDS, seed=0)
        diff = float(np.max(np.abs(res.final_params
                                   - np.asarray(server.params_flat))))
        key = f"{algo}/{attack}/{agg}"
        out[key] = {"rounds": PARITY_ROUNDS, "max_abs_diff": diff,
                    "exact": diff == 0.0, "step_traces": res.step_traces}
        emit(f"chaos/parity/{transport}/{key}", 0.0,
             f"max_abs_diff={diff} traces={res.step_traces}")
        assert diff == 0.0, (
            f"{transport} transport parity broken for {key}: {diff}")
        assert res.step_traces == [1]
    return out


def _loopback_parity():
    return _transport_parity("loopback")


def _tcp_parity():
    """Same gate over real sockets; a sandbox that forbids sockets gets a
    recorded skip, not a failure."""
    try:
        import socket
        probe = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            probe.bind(("127.0.0.1", 0))
        finally:
            probe.close()
    except OSError as e:
        emit("chaos/parity/tcp", 0.0, f"SKIPPED: {e}")
        return {"skipped": True, "reason": str(e)}
    return _transport_parity("tcp")


def _chaos_matrix():
    """Every registered scenario against the f=3-of-13 ALIE cell, with the
    combined-fault loss gate and the kill-restart bitwise gate."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, targets = quadratic_testbed(cfg.n_workers,
                                                            d=D)
    spec = T.make_flat_spec(params0)
    out = {}
    finals = {}
    base_loss = None
    for name in CHAOS_REGISTRY:
        res = run_chaos(cfg, params0, batch_fn, loss_fn, get_chaos(name),
                        CHAOS_ROUNDS, seed=0)
        loss = _honest_loss(res.final_params, targets, spec, F)
        finals[name] = res.final_params
        last = res.summaries[-1]
        rec = {
            "rounds_driven": res.rounds_driven,
            "rounds_applied": sum(s["rounds"] for s in res.summaries),
            "all_rounds_terminated": res.all_rounds_terminated(),
            "restarts": res.restarts,
            "step_traces": res.step_traces,
            "final_honest_loss": loss,
            "injected_faults": res.injected,
            "client_stats": res.client_stats,
            "ingest_decisions": last["ingest_decisions"],
            "quorum_histogram": last["quorum_histogram"],
            "quorum_transitions": last["quorum_transitions"],
            "watchdog": [s["watchdog"] for s in res.summaries],
            "fault_budget_events": [e for s in res.summaries
                                    for e in s["fault_budget_events"]],
            "updates_per_sec": last["updates_per_sec"],
            "latency_p50_ms": last["latency_p50_ms"],
            "latency_p99_ms": last["latency_p99_ms"],
        }
        if name == "fault-free":
            base_loss = loss
        elif base_loss is not None:
            rec["loss_vs_fault_free_rtol"] = (
                abs(loss - base_loss) / max(abs(base_loss), 1e-12))
        out[name] = rec
        emit(f"chaos/scenario/{name}", 0.0,
             f"loss={loss:.4f} restarts={res.restarts} "
             f"injected={sum(res.injected.values())} "
             f"traces={res.step_traces} "
             f"terminated={res.all_rounds_terminated()}")
        # liveness + single-compile gates hold for EVERY scenario
        assert res.all_rounds_terminated(), (
            f"chaos scenario {name!r}: rounds failed to terminate "
            f"({len(res.results)}/{res.rounds_driven}, "
            f"{res.unresolved_watchdogs} unresolved watchdog fires)")
        assert all(t == 1 for t in res.step_traces), (
            f"chaos scenario {name!r} retraced the step: "
            f"{res.step_traces}")

    # gate: the combined-fault scenario converges like the fault-free run
    combined = out["combined"]
    emit("chaos/gate/combined_loss", 0.0,
         f"loss={combined['final_honest_loss']:.4f} "
         f"fault_free={base_loss:.4f} "
         f"rtol={combined['loss_vs_fault_free_rtol']:.4f}")
    assert combined["loss_vs_fault_free_rtol"] <= LOSS_RTOL, (
        f"combined-fault loss {combined['final_honest_loss']} drifted "
        f"beyond rtol {LOSS_RTOL} of fault-free {base_loss}")
    assert combined["restarts"] == 1

    # gate: a mid-round crash + restore on a CLEAN transport is bitwise
    # invisible — same final parameters as never having crashed
    kr_diff = float(np.max(np.abs(finals["kill-restart"]
                                  - finals["fault-free"])))
    out["kill-restart"]["bitwise_vs_fault_free"] = kr_diff
    emit("chaos/gate/kill_restart_bitwise", 0.0, f"max_abs_diff={kr_diff}")
    assert kr_diff == 0.0, (
        f"mid-round kill-and-restart diverged from the uncrashed "
        f"trajectory: max_abs_diff={kr_diff}")
    return out


def run(out: str = "results/BENCH_chaos.json",
        out_root: str = "BENCH_chaos.json"):
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    results = {}

    def record(name, fn):
        try:
            results[name] = fn()
        finally:
            for path in (out, out_root):
                if path:
                    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                    with open(path, "w") as fh:
                        json.dump(results, fh, indent=2)

    record("loopback_parity", _loopback_parity)
    record("chaos_matrix", _chaos_matrix)
    record("tcp_parity", _tcp_parity)
    return results


if __name__ == "__main__":
    run()
