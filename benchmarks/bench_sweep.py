"""One-program grid engine: fused/sharded sweep vs sequential Simulator runs.

Five claims are measured (and all but the sharding one gated):

1. **Attack fusion**: a 4-seed x 3-attack grid through ``repro.core.sweep``
   must be >= 1.2x faster wall-clock than sequential ``Simulator.run`` calls
   on CPU, with identical per-cell bytes-to-tau tables. (PR 1 gated this at
   5x against the then-chunked ``run``; the eval-in-scan rewrite made the
   baseline itself ~2x cheaper — one compile per cell instead of one per
   distinct chunk length — and wall-clock on shared 2-core CI is noisy, so
   the hard gates are now the *compile counts* of claim 2 and the loose
   1.2x floor here; typical observed speedup is 2-4x.)
2. **One compile for the whole grid**: a rosdhb x 5-attack x 3-aggregator
   x 4-seed grid (the paper's Fig.-1-style comparison across robust rules)
   plans to ONE fusible bank and traces the round body exactly once
   (``Simulator.round_traces`` — jit compiles trace once, so this counts
   compiled programs), where the per-scenario path pays one compile per
   scenario (n_attacks x n_aggregators of them).
3. **Stateful attack bank**: a mixed grid of SIX attacks — three stateless
   (alie/signflip/foe) plus the stateful tracked mimic, gauss, and the
   adaptive spectral attack (``repro.adversary``) — x 3 aggregators must
   STILL plan to one bank and trace the round body exactly once, with every
   cell matching its per-scenario (statically configured) rollout. This is
   the ISSUE-3 acceptance gate: adversary memory lives in the scan carry,
   so statefulness no longer breaks fusion.
4. **Cross-algorithm bank** (the ISSUE-4 Table-1 acceptance gate): the
   paper's full algorithm axis — rosdhb, Byz-DASHA-PAGE, robust DGD, plain
   DGD — x 3 attacks x 2 aggregators x 4 seeds must plan to ONE bank
   (``lax.switch`` algorithm branches over the unified ``ServerState``,
   per-cell hyperparameters as traced data) and trace the round body
   exactly once, where the legacy per-algorithm partition
   (``plan_grid(cross_algo=False)``) pays one compile per algorithm. Every
   cell must match its per-algorithm-bank trajectory (single-algorithm
   banks are bit-for-bit equal — pinned in tests/test_algo_bank.py; inside
   the multi-branch switch XLA may fuse across branches and drift by an
   ulp, so the gate compares at rtol=1e-5).
5. **Device sharding**: the same bank laid out over all visible devices
   (``--shard`` path, ``repro.sharding.sweep_mesh``) must match the
   single-device rows exactly; the speedup is reported (force virtual CPU
   devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
   near-linear until the physical core count saturates).

All timings land in ``results/BENCH_sweep.json`` for CI trend tracking.

The engine is timed FIRST (coldest JAX state), so any in-process warmup
favours the baselines.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AttackConfig, Simulator, grid_scenarios, plan_grid,
                        quadratic_testbed, rollout_over_seeds, stack_batches)
from repro.core.sweep import fused_attack_rollout, fused_grid_rollout

D = 64
STEPS = 300
EVAL_EVERY = 20
TAU_LOSS = 0.5  # honest-mean-loss threshold standing in for the paper's tau
SEEDS = (0, 1, 2, 3)
ATTACKS = ("alie", "foe", "signflip")
GRID_ATTACKS = ("alie", "signflip", "ipm", "foe", "zero")
GRID_AGGS = ("cwtm", "median", "geomed")
STATEFUL_ATTACKS = ("alie", "signflip", "foe", "mimic", "gauss", "spectral")
CROSS_ALGOS = ("rosdhb", "dasha", "robust_dgd", "dgd")
CROSS_ATTACKS = ("alie", "foe", "signflip")
CROSS_AGGS = ("cwtm", "median")


def _attack_fusion_gate(loss_fn, params0, batch_fn, batches, scenarios):
    """Claim 1: fused attack grid vs sequential Simulator.run (1.2x floor)."""
    cells = len(scenarios) * len(SEEDS)
    eval_rounds = np.asarray([t for t in range(STEPS)
                              if t % EVAL_EVERY == 0 or t == STEPS - 1])

    # -- the engine: one compiled program for the whole grid, post-hoc stop
    t0 = time.perf_counter()
    lin = dataclasses.replace(scenarios[0].cfg,
                              attack=AttackConfig(name="linear"))
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=lin)
    per_round_bytes = sim.payload_bytes_per_round()
    _, metrics = fused_attack_rollout(
        sim, [sc.cfg.attack for sc in scenarios], SEEDS, batches)
    loss_at_evals = np.asarray(metrics["loss"])[:, :, eval_rounds]
    hit = loss_at_evals <= TAU_LOSS
    first = np.where(hit.any(-1), hit.argmax(-1), 0)
    sweep_bytes = np.where(hit.any(-1),
                           (eval_rounds[first] + 1.0) * per_round_bytes,
                           np.inf)
    t_sweep = time.perf_counter() - t0

    # -- sequential baselines: same protocol, one cell at a time
    def sequential(method):
        out = np.full((len(scenarios), len(SEEDS)), np.inf)
        t0 = time.perf_counter()
        for a, sc in enumerate(scenarios):
            for i, s in enumerate(SEEDS):
                cell_sim = Simulator(loss_fn=loss_fn, params0=params0,
                                     cfg=sc.cfg)
                reached = {}

                def stop(m):
                    if m["loss"] <= TAU_LOSS and not reached:
                        reached["bytes"] = m["comm_bytes"]
                    return bool(reached)

                getattr(cell_sim, method)(cell_sim.init(s), batch_fn, STEPS,
                                          eval_every=EVAL_EVERY, stop_fn=stop)
                out[a, i] = reached.get("bytes", np.inf)
        return time.perf_counter() - t0, out

    t_run, run_bytes = sequential("run")
    t_legacy, legacy_bytes = sequential("run_per_round")

    # Output parity: the three engines must find the same crossings. The
    # paths are separately compiled XLA programs, so a cell whose eval loss
    # grazes TAU_LOSS within float rounding may legitimately cross one eval
    # round apart — tolerate a mismatch only there.
    def assert_same_crossings(other):
        diff = sweep_bytes != other
        grazes = np.min(np.abs(loss_at_evals - TAU_LOSS), axis=-1) < 1e-4
        assert np.all(~diff | grazes), (sweep_bytes, other)

    assert_same_crossings(run_bytes)
    assert_same_crossings(legacy_bytes)

    emit("sweep/sequential_run_cells", t_run * 1e6 / cells,
         f"total={t_run:.2f}s (acceptance baseline)")
    emit("sweep/sequential_per_round_cells", t_legacy * 1e6 / cells,
         f"total={t_legacy:.2f}s")
    emit("sweep/fused_engine", t_sweep * 1e6 / cells,
         f"total={t_sweep:.2f}s speedup_vs_run={t_run / t_sweep:.1f}x "
         f"speedup_vs_per_round={t_legacy / t_sweep:.1f}x")
    # Loose 1.2x floor: the sequential baseline is itself on the one-scan
    # engine now (a single compile per cell, no chunk-boundary recompiles),
    # so the remaining fused win is compile amortisation across cells —
    # which grows with grid size and is gated deterministically via compile
    # counts in _one_program_grid (wall-clock on shared CI is too noisy for
    # a tight gate).
    speedup = t_run / t_sweep
    assert speedup >= 1.2, (
        f"fused sweep only {speedup:.1f}x faster than sequential "
        f"Simulator.run calls (acceptance floor is 1.2x)")
    return {"run_s": t_run, "per_round_s": t_legacy, "sweep_s": t_sweep,
            "speedup": speedup}


def _one_program_grid(loss_fn, params0, batches):
    """Claim 2: attack x aggregator grid = ONE compiled program (counted)."""
    scenarios = grid_scenarios(["rosdhb"], GRID_ATTACKS, GRID_AGGS,
                               n_honest=10, f=3, ratio=0.1, gamma=0.05)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == len(scenarios), \
        plan.describe()
    bank = plan.banks[0]

    t0 = time.perf_counter()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    states, metrics = fused_grid_rollout(
        sim, bank.scenario_params(), SEEDS, batches, shard=False)
    jax.block_until_ready(metrics["loss"])
    t_bank = time.perf_counter() - t0
    assert sim.round_traces == 1, (
        f"fused grid traced the round body {sim.round_traces}x; "
        "expected ONE compiled program for the whole bank")
    fused_loss = np.asarray(metrics["loss"])  # [n_cells, n_seeds, steps]

    # per-scenario path: one vmapped-scan compile per (attack, aggregator)
    t0 = time.perf_counter()
    traces = 0
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        _, ref_metrics = rollout_over_seeds(ref, SEEDS, batches)
        traces += ref.round_traces
        np.testing.assert_allclose(
            fused_loss[c], np.asarray(ref_metrics["loss"]),
            rtol=1e-4, atol=1e-6, err_msg=sc.label)
    t_seq = time.perf_counter() - t0
    assert traces == len(bank.scenarios), traces

    n_cells = len(scenarios)
    emit("sweep/grid_one_program", t_bank * 1e6 / (n_cells * len(SEEDS)),
         f"total={t_bank:.2f}s compiles=1 cells={n_cells}")
    emit("sweep/grid_per_scenario", t_seq * 1e6 / (n_cells * len(SEEDS)),
         f"total={t_seq:.2f}s compiles={traces} "
         f"speedup_fused={t_seq / t_bank:.1f}x")
    return {"bank_s": t_bank, "per_scenario_s": t_seq,
            "bank_compiles": sim.round_traces, "per_scenario_compiles": traces,
            "n_cells": n_cells, "speedup": t_seq / t_bank}


def _stateful_grid(loss_fn, params0, batches):
    """Claim 3 (ISSUE-3 acceptance): 6 mixed stateless+stateful attacks x 3
    aggregators = ONE compiled program, cells match per-scenario rollouts."""
    scenarios = grid_scenarios(["rosdhb"], STATEFUL_ATTACKS, GRID_AGGS,
                               n_honest=10, f=3, ratio=0.1, gamma=0.05)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == len(scenarios), \
        plan.describe()
    bank = plan.banks[0]
    assert {"mimic", "gauss", "spectral"} <= set(bank.cfg.attack.bank)

    t0 = time.perf_counter()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    _, metrics = fused_grid_rollout(
        sim, bank.scenario_params(), SEEDS, batches, shard=False)
    jax.block_until_ready(metrics["loss"])
    t_bank = time.perf_counter() - t0
    assert sim.round_traces == 1, (
        f"stateful attack bank traced the round body {sim.round_traces}x; "
        "expected ONE compiled program for the whole mixed grid")
    fused_loss = np.asarray(metrics["loss"])

    # parity: every cell (stateful adversaries included — their memory is in
    # the scan carry on both paths) matches its per-scenario program
    t0 = time.perf_counter()
    traces = 0
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        _, ref_metrics = rollout_over_seeds(ref, SEEDS, batches)
        traces += ref.round_traces
        np.testing.assert_allclose(
            fused_loss[c], np.asarray(ref_metrics["loss"]),
            rtol=1e-4, atol=1e-6, err_msg=sc.label)
    t_seq = time.perf_counter() - t0

    n_cells = len(scenarios)
    emit("sweep/stateful_grid_one_program",
         t_bank * 1e6 / (n_cells * len(SEEDS)),
         f"total={t_bank:.2f}s compiles=1 cells={n_cells} "
         f"attacks={len(STATEFUL_ATTACKS)} (3 stateful)")
    emit("sweep/stateful_grid_per_scenario",
         t_seq * 1e6 / (n_cells * len(SEEDS)),
         f"total={t_seq:.2f}s compiles={traces} "
         f"speedup_fused={t_seq / t_bank:.1f}x")
    return {"bank_s": t_bank, "per_scenario_s": t_seq,
            "bank_compiles": sim.round_traces,
            "per_scenario_compiles": traces, "n_cells": n_cells,
            "speedup": t_seq / t_bank}


def _timed_fused(sim, bank, batches, repeats=2):
    """(cold_s, warm_s, loss): cold includes the compile; warm is the best
    of ``repeats`` cached-program executions (min damps CI scheduler noise)."""
    t0 = time.perf_counter()
    _, metrics = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                    batches, shard=False)
    jax.block_until_ready(metrics["loss"])
    cold = time.perf_counter() - t0
    warm = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, m = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                  batches, shard=False)
        jax.block_until_ready(m["loss"])
        warm = min(warm, time.perf_counter() - t0)
    return cold, warm, np.asarray(metrics["loss"])


def _cross_algo_grid(loss_fn, params0, batches):
    """Claim 4 + the PR-6 bugfix gate: measure BOTH static plans for the
    Table-1 grid (one fused 4-branch program vs the per-algorithm
    partition), calibrate the cost model from those probes (persisted to
    ``results/COST_MODEL.json``), and gate that the model's chosen plan is
    never slower than the best static choice — the warm-runtime floor that
    the PR-4 gate lacked when the fused default shipped at 0.52x warm."""
    from repro.core import CostModel

    scenarios = grid_scenarios(CROSS_ALGOS, CROSS_ATTACKS, CROSS_AGGS,
                               n_honest=10, f=3, ratio=0.1, gamma=0.05)
    rows = len(scenarios) * len(SEEDS)

    # -- static choice A: ONE fused cross-algorithm program
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == len(scenarios), \
        plan.describe()
    bank = plan.banks[0]
    assert set(bank.cfg.bank) == set(CROSS_ALGOS)
    assert bank.cfg.resolved_state_layout().is_full  # dasha branch present
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    fused_cold, fused_warm, floss = _timed_fused(sim, bank, batches)
    assert sim.round_traces == 1, (
        f"cross-algorithm bank traced the round body {sim.round_traces}x; "
        "expected ONE compiled program for the whole Table-1 grid")
    fused_loss = {sc.label: floss[c] for c, sc in enumerate(bank.scenarios)}

    # -- static choice B: the legacy per-algorithm banks (4 compiles), each
    # dasha-free bank scanning the pruned carry
    per_plan = plan_grid(scenarios, cross_algo=False)
    assert per_plan.n_programs == len(CROSS_ALGOS), per_plan.describe()
    part_cold = part_warm = 0.0
    traces = 0
    single_probe = None  # the 1-branch calibration probe (rosdhb's bank)
    for b in per_plan.banks:
        assert (b.cfg.resolved_state_layout().is_full
                == (b.cfg.name == "dasha")), b.cfg.name
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=b.cfg)
        cold, warm, loss = _timed_fused(ref, b, batches)
        part_cold, part_warm = part_cold + cold, part_warm + warm
        traces += ref.round_traces
        if b.cfg.name == "rosdhb":
            single_probe = (cold, warm, b.n_cells * len(SEEDS))
        for c, sc in enumerate(b.scenarios):
            np.testing.assert_allclose(
                fused_loss[sc.label], loss[c],
                rtol=1e-5, atol=1e-7, err_msg=sc.label)
    assert traces == len(CROSS_ALGOS), traces

    # -- calibration pass: fit the cost model from the two probes, persist
    model = CostModel.fit(
        single_cold_s=single_probe[0], single_warm_s=single_probe[1],
        single_rows=single_probe[2],
        fused_cold_s=fused_cold, fused_warm_s=fused_warm, fused_rows=rows,
        branches=len(CROSS_ALGOS), rounds=STEPS,
        source=f"bench_sweep table1 D={D} steps={STEPS}")
    model.save("results/COST_MODEL.json")

    # -- the model's choice, re-planned and EXECUTED (fresh sims: the
    # partition emits 1-entry algorithm banks, bit-for-bit equal to the
    # legacy banks — pinned in tests — but separate configs/compiles)
    chosen_plan = plan_grid(scenarios, cost_model=model, rounds=STEPS,
                            n_seeds=len(SEEDS))
    chosen_kind = ("fused" if chosen_plan.n_programs == 1 else "partitioned")
    chosen_cold = chosen_warm_exec = 0.0
    for b in chosen_plan.banks:
        csim = Simulator(loss_fn=loss_fn, params0=params0, cfg=b.cfg)
        cold, warm, loss = _timed_fused(csim, b, batches)
        chosen_cold, chosen_warm_exec = chosen_cold + cold, \
            chosen_warm_exec + warm
        for c, sc in enumerate(b.scenarios):
            np.testing.assert_allclose(
                fused_loss[sc.label], loss[c],
                rtol=1e-5, atol=1e-7, err_msg=sc.label)
    assert not chosen_plan.singles, chosen_plan.describe()

    # the decision gate: the plan the model picked must BE the measured-best
    # static choice (this is what let 0.52x ship: PR 4 gated compiles and
    # parity but never warm runtime)
    best_warm = min(fused_warm, part_warm)
    chosen_warm = fused_warm if chosen_kind == "fused" else part_warm
    speedup = best_warm / chosen_warm
    assert speedup >= 1.0, (
        f"cost model chose {chosen_kind} ({chosen_warm:.2f}s warm) over a "
        f"faster static plan ({best_warm:.2f}s warm)")
    # the warm-runtime floor: actually executing the chosen plan must land
    # within noise tolerance of the best static warm time
    assert chosen_warm_exec <= best_warm * 1.25, (
        f"chosen plan executed at {chosen_warm_exec:.2f}s warm vs best "
        f"static {best_warm:.2f}s (tolerance 1.25x)")

    n_cells = len(scenarios)
    emit("sweep/cross_algo_one_program", fused_cold * 1e6 / rows,
         f"cold={fused_cold:.2f}s warm={fused_warm:.2f}s compiles=1 "
         f"cells={n_cells} algos={len(CROSS_ALGOS)}")
    emit("sweep/cross_algo_per_algo_banks", part_cold * 1e6 / rows,
         f"cold={part_cold:.2f}s warm={part_warm:.2f}s compiles={traces}")
    emit("sweep/cross_algo_chosen_plan", chosen_warm_exec * 1e6 / rows,
         f"{chosen_kind} warm={chosen_warm_exec:.2f}s "
         f"vs best static warm={best_warm:.2f}s "
         f"(fused_warm/partitioned_warm={fused_warm / part_warm:.2f})")
    return {"bank_s": fused_cold, "per_algo_s": part_cold,
            "fused_warm_s": fused_warm, "per_algo_warm_s": part_warm,
            "chosen": chosen_kind, "chosen_cold_s": chosen_cold,
            "chosen_warm_s": chosen_warm_exec,
            "bank_compiles": sim.round_traces, "per_algo_compiles": traces,
            "n_cells": n_cells, "speedup": speedup,
            "warm_vs_fused_default": fused_warm / chosen_warm}


def _sharded_grid(loss_fn, params0, batches):
    """Claim 5: the bank sharded across devices matches single-device."""
    n_dev = len(jax.devices())
    scenarios = grid_scenarios(["rosdhb"], GRID_ATTACKS, GRID_AGGS,
                               n_honest=10, f=3, ratio=0.1, gamma=0.05)
    bank = plan_grid(scenarios).banks[0]

    def timed(shard):
        """(cold_s, warm_s, loss): cold includes the compile; warm is the
        cached-program execution — the number that scales with devices."""
        sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
        t0 = time.perf_counter()
        _, metrics = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                        batches, shard=shard)
        loss = np.asarray(metrics["loss"])
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, metrics = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                        batches, shard=shard)
        jax.block_until_ready(metrics["loss"])
        warm = time.perf_counter() - t0
        return cold, warm, loss

    c_single, w_single, loss_single = timed(False)
    if n_dev < 2:
        emit("sweep/sharded_grid", w_single * 1e6,
             f"SKIPPED n_devices={n_dev} (set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return {"n_devices": n_dev, "single_warm_s": w_single,
                "sharded_warm_s": None}
    c_shard, w_shard, loss_shard = timed(True)
    np.testing.assert_allclose(loss_shard, loss_single, rtol=1e-5, atol=1e-7)
    # sharding the grid axis makes the COLD compile slower than
    # single-device — SPMD partitioning overhead on the same program. Fold
    # the measurement into the persisted cost model so plan_grid's
    # fused-vs-partitioned predictions charge sharded compiles correctly.
    cold_overhead = c_shard - c_single
    from repro.core import CostModel
    model = CostModel.load_or_default()
    model = dataclasses.replace(
        model, sharded_compile_overhead_s=max(0.0, cold_overhead),
        source=model.source.replace("+sharded", "") + "+sharded")
    model.save("results/COST_MODEL.json")
    emit("sweep/sharded_grid", w_shard * 1e6,
         f"n_devices={n_dev} warm single={w_single:.2f}s "
         f"sharded={w_shard:.2f}s speedup={w_single / w_shard:.2f}x "
         f"(cold {c_single:.2f}s/{c_shard:.2f}s "
         f"overhead={cold_overhead:+.2f}s -> cost model)")
    return {"n_devices": n_dev, "single_warm_s": w_single,
            "sharded_warm_s": w_shard, "single_cold_s": c_single,
            "sharded_cold_s": c_shard, "speedup": w_single / w_shard,
            "cold_compile_overhead_s": cold_overhead}


def run(out: str = "results/BENCH_sweep.json",
        out_root: str = "BENCH_sweep.json"):
    f = 3
    n = 10 + f
    loss_fn, params0, batch_fn, _ = quadratic_testbed(n, D, seed=0)
    scenarios = grid_scenarios(["rosdhb"], ATTACKS, ["cwtm"], n_honest=10,
                               f=f, ratio=0.1, gamma=0.05)
    batches = stack_batches(batch_fn, STEPS)
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    # write the JSON after every section so a failed gate still leaves the
    # partial timings behind for diagnosis (CI uploads it with if: always());
    # a second copy lands at the repo root so the cross-PR perf trajectory
    # is tracked in-tree, not just as a CI artifact
    results = {}

    def record(name, fn):
        try:
            results[name] = fn()
        finally:
            for path in (out, out_root):
                if path:
                    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                    with open(path, "w") as fh:
                        json.dump(results, fh, indent=2)

    record("attack_fusion", lambda: _attack_fusion_gate(
        loss_fn, params0, batch_fn, batches, scenarios))
    record("grid_one_program",
           lambda: _one_program_grid(loss_fn, params0, batches))
    record("stateful_grid",
           lambda: _stateful_grid(loss_fn, params0, batches))
    record("cross_algo_grid",
           lambda: _cross_algo_grid(loss_fn, params0, batches))
    record("sharded", lambda: _sharded_grid(loss_fn, params0, batches))
    return results


if __name__ == "__main__":
    run()
