"""Batched-engine speedup: the grid sweep vs sequential Simulator runs.

The engine PR's acceptance gate: a 4-seed x 3-attack grid through
``repro.core.sweep`` must be >= 5x faster wall-clock than sequential
``Simulator.run`` calls on CPU. Both paths execute the paper's
comm-bytes-to-threshold protocol on the quadratic testbed and must produce
IDENTICAL per-cell bytes-to-tau tables (asserted below) — the comparison is
end-to-end, compilation included, because per-cell construct + compile +
run is exactly what sequential sweeping pays (see
``benchmarks.common.comm_cost_to_tau``).

Paths, slowest to fastest:
  * sequential ``Simulator.run`` per cell — the acceptance baseline: eval
    every 20 rounds with a stop_fn, fresh Simulator per cell;
  * sequential legacy ``Simulator.run_per_round`` per cell — the pre-engine
    loop (one compile per cell, one dispatch per round);
  * the fused engine: ONE compiled program for all 12 cells — linear-family
    attack coefficients as a traced vmap axis (``fused_attack_rollout``),
    seeds as a vmap axis, rounds as a lax.scan, threshold crossings
    post-hoc from the stacked on-device loss trajectory.

The engine is timed FIRST (coldest JAX state), so any in-process warmup
favours the baselines.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AttackConfig, Simulator, grid_scenarios,
                        quadratic_testbed, stack_batches)
from repro.core.sweep import fused_attack_rollout

D = 64
STEPS = 300
EVAL_EVERY = 20
TAU_LOSS = 0.5  # honest-mean-loss threshold standing in for the paper's tau
SEEDS = (0, 1, 2, 3)
ATTACKS = ("alie", "foe", "signflip")


def run():
    f = 3
    n = 10 + f
    loss_fn, params0, batch_fn, _ = quadratic_testbed(n, D, seed=0)
    scenarios = grid_scenarios(["rosdhb"], ATTACKS, ["cwtm"], n_honest=10,
                               f=f, ratio=0.1, gamma=0.05)
    batches = stack_batches(batch_fn, STEPS)
    cells = len(scenarios) * len(SEEDS)
    eval_rounds = np.asarray([t for t in range(STEPS)
                              if t % EVAL_EVERY == 0 or t == STEPS - 1])
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    # -- the engine: one compiled program for the whole grid, post-hoc stop
    t0 = time.perf_counter()
    lin = dataclasses.replace(scenarios[0].cfg,
                              attack=AttackConfig(name="linear"))
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=lin)
    per_round_bytes = sim.payload_bytes_per_round()
    _, metrics = fused_attack_rollout(
        sim, [sc.cfg.attack for sc in scenarios], SEEDS, batches)
    loss_at_evals = np.asarray(metrics["loss"])[:, :, eval_rounds]
    hit = loss_at_evals <= TAU_LOSS
    first = np.where(hit.any(-1), hit.argmax(-1), 0)
    sweep_bytes = np.where(hit.any(-1),
                           (eval_rounds[first] + 1.0) * per_round_bytes,
                           np.inf)
    t_sweep = time.perf_counter() - t0

    # -- sequential baselines: same protocol, one cell at a time
    def sequential(method):
        out = np.full((len(scenarios), len(SEEDS)), np.inf)
        t0 = time.perf_counter()
        for a, sc in enumerate(scenarios):
            for i, s in enumerate(SEEDS):
                cell_sim = Simulator(loss_fn=loss_fn, params0=params0,
                                     cfg=sc.cfg)
                reached = {}

                def stop(m):
                    if m["loss"] <= TAU_LOSS and not reached:
                        reached["bytes"] = m["comm_bytes"]
                    return bool(reached)

                getattr(cell_sim, method)(cell_sim.init(s), batch_fn, STEPS,
                                          eval_every=EVAL_EVERY, stop_fn=stop)
                out[a, i] = reached.get("bytes", np.inf)
        return time.perf_counter() - t0, out

    t_run, run_bytes = sequential("run")
    t_legacy, legacy_bytes = sequential("run_per_round")

    # Output parity: the three engines must find the same crossings. The
    # paths are separately compiled XLA programs, so a cell whose eval loss
    # grazes TAU_LOSS within float rounding may legitimately cross one eval
    # round apart — tolerate a mismatch only there.
    def assert_same_crossings(other):
        diff = sweep_bytes != other
        grazes = np.min(np.abs(loss_at_evals - TAU_LOSS), axis=-1) < 1e-4
        assert np.all(~diff | grazes), (sweep_bytes, other)

    assert_same_crossings(run_bytes)
    assert_same_crossings(legacy_bytes)

    emit("sweep/sequential_run_cells", t_run * 1e6 / cells,
         f"total={t_run:.2f}s (acceptance baseline)")
    emit("sweep/sequential_per_round_cells", t_legacy * 1e6 / cells,
         f"total={t_legacy:.2f}s")
    emit("sweep/fused_engine", t_sweep * 1e6 / cells,
         f"total={t_sweep:.2f}s speedup_vs_run={t_run / t_sweep:.1f}x "
         f"speedup_vs_per_round={t_legacy / t_sweep:.1f}x")
    speedup = t_run / t_sweep
    assert speedup >= 5.0, (
        f"fused sweep only {speedup:.1f}x faster than sequential "
        f"Simulator.run calls (acceptance gate is 5x)")
    return {"run_s": t_run, "per_round_s": t_legacy, "sweep_s": t_sweep,
            "speedup": speedup}


if __name__ == "__main__":
    run()
