"""Global vs local sparsification (paper §3.3): convergence distance after T
rounds as a function of compression ratio, averaged over seeds. Exhibits the
O(1/T)-vs-O(1/sqrt(T)) separation of Theorems 1 and 2 empirically."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        server_round)

D = 64


def _dist(ratio, local, steps, seed):
    n, f = 12, 2
    tg = jax.random.normal(jax.random.PRNGKey(1), (n, D)) * 0.2 + 1.0
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=n, f=f, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio, local=local),
        aggregator=AggregatorConfig(name="cwtm", f=f, pre_nnm=True),
        attack=AttackConfig(name="alie", z=1.5))
    st = init_state(cfg, D)
    th = jnp.zeros(D)
    k = jax.random.PRNGKey(seed)

    @jax.jit
    def one(th, st, k):
        k, sk = jax.random.split(k)
        r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
        return apply_direction(th, r, cfg.gamma), st, k

    for _ in range(steps):
        th, st, k = one(th, st, k)
    return float(jnp.linalg.norm(th - jnp.mean(tg[f:], 0)))


def run():
    out = {}
    for ratio in (0.05, 0.2):
        for local in (False, True):
            t0 = time.perf_counter()
            ds = [_dist(ratio, local, steps=600, seed=s) for s in range(3)]
            wall = (time.perf_counter() - t0) * 1e6
            tag = "local" if local else "global"
            out[(ratio, tag)] = float(np.mean(ds))
            emit(f"glob_vs_local/ratio={ratio}/{tag}", wall,
                 f"dist={np.mean(ds):.4f}+-{np.std(ds):.4f}")
    for ratio in (0.05, 0.2):
        g, l = out[(ratio, "global")], out[(ratio, "local")]
        emit(f"glob_vs_local/ratio={ratio}/advantage", 0.0,
             f"local/global={l / max(g, 1e-9):.2f}x")
    return out


if __name__ == "__main__":
    run()
