"""Roofline report (deliverable g): reads the dry-run JSON and prints the
per-(arch x shape x mesh) three-term roofline table for EXPERIMENTS.md."""

from __future__ import annotations

import json
import os
import sys

from benchmarks.common import emit

DEFAULT = "results/dryrun_final.json"


def load(path: str = DEFAULT):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run(path: str = DEFAULT, markdown: bool = False):
    reports = load(path)
    if reports is None:
        emit("roofline/missing", 0.0, f"run dryrun --all first ({path})")
        return None
    ok = [r for r in reports if r.get("ok")]
    if markdown:
        print("| arch | shape | mesh | compute ms | memory ms | collective "
              "ms | bottleneck | peak GiB/chip | useful FLOPs |")
        print("|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        rf = r["roofline"]
        peak = (r["memory"]["peak_bytes_per_chip"] / 2**30
                if r.get("memory") else float("nan"))
        uf = rf.get("useful_flops_fraction")
        if markdown:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {rf['compute_s']*1e3:.3f} | {rf['memory_s']*1e3:.3f} "
                  f"| {rf['collective_s']*1e3:.3f} | {rf['bottleneck']} "
                  f"| {peak:.2f} | {uf:.3f} |" if uf is not None else "")
        else:
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                 rf["compute_s"] * 1e6,
                 f"mem_us={rf['memory_s']*1e6:.1f} "
                 f"coll_us={rf['collective_s']*1e6:.1f} "
                 f"bottleneck={rf['bottleneck']} peakGiB={peak:.2f} "
                 f"useful={uf if uf is None else round(uf, 3)}")
    bad = [r for r in reports if not r.get("ok")]
    for r in bad:
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0, "FAILED")
    return ok


if __name__ == "__main__":
    run(markdown="--markdown" in sys.argv)
