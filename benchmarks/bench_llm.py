"""Benchmark the streaming LLM-scale rollout pipeline (the ring-buffer PR).

Three gated sections, JSON'd to results/BENCH_llm.json after each one:

  parity_gate      CNN grid, ONE shared pre-stacked batch array feeding
                   both paths: ``rollout_streaming`` must reproduce
                   ``rollout`` bit for bit (max_abs_diff == 0.0 on params,
                   momentum and every per-round metric), and a streamed
                   ``execute_plan`` must return identical result rows.
  host_memory      reduced stablelm_3b through the launch path
                   (make_host_mesh + make_train_plan +
                   build_chunked_train_step): materialising the batch
                   schedule under the host budget must RAISE
                   (``stack_batches``'s guard) while the ChunkPrefetcher
                   run completes the same trajectory with
                   high_water_bytes <= budget — the O(steps) ->
                   O(prefetch_depth) claim, measured.
  early_exit       warmed wall-clock: a tau-crossing streaming run must
                   never be slower than the fixed-length streaming run of
                   the same trajectory (the while-loop skips the remaining
                   chunks' compute AND their transfers).

Run: PYTHONPATH=src:. python -m benchmarks.bench_llm
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, Simulator,
    SparsifierConfig, stack_batches,
)
from repro.core import sweep as SW

# CNN parity grid (kept small: the gate is exactness, not scale)
CNN_WORKERS, CNN_F, CNN_ROUNDS = 9, 2, 24
# quadratic early-exit timing
EE_N, EE_F, EE_D, EE_STEPS, EE_CHUNK = 13, 3, 256, 384, 32
# transformer memory gate
TF_STEPS, TF_CHUNK, TF_DEPTH = 48, 4, 2
TF_BUDGET = 128 * 1024  # host bytes the materialised schedule must exceed


def _parity_gate():
    """Streaming == materialised on the MNIST-CNN grid, bit for bit."""
    from repro.data import SyntheticMNIST
    from repro.models import cnn_init, cnn_loss

    ds = SyntheticMNIST(n_workers=CNN_WORKERS, per_worker=200, seed=0)
    params0 = cnn_init(jax.random.PRNGKey(0))
    # ONE pre-stacked array shared by both paths (BatchFn is stateful, so
    # the stream must not re-pull from it — see execute_plan's docstring)
    batches = stack_batches(ds.worker_batches(32), CNN_ROUNDS)
    out = {}
    worst = 0.0
    for algo, attack in (("rosdhb", "alie"), ("robust_dgd", "signflip"),
                         ("dgd", "alie")):
        agg = "mean" if algo == "dgd" else "cwtm"
        cfg = AlgorithmConfig(
            name=algo, n_workers=CNN_WORKERS, f=CNN_F, gamma=0.05, beta=0.9,
            sparsifier=SparsifierConfig(
                kind="randk", ratio=1.0 if algo == "robust_dgd" else 0.1),
            aggregator=AggregatorConfig(name=agg, f=CNN_F,
                                        pre_nnm=(agg != "mean")),
            attack=AttackConfig(name=attack,
                                z=1.5 if attack == "alie" else None))
        sim = Simulator(loss_fn=cnn_loss, params0=params0, cfg=cfg)
        st_ref, ms_ref = sim.rollout(sim.init(0), batches)
        st_s, ms_s, info = sim.rollout_streaming(
            sim.init(0), batches, chunk_size=8, prefetch_depth=2)
        diff = float(np.max(np.abs(np.asarray(st_s.params_flat)
                                   - np.asarray(st_ref.params_flat))))
        mdiff = max(float(np.max(np.abs(np.asarray(ms_s[k])
                                        - np.asarray(ms_ref[k]))))
                    for k in ms_ref)
        worst = max(worst, diff, mdiff)
        key = f"{algo}/{attack}"
        out[key] = {"rounds": info["rounds_run"], "max_abs_diff": diff,
                    "metric_max_abs_diff": mdiff, "exact": diff == 0.0,
                    "dispatches": info["dispatches"]}
        emit(f"llm/parity/{key}", 0.0,
             f"max_abs_diff={diff} dispatches={info['dispatches']}")
        assert diff == 0.0 and mdiff == 0.0, \
            f"streaming parity broken for {key}: {diff} / {mdiff}"

    # the fused grid path must stream to the same rows
    scen = SW.grid_scenarios(["rosdhb", "dgd"], ["alie"], ["cwtm"],
                             n_honest=CNN_WORKERS - CNN_F, f=CNN_F, ratio=0.1)
    plan = SW.plan_grid(scen)
    ref_rows = SW.execute_plan(plan, loss_fn=cnn_loss, params0=params0,
                               batches=batches, seeds=[0], shard=False)
    got_rows = SW.execute_plan(plan, loss_fn=cnn_loss, params0=params0,
                               batches=batches, seeds=[0], shard=False,
                               streaming=True, stream_chunk_size=8,
                               prefetch_depth=2)
    rows_equal = ref_rows == got_rows
    emit("llm/parity/execute_plan", 0.0, f"rows_equal={rows_equal}")
    assert rows_equal, "streamed execute_plan rows differ"
    out["execute_plan_rows_equal"] = rows_equal
    out["max_abs_diff"] = worst
    return out


def _host_memory_gate():
    """Reduced stablelm_3b via the launch path: the O(steps) materialisation
    refuses the budget, the stream completes under it."""
    from repro.configs import get_arch
    from repro.configs.base import ArchSpec, InputShape
    from repro.core import algorithms as alg
    from repro.data import ChunkPrefetcher
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (TrainState, build_chunked_train_step,
                                    make_train_plan)
    from repro.models import model_init

    spec = get_arch("stablelm_3b")
    spec = ArchSpec(model=spec.model.reduced(n_layers=2, d_model=256)
                    .with_overrides(vocab_size=512),
                    citation=spec.citation)
    mesh = make_host_mesh()
    shape = InputShape("host_train", 128, 16, "train")
    overrides = {
        "name": "rosdhb", "gamma": 1e-3, "f": 2,
        "sparsifier": SparsifierConfig(kind="block", ratio=0.05,
                                       block_size=512),
        "aggregator": AggregatorConfig(name="cwtm", f=2),
        "attack": AttackConfig(name="alie"),
    }
    plan = make_train_plan(spec, shape, mesh, overrides, n_workers=8)
    cfg = plan.model
    lb = shape.global_batch // plan.n_workers

    def batch_fn(t):
        gen = np.random.default_rng((0, int(t)))
        toks = gen.integers(0, cfg.vocab_size,
                            (plan.n_workers, lb, shape.seq_len))
        toks[..., 1::2] = (toks[..., 0::2] + 1) % cfg.vocab_size
        return {"tokens": np.asarray(toks, np.int32)}

    # the materialised path must refuse this budget...
    try:
        stack_batches(batch_fn, TF_STEPS, max_bytes=TF_BUDGET)
        raised = False
    except ValueError as e:
        raised = True
        assert "rollout_streaming" in str(e)
    est_bytes = TF_STEPS * int(sum(
        np.asarray(v).nbytes for v in batch_fn(0).values()))
    emit("llm/host_memory/stack_refused", 0.0,
         f"raised={raised} est={est_bytes} budget={TF_BUDGET}")
    assert raised, (
        f"stack_batches fit {est_bytes} B under {TF_BUDGET} B — grow "
        "TF_STEPS so the materialised schedule exceeds the budget")

    # ...while the stream finishes the SAME schedule inside it
    with mesh:
        params = model_init(jax.random.PRNGKey(0), cfg)
        state = TrainState(
            params=params,
            server=alg.init_state(plan.algo, plan.flat_spec.padded_size),
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(1))
        chunk_step = jax.jit(build_chunked_train_step(plan, mesh, TF_CHUNK))
        t0 = time.perf_counter()
        steps_run = 0
        with ChunkPrefetcher(batch_fn, TF_STEPS, TF_CHUNK, TF_DEPTH) as pf:
            while True:
                chunks = pf.take(1)
                if not chunks:
                    break
                state, metrics = chunk_step(state, chunks[0])
                steps_run += TF_CHUNK
            jax.block_until_ready(state.params)
            high_water = pf.high_water_bytes
            chunk_bytes = pf.chunk_bytes
        elapsed = time.perf_counter() - t0
        final_loss = float(metrics["loss"][-1])

    rounds_per_s = steps_run / elapsed
    emit("llm/host_memory/stream", elapsed * 1e6 / steps_run,
         f"high_water={high_water} budget={TF_BUDGET} "
         f"rounds/s={rounds_per_s:.2f} loss={final_loss:.3f}")
    assert steps_run == TF_STEPS
    assert 0 < high_water <= TF_BUDGET, \
        f"stream breached the host budget: {high_water} > {TF_BUDGET}"
    assert np.isfinite(final_loss)
    return {
        "model": cfg.name, "d": int(plan.flat_spec.padded_size),
        "n_workers": plan.n_workers, "steps": steps_run,
        "chunk_size": TF_CHUNK, "prefetch_depth": TF_DEPTH,
        "materialised_est_bytes": est_bytes, "budget_bytes": TF_BUDGET,
        "stack_batches_raised": raised,
        "stream_high_water_bytes": int(high_water),
        "chunk_bytes": int(chunk_bytes),
        "rounds_per_sec": rounds_per_s, "final_loss": final_loss,
    }


def _early_exit_gate():
    """tau-crossing streaming run vs fixed-length streaming run, warmed."""
    loss_fn, params0, batch_fn, _ = SW.quadratic_testbed(EE_N, EE_D)
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=EE_N, f=EE_F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.2),
        aggregator=AggregatorConfig(name="cwtm", f=EE_F, pre_nnm=True),
        attack=AttackConfig(name="alie", z=1.5))
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
    batches = stack_batches(batch_fn, EE_STEPS)
    _, ms_ref = sim.rollout(sim.init(0), batches)
    loss_ref = np.asarray(ms_ref["loss"])
    tau = float(loss_ref[EE_STEPS // 4])  # crossed a quarter of the way in

    def run(tau_):
        t0 = time.perf_counter()
        _, _, info = sim.rollout_streaming(
            sim.init(0), batches, chunk_size=EE_CHUNK, prefetch_depth=4,
            tau=tau_, tau_metric="loss", tau_mode="<=")
        return time.perf_counter() - t0, info

    run(tau)          # warm both branches of the shared compiled program
    run(None)
    t_early = min(run(tau)[0] for _ in range(3))
    t_full = min(run(None)[0] for _ in range(3))
    _, info = run(tau)
    speedup = t_full / t_early
    emit("llm/early_exit", t_early * 1e6,
         f"rounds={info['rounds_run']}/{EE_STEPS} "
         f"full={t_full * 1e6:.0f}us speedup={speedup:.2f}x")
    assert info["early_exit"] and info["rounds_run"] < EE_STEPS
    assert t_early <= t_full * 1.05, (
        f"early exit slower than fixed length: {t_early:.4f}s vs "
        f"{t_full:.4f}s")
    return {
        "steps": EE_STEPS, "chunk_size": EE_CHUNK, "tau": tau,
        "rounds_at_exit": info["rounds_run"],
        "early_s": t_early, "full_s": t_full, "speedup": speedup,
    }


def run(out: str = "results/BENCH_llm.json",
        out_root: str = "BENCH_llm.json"):
    jnp.zeros(1).block_until_ready()  # backend init outside all timings

    # rewrite the JSON after every section so a failed gate still leaves
    # partial results behind (CI uploads with if: always())
    results = {}

    def record(name, fn):
        try:
            results[name] = fn()
        finally:
            for path in (out, out_root):
                if path:
                    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                    with open(path, "w") as fh:
                        json.dump(results, fh, indent=2)

    record("parity_gate", _parity_gate)
    record("host_memory", _host_memory_gate)
    record("early_exit", _early_exit_gate)
    return results


if __name__ == "__main__":
    run()
