"""Benchmark harness — one module per paper table/figure.

  fig1            paper Figure 1: comm cost to tau vs compression ratio (ALIE)
  table1          paper Table 1: RoSDHB vs Byz-DASHA-PAGE vs corner baselines
  global_vs_local paper §3.3: coordinated vs uncoordinated sparsification
  sweep           batched grid engine vs sequential Simulator runs (5x gate)
  aggregators     (f,kappa)-robust rule microbench
  kernels         kernel oracle microbench
  roofline        per-(arch x shape x mesh) roofline from the dry-run JSON

Every measurement prints one CSV line: ``name,us_per_call,derived``.
``python -m benchmarks.run [--full] [--only NAME]``
"""

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]

    from benchmarks import (bench_aggregators, bench_breakdown, bench_fig1,
                            bench_global_vs_local, bench_kernels,
                            bench_momentum, bench_roofline, bench_sweep,
                            bench_table1)
    suites = {
        "aggregators": lambda: bench_aggregators.run(),
        "kernels": lambda: bench_kernels.run(),
        "table1": lambda: bench_table1.run(),
        "momentum": lambda: bench_momentum.run(),
        "sweep": lambda: bench_sweep.run(),
        "breakdown": lambda: bench_breakdown.run(),
        "global_vs_local": lambda: bench_global_vs_local.run(),
        "fig1": lambda: bench_fig1.run(full=full,
                                       out="results/fig1_quick.json"),
        "roofline": lambda: bench_roofline.run(),
    }
    t0 = time.time()
    for name, fn in suites.items():
        if only and name != only:
            continue
        print(f"# --- {name} ---")
        fn()
    print(f"# total wall: {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
