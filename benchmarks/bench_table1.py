"""Table 1: convergence comparison of RoSDHB vs Byz-DASHA-PAGE vs the two
corner baselines (robust-DGD without compression, compressed DGD without
robustness), on the controlled quadratic testbed where the honest optimum is
known exactly. Reports E||grad||^2-style distance after T rounds under ALIE.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        server_round)

D = 64


def _distance(name, ratio, f, gamma, steps=800, seed=3, attack="alie"):
    n = 10 + f
    tg = jax.random.normal(jax.random.PRNGKey(0), (n, D)) * 0.1 + 1.0
    cfg = AlgorithmConfig(
        name=name, n_workers=n, f=f, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=(AggregatorConfig(name="mean") if name == "dgd"
                    else AggregatorConfig(name="cwtm", f=max(f, 1),
                                          pre_nnm=True)),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))
    st = init_state(cfg, D)
    th = jnp.zeros(D)
    k = jax.random.PRNGKey(seed)

    @jax.jit
    def one(th, st, k):
        k, sk = jax.random.split(k)
        r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
        return apply_direction(th, r, cfg.gamma), st, k

    for _ in range(steps):
        th, st, k = one(th, st, k)
    grad_sq = float(jnp.sum(jnp.square(th - jnp.mean(tg[f:], 0))))
    return grad_sq


def run():
    f = 3
    cells = [
        ("rosdhb", 0.1, 0.05),
        ("rosdhb-local", 0.1, 0.05),
        ("dasha", 0.1, 0.02),
        ("robust_dgd", 1.0, 0.1),
        ("dgd", 0.1, 0.05),
    ]
    results = {}
    for name, ratio, gamma in cells:
        algo = "rosdhb" if name.startswith("rosdhb") else name
        local = name.endswith("local")
        t0 = time.perf_counter()
        n = 10 + f
        tg = jax.random.normal(jax.random.PRNGKey(0), (n, D)) * 0.1 + 1.0
        cfg = AlgorithmConfig(
            name=algo, n_workers=n, f=f, gamma=gamma, beta=0.9,
            sparsifier=SparsifierConfig(kind="randk", ratio=ratio,
                                        local=local),
            aggregator=(AggregatorConfig(name="mean") if algo == "dgd"
                        else AggregatorConfig(name="cwtm", f=f,
                                              pre_nnm=True)),
            attack=AttackConfig(name="alie", z=1.5))
        st = init_state(cfg, D)
        th = jnp.zeros(D)
        k = jax.random.PRNGKey(3)

        @jax.jit
        def one(th, st, k, cfg=cfg, tg=tg):
            k, sk = jax.random.split(k)
            r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
            return apply_direction(th, r, cfg.gamma), st, k

        for _ in range(800):
            th, st, k = one(th, st, k)
        grad_sq = float(jnp.sum(jnp.square(th - jnp.mean(tg[f:], 0))))
        wall = (time.perf_counter() - t0) * 1e6
        results[name] = grad_sq
        emit(f"table1/{name}/alie_f{f}", wall, f"dist_sq={grad_sq:.4g}")
    # headline orderings from the paper's theory:
    #   global sparsification beats local (Thm 1 vs Thm 2)
    assert results["rosdhb"] <= results["rosdhb-local"] * 2.0
    return results


if __name__ == "__main__":
    run()
