"""Table 1: convergence comparison of RoSDHB vs Byz-DASHA-PAGE vs the two
corner baselines (robust-DGD without compression, compressed DGD without
robustness), on the controlled quadratic testbed where the honest optimum is
known exactly. Reports E||grad||^2-style distance after T rounds under ALIE.

Runs on the batched engine: each cell is ONE jitted lax.scan trajectory
(``rollout_over_seeds``) instead of 800 per-round dispatches; the math and
PRNG stream are identical to the legacy loop (tests/test_engine.py).
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        Simulator, SparsifierConfig, quadratic_testbed,
                        rollout_over_seeds)

D = 64
STEPS = 800
SEED = 3


def run():
    f = 3
    n = 10 + f
    loss_fn, params0, batch_fn, tg = quadratic_testbed(n, D, spread=0.1,
                                                       seed=0)
    honest_opt = jnp.mean(tg[f:], axis=0)
    cells = [
        ("rosdhb", 0.1, 0.05),
        ("rosdhb-local", 0.1, 0.05),
        ("dasha", 0.1, 0.02),
        ("robust_dgd", 1.0, 0.1),
        ("dgd", 0.1, 0.05),
    ]
    results = {}
    for name, ratio, gamma in cells:
        algo = "rosdhb" if name.startswith("rosdhb") else name
        local = name.endswith("local")
        t0 = time.perf_counter()
        cfg = AlgorithmConfig(
            name=algo, n_workers=n, f=f, gamma=gamma, beta=0.9,
            sparsifier=SparsifierConfig(kind="randk", ratio=ratio,
                                        local=local),
            aggregator=(AggregatorConfig(name="mean") if algo == "dgd"
                        else AggregatorConfig(name="cwtm", f=f,
                                              pre_nnm=True)),
            attack=AttackConfig(name="alie", z=1.5))
        sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
        states, _ = rollout_over_seeds(sim, [SEED], batch_fn, steps=STEPS)
        th = states.params_flat[0, :D]
        grad_sq = float(jnp.sum(jnp.square(th - honest_opt)))
        wall = (time.perf_counter() - t0) * 1e6
        results[name] = grad_sq
        emit(f"table1/{name}/alie_f{f}", wall, f"dist_sq={grad_sq:.4g}")
    # headline orderings from the paper's theory:
    #   global sparsification beats local (Thm 1 vs Thm 2)
    assert results["rosdhb"] <= results["rosdhb-local"] * 2.0
    return results


if __name__ == "__main__":
    run()
