"""Momentum-mechanism ablation — the paper's central claim isolated.

The paper's contribution is that *Polyak momentum is what reconciles
sparsification noise with Byzantine robustness* (its variance scales with
the gradient norm, and the heavy-ball average damps it before the robust
aggregator sees it). This bench sweeps beta with everything else fixed
(RandK 0.1, ALIE f=3, CWTM+NNM): beta=0 is robust compressed DGD (no
momentum), which the paper's Lemma A.4/A.5 predicts to be strictly worse.

Runs on the batched engine: per beta, all three seeds execute in one
vmapped lax.scan (``rollout_over_seeds``) instead of 3 x 800 per-round
dispatches.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        Simulator, SparsifierConfig, quadratic_testbed,
                        rollout_over_seeds)

D = 64
STEPS = 800
SEEDS = (0, 1, 2)


def run():
    n, f = 13, 3
    loss_fn, params0, batch_fn, tg = quadratic_testbed(n, D, spread=0.2,
                                                       seed=0)
    honest_opt = np.asarray(jnp.mean(tg[f:], axis=0))
    out = {}
    for beta in (0.0, 0.5, 0.9, 0.99):
        t0 = time.perf_counter()
        cfg = AlgorithmConfig(
            name="rosdhb", n_workers=n, f=f, gamma=0.05, beta=beta,
            sparsifier=SparsifierConfig(kind="randk", ratio=0.1),
            aggregator=AggregatorConfig(name="cwtm", f=f, pre_nnm=True),
            attack=AttackConfig(name="alie", z=1.5))
        sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
        states, _ = rollout_over_seeds(sim, SEEDS, batch_fn, steps=STEPS)
        ds = np.linalg.norm(np.asarray(states.params_flat)[:, :D]
                            - honest_opt, axis=1)
        out[beta] = float(np.mean(ds))
        emit(f"momentum/beta={beta}", (time.perf_counter() - t0) * 1e6,
             f"dist={np.mean(ds):.4f}+-{np.std(ds):.4f}")
    # the paper's mechanism: momentum strictly improves on no-momentum
    emit("momentum/mechanism", 0.0,
         f"no_momentum/best={out[0.0] / max(min(out.values()), 1e-9):.2f}x")
    return out


if __name__ == "__main__":
    run()
