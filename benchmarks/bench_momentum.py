"""Momentum-mechanism ablation — the paper's central claim isolated.

The paper's contribution is that *Polyak momentum is what reconciles
sparsification noise with Byzantine robustness* (its variance scales with
the gradient norm, and the heavy-ball average damps it before the robust
aggregator sees it). This bench sweeps beta with everything else fixed
(RandK 0.1, ALIE f=3, CWTM+NNM): beta=0 is robust compressed DGD (no
momentum), which the paper's Lemma A.4/A.5 predicts to be strictly worse.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        server_round)

D = 64


def _dist(beta, seed, steps=800):
    n, f = 13, 3
    tg = jax.random.normal(jax.random.PRNGKey(0), (n, D)) * 0.2 + 1.0
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=n, f=f, gamma=0.05, beta=beta,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.1),
        aggregator=AggregatorConfig(name="cwtm", f=f, pre_nnm=True),
        attack=AttackConfig(name="alie", z=1.5))
    st = init_state(cfg, D)
    th = jnp.zeros(D)
    k = jax.random.PRNGKey(seed)

    @jax.jit
    def one(th, st, k):
        k, sk = jax.random.split(k)
        r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
        return apply_direction(th, r, cfg.gamma), st, k

    for _ in range(steps):
        th, st, k = one(th, st, k)
    return float(jnp.linalg.norm(th - jnp.mean(tg[f:], 0)))


def run():
    import numpy as np
    out = {}
    for beta in (0.0, 0.5, 0.9, 0.99):
        t0 = time.perf_counter()
        ds = [_dist(beta, s) for s in range(3)]
        out[beta] = float(np.mean(ds))
        emit(f"momentum/beta={beta}", (time.perf_counter() - t0) * 1e6,
             f"dist={np.mean(ds):.4f}+-{np.std(ds):.4f}")
    # the paper's mechanism: momentum strictly improves on no-momentum
    emit("momentum/mechanism", 0.0,
         f"no_momentum/best={out[0.0] / max(min(out.values()), 1e-9):.2f}x")
    return out


if __name__ == "__main__":
    run()
