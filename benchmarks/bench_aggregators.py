"""Aggregator microbenchmarks: wall time of each (f,kappa)-robust rule on a
server-scale bank [n=20, d=1e6] (XLA CPU timing; the TPU hot loop is the
cwtm Pallas kernel, validated in interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import AggregatorConfig, make_aggregator


def run(d: int = 1_000_000, n: int = 20, f: int = 4):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)
    for name in ["mean", "cwtm", "median", "geomed", "krum"]:
        cfg = AggregatorConfig(name=name, f=f)
        agg = jax.jit(make_aggregator(cfg))
        us = time_fn(agg, x, iters=5)
        gbps = (x.size * 4 / (us / 1e6)) / 1e9
        emit(f"aggregators/{name}/n{n}_d{d}", us,
             f"GB/s={gbps:.2f} kappa<={cfg.kappa_bound(n):.3f}")
    # NNM-composed variant (the optimal-kappa configuration)
    cfg = AggregatorConfig(name="cwtm", f=f, pre_nnm=True)
    agg = jax.jit(make_aggregator(cfg))
    us = time_fn(agg, x, iters=3)
    emit(f"aggregators/cwtm+nnm/n{n}_d{d}", us,
         f"kappa<={cfg.kappa_bound(n):.3f}")


if __name__ == "__main__":
    run()
