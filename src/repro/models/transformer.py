"""Generic decoder stack covering all six assigned families.

Layer patterns (see DESIGN §4):
  dense / audio : L x (attn + mlp)
  moe           : first_k_dense x (attn + mlp) then (L-F) x (attn|mla + moe)
  ssm           : L x mamba2
  hybrid        : G x (attn_every x mamba2 + ONE weight-shared attn block)
                  + (L mod attn_every) trailing mamba2 layers   (Zamba2)
  vlm           : G x ((cross_attn_every-1) x self + 1 x cross-attn layer)

All homogeneous runs of layers are ``lax.scan`` over stacked parameters so
the compiled HLO contains each distinct block body once — essential for the
40x2 dry-run matrix (88-layer 123B models compile in seconds). Every scan
body is rematerialised (``jax.checkpoint``) in train mode.

Caches are pytrees stacked exactly like the parameters that own them.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.sharding.partitioning import constrain_activation

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# single blocks
# --------------------------------------------------------------------------


def _attn_block_init(key, cfg, use_moe: bool = False) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": L.norm_init(cfg.d_model, cfg.norm),
         "norm2": L.norm_init(cfg.d_model, cfg.norm)}
    if cfg.use_mla:
        p["attn"] = MLA.mla_init(k1, cfg)
    else:
        p["attn"] = L.attn_init(k1, cfg)
    if use_moe:
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def _attn_block_apply(p: Params, cfg, x, *, mode, pos, cache,
                      ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    h = L.norm_apply(p["norm1"], x, cfg.norm)
    if cfg.use_mla:
        a, new_cache = MLA.mla_apply(p["attn"], cfg, h, mode=mode, pos=pos,
                                     cache=cache)
    else:
        a, new_cache = L.attn_apply(p["attn"], cfg, h, mode=mode, pos=pos,
                                    cache=cache)
    x = x + a
    h = L.norm_apply(p["norm2"], x, cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        m, aux = MOE.moe_apply(p["moe"], cfg, h)
    else:
        m = L.mlp_apply(p["mlp"], h, cfg.mlp)
    return x + m, new_cache, aux


def _cross_block_init(key, cfg) -> Params:
    k1, k2 = jax.random.split(key)
    return {"norm1": L.norm_init(cfg.d_model, cfg.norm),
            "norm2": L.norm_init(cfg.d_model, cfg.norm),
            "attn": L.attn_init(k1, cfg),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp),
            "gate": jnp.zeros((), jnp.float32)}  # tanh-gated, llama-3.2 style


def _cross_block_apply(p: Params, cfg, x, kv_x) -> jnp.ndarray:
    h = L.norm_apply(p["norm1"], x, cfg.norm)
    a, _ = L.attn_apply(p["attn"], cfg, h, mode="train", kv_x=kv_x)
    x = x + jnp.tanh(p["gate"]).astype(x.dtype) * a
    h = L.norm_apply(p["norm2"], x, cfg.norm)
    return x + L.mlp_apply(p["mlp"], h, cfg.mlp)


def _ssm_block_init(key, cfg) -> Params:
    return {"norm": L.norm_init(cfg.d_model, cfg.norm),
            "ssm": SSM.ssm_init(key, cfg)}


def _ssm_block_apply(p: Params, cfg, x, *, mode, cache):
    h = L.norm_apply(p["norm"], x, cfg.norm)
    y, new_cache = SSM.ssm_apply(p["ssm"], cfg, h, mode=mode, cache=cache)
    return x + y, new_cache, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# stacked init
# --------------------------------------------------------------------------


def _stacked(init_fn, key, n: int) -> Params:
    if n == 0:
        return None
    return jax.vmap(init_fn)(jax.random.split(key, n))


def model_init(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {}
    if cfg.input_kind == "tokens":
        p["embed"] = jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
    p["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        p["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02

    fam = cfg.family
    if fam in ("dense", "audio"):
        p["blocks"] = _stacked(lambda k: _attn_block_init(k, cfg), keys[2],
                               cfg.n_layers)
    elif fam == "moe":
        fk = cfg.first_k_dense
        p["dense_blocks"] = _stacked(lambda k: _attn_block_init(k, cfg),
                                     keys[2], fk)
        p["blocks"] = _stacked(
            lambda k: _attn_block_init(k, cfg, use_moe=True), keys[3],
            cfg.n_layers - fk)
    elif fam == "ssm":
        p["blocks"] = _stacked(lambda k: _ssm_block_init(k, cfg), keys[2],
                               cfg.n_layers)
    elif fam == "hybrid":
        ae = cfg.attn_every
        g = cfg.n_layers // ae
        rem = cfg.n_layers - g * ae
        grouped = _stacked(lambda k: _ssm_block_init(k, cfg), keys[2], g * ae)
        p["blocks"] = jax.tree_util.tree_map(
            lambda a: a.reshape((g, ae) + a.shape[1:]), grouped)
        p["tail_blocks"] = _stacked(lambda k: _ssm_block_init(k, cfg),
                                    keys[3], rem)
        p["shared_attn"] = _attn_block_init(keys[4], cfg)
    elif fam == "vlm":
        cae = cfg.cross_attn_every
        g = cfg.n_layers // cae
        per = cae - 1
        rem = cfg.n_layers - g * cae
        grouped = _stacked(lambda k: _attn_block_init(k, cfg), keys[2],
                           g * per)
        p["blocks"] = jax.tree_util.tree_map(
            lambda a: a.reshape((g, per) + a.shape[1:]), grouped)
        p["cross_blocks"] = _stacked(lambda k: _cross_block_init(k, cfg),
                                     keys[3], g)
        p["tail_blocks"] = _stacked(lambda k: _attn_block_init(k, cfg),
                                    keys[4], rem)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return p


# --------------------------------------------------------------------------
# cache init
# --------------------------------------------------------------------------


def _block_cache_init(cfg, batch: int, max_len: int, dtype, kind: str):
    if kind == "ssm":
        return SSM.ssm_cache_init(cfg, batch, dtype)
    if cfg.use_mla and kind == "attn":
        return MLA.mla_cache_init(cfg, batch, max_len, dtype)
    return L.attn_cache_init(cfg, batch, max_len, dtype)


def _stack_caches(make_one, n: int):
    if n == 0:
        return None
    one = make_one()
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy()
        if hasattr(a, "shape") else a, one)


def cache_init(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict:
    """Build the full stacked cache pytree for decode."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    fam = cfg.family
    c: Dict[str, Any] = {}
    if fam in ("dense", "audio", "vlm"):
        attn_c = lambda: _block_cache_init(cfg, batch, max_len, dtype, "attn")  # noqa: E731
        if fam == "vlm":
            cae = cfg.cross_attn_every
            g = cfg.n_layers // cae
            per = cae - 1
            rem = cfg.n_layers - g * cae
            grouped = _stack_caches(attn_c, g * per)
            c["blocks"] = jax.tree_util.tree_map(
                lambda a: a.reshape((g, per) + a.shape[1:]), grouped)
            c["tail_blocks"] = _stack_caches(attn_c, rem)
        else:
            c["blocks"] = _stack_caches(attn_c, cfg.n_layers)
    elif fam == "moe":
        attn_c = lambda: _block_cache_init(cfg, batch, max_len, dtype, "attn")  # noqa: E731
        c["dense_blocks"] = _stack_caches(attn_c, cfg.first_k_dense)
        c["blocks"] = _stack_caches(attn_c, cfg.n_layers - cfg.first_k_dense)
    elif fam == "ssm":
        ssm_c = lambda: _block_cache_init(cfg, batch, max_len, dtype, "ssm")  # noqa: E731
        c["blocks"] = _stack_caches(ssm_c, cfg.n_layers)
    elif fam == "hybrid":
        ae = cfg.attn_every
        g = cfg.n_layers // ae
        rem = cfg.n_layers - g * ae
        ssm_c = lambda: _block_cache_init(cfg, batch, max_len, dtype, "ssm")  # noqa: E731
        attn_c = lambda: L.attn_cache_init(cfg, batch, max_len, dtype)  # noqa: E731
        grouped = _stack_caches(ssm_c, g * ae)
        c["blocks"] = jax.tree_util.tree_map(
            lambda a: a.reshape((g, ae) + a.shape[1:]), grouped)
        c["shared_attn"] = _stack_caches(attn_c, g)
        c["tail_blocks"] = _stack_caches(ssm_c, rem)
    return c


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _scan_layers(apply_one, stacked_params, x, caches, *, remat: bool):
    """Scan ``apply_one(p, x, cache) -> (x, new_cache, aux)`` over layer dim 0
    of ``stacked_params`` (and ``caches`` if given)."""
    if stacked_params is None:
        return x, caches, jnp.zeros((), jnp.float32)

    has_cache = caches is not None

    def body(carry, inp):
        xx = carry
        if has_cache:
            pp, cc = inp
        else:
            pp, cc = inp, None
        y, new_c, aux = apply_one(pp, xx, cc)
        y = constrain_activation(y)
        return y, (new_c, aux) if has_cache else aux

    if remat:
        body = jax.checkpoint(body)
    xs = (stacked_params, caches) if has_cache else stacked_params
    x, out = jax.lax.scan(body, x, xs)
    if has_cache:
        new_caches, auxs = out
    else:
        new_caches, auxs = None, out
    return x, new_caches, jnp.sum(auxs)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, mode: str = "train", pos=0, caches: Optional[Dict] = None,
            remat: Optional[bool] = None) -> Tuple[jnp.ndarray, Any, Any]:
    """Run the decoder stack.

    batch: {"tokens": [B,S] int32} or {"embeddings": [B,S,D]}; VLMs add
    {"image_embeddings": [B,T_img,D]}.

    Returns (hidden [B,S,D], new_caches, aux dict with 'moe_loss').
    """
    dtype = jnp.dtype(cfg.dtype)
    remat = (mode == "train") if remat is None else remat
    if mode in ("prefill", "decode"):
        assert caches is not None, f"{mode} requires preallocated caches"
    if cfg.input_kind == "tokens":
        x = params["embed"].astype(dtype)[batch["tokens"]]
        if cfg.family == "dense" and cfg.tie_embeddings:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(dtype)
    else:
        x = batch["embeddings"].astype(dtype)
    x = constrain_activation(x)
    kv_img = batch.get("image_embeddings")
    if kv_img is not None:
        kv_img = kv_img.astype(dtype)

    fam = cfg.family
    moe_loss = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    cc = caches or {}

    if fam in ("dense", "audio", "moe"):
        def one(p, xx, c, use_moe=False):
            return _attn_block_apply(p, cfg, xx, mode=mode, pos=pos, cache=c)
        if fam == "moe" and params.get("dense_blocks") is not None:
            x, nc, a = _scan_layers(one, params["dense_blocks"], x,
                                    cc.get("dense_blocks"), remat=remat)
            new_caches["dense_blocks"] = nc
            moe_loss += a
        x, nc, a = _scan_layers(one, params["blocks"], x, cc.get("blocks"),
                                remat=remat)
        new_caches["blocks"] = nc
        moe_loss += a

    elif fam == "ssm":
        def one(p, xx, c):
            return _ssm_block_apply(p, cfg, xx, mode=mode, cache=c)
        x, nc, _ = _scan_layers(one, params["blocks"], x, cc.get("blocks"),
                                remat=remat)
        new_caches["blocks"] = nc

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def ssm_one(p, xx, c):
            return _ssm_block_apply(p, cfg, xx, mode=mode, cache=c)

        def group(carry, inp):
            xx = carry
            if cc:
                gp, gcache, scache = inp
            else:
                gp, gcache, scache = inp, None, None
            xx, ncache, _ = _scan_layers(ssm_one, gp, xx, gcache, remat=remat)
            xx, nshared, _ = _attn_block_apply(shared, cfg, xx, mode=mode,
                                               pos=pos, cache=scache)
            out = (ncache, nshared) if cc else None
            return xx, out

        gbody = jax.checkpoint(group) if remat else group
        xs = ((params["blocks"], cc["blocks"], cc["shared_attn"])
              if cc else params["blocks"])
        x, gout = jax.lax.scan(gbody, x, xs)
        if cc:
            new_caches["blocks"], new_caches["shared_attn"] = gout
        x, nc, _ = _scan_layers(ssm_one, params.get("tail_blocks"), x,
                                cc.get("tail_blocks"), remat=remat)
        new_caches["tail_blocks"] = nc

    elif fam == "vlm":
        def self_one(p, xx, c):
            return _attn_block_apply(p, cfg, xx, mode=mode, pos=pos, cache=c)

        def group(carry, inp):
            xx = carry
            if cc:
                sp, xp, scache = inp
            else:
                (sp, xp), scache = inp, None
            xx, ncache, _ = _scan_layers(self_one, sp, xx, scache,
                                         remat=remat)
            xx = _cross_block_apply(xp, cfg, xx, kv_img)
            return xx, ncache

        gbody = jax.checkpoint(group) if remat else group
        xs = ((params["blocks"], params["cross_blocks"], cc["blocks"])
              if cc else (params["blocks"], params["cross_blocks"]))
        x, gout = jax.lax.scan(gbody, x, xs)
        if cc:
            new_caches["blocks"] = gout
        x, nc, _ = _scan_layers(self_one, params.get("tail_blocks"), x,
                                cc.get("tail_blocks"), remat=remat)
        new_caches["tail_blocks"] = nc

    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    return x, (new_caches if caches is not None else None), \
        {"moe_loss": moe_loss}


# --------------------------------------------------------------------------
# heads & losses
# --------------------------------------------------------------------------


def logits_fn(params: Params, cfg: ModelConfig,
              hidden: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings and "embed" in params:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return hidden @ w.astype(hidden.dtype)


def chunked_xent(params: Params, cfg: ModelConfig, hidden: jnp.ndarray,
                 targets: jnp.ndarray, loss_mask: Optional[jnp.ndarray] = None,
                 chunk: int = 512) -> jnp.ndarray:
    """Next-token cross entropy with the LM head applied per sequence chunk,
    so [B, S, V] logits never materialise at 150k-256k vocabularies."""
    b, s, d = hidden.shape
    if loss_mask is None:
        loss_mask = jnp.ones((b, s), jnp.float32)
    if s <= chunk:
        logits = logits_fn(params, cfg, hidden).astype(jnp.float32)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, targets[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)

    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(loss_mask, ((0, 0), (0, pad)))
        s += pad
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = loss_mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def per_chunk(args):
        h, t, m = args
        logits = logits_fn(params, cfg, h).astype(jnp.float32)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, t[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * m)

    tot = jnp.sum(jax.lax.map(per_chunk, (hs, ts, ms)))
    return tot / jnp.maximum(jnp.sum(loss_mask), 1.0)


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            moe_loss_weight: float = 0.01) -> jnp.ndarray:
    """Standard causal-LM training loss over ``batch['tokens']`` (shifted),
    or over provided ``batch['targets']`` for embedding-input models."""
    hidden, _, aux = forward(params, cfg, batch, mode="train")
    if "targets" in batch:
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        hidden_in = hidden
    else:
        targets = batch["tokens"][:, 1:]
        hidden_in = hidden[:, :-1]
        mask = None
    loss = chunked_xent(params, cfg, hidden_in, targets, mask)
    return loss + moe_loss_weight * aux["moe_loss"]
