"""Unified model configuration covering all six assigned arch families."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config class for dense / moe / ssm / hybrid / vlm / audio decoders.

    Only the fields relevant to a family are consumed by the builder; see
    ``repro/models/transformer.py`` for the layer-pattern semantics
    (``attn_every`` for hybrids, ``cross_attn_every`` for VLMs,
    ``first_k_dense`` for MoE stacks).
    """

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # None = full causal attention

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0  # leading dense layers in an MoE stack (DeepSeek)

    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_n_groups: int = 1

    # --- hybrid (Zamba2): one weight-shared attention block applied after
    #     every ``attn_every`` mamba layers ---
    attn_every: int = 0

    # --- VLM (Llama-3.2-Vision): every ``cross_attn_every``-th layer is a
    #     cross-attention layer over stub image embeddings ---
    cross_attn_every: int = 0
    n_image_tokens: int = 1024

    # --- input modality: "tokens" (ids) or "embeddings" (audio stub) ---
    input_kind: str = "tokens"

    # --- attention backend: None auto-selects the Pallas flash-attention
    #     kernel on TPU (jnp fallback elsewhere); True forces the kernel
    #     (interpret mode off-TPU — parity testing); False forces the
    #     chunked-XLA path. Train-mode self-attention only; decode/prefill
    #     cache paths always use the XLA formulation. ---
    use_flash_attention: Optional[bool] = None

    dtype: str = "bfloat16"

    # ----------------------------------------------------------------- #

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if the arch can decode at 500k+ context: SSM/hybrid natively,
        attention archs via a sliding window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant of the same family (assignment: <=2 layers,
        d_model <= 512, <= 4 experts)."""
        hd = 64
        n_heads = max(2, d_model // 128)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        if self.n_kv_heads == self.n_heads:
            n_kv = n_heads
        kw = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=2 * d_model,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, max_experts),
                      top_k=min(self.top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_k_dense=min(self.first_k_dense, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=64, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                      ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=1, n_layers=2)
        if self.cross_attn_every:
            kw.update(cross_attn_every=2, n_layers=2, n_image_tokens=16)
        return dataclasses.replace(self, **kw)
