"""The paper's Section-4 model: a small CNN (~11.8k parameters) for 10-class
28x28 grayscale image classification (MNIST-scale)."""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp


def cnn_init(key, n_classes: int = 10) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv(k, h, w, cin, cout):
        scale = 1.0 / math.sqrt(h * w * cin)
        return {"w": jax.random.normal(k, (h, w, cin, cout), jnp.float32) * scale,
                "b": jnp.zeros((cout,), jnp.float32)}

    def fc(k, din, dout):
        scale = 1.0 / math.sqrt(din)
        return {"w": jax.random.normal(k, (din, dout), jnp.float32) * scale,
                "b": jnp.zeros((dout,), jnp.float32)}

    return {
        "conv1": conv(k1, 3, 3, 1, 8),
        "conv2": conv(k2, 3, 3, 8, 8),
        "fc1": fc(k3, 8 * 7 * 7, 28),
        "fc2": fc(k4, 28, n_classes),
    }


def _conv2d(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def cnn_apply(params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: [B, 28, 28, 1] -> logits [B, 10]."""
    x = jax.nn.relu(_conv2d(params["conv1"], images))
    x = _maxpool2(x)
    x = jax.nn.relu(_conv2d(params["conv2"], x))
    x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_loss(params: Dict, batch) -> jnp.ndarray:
    """batch: {'images': [B,28,28,1], 'labels': [B]} -> mean CE loss."""
    logits = cnn_apply(params, batch["images"])
    ll = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(ll, batch["labels"][:, None],
                                         axis=-1))


def cnn_accuracy(params: Dict, batch) -> jnp.ndarray:
    logits = cnn_apply(params, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]
                     ).astype(jnp.float32))
