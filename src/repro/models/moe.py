"""Mixture-of-Experts layer (GShard-style top-k dispatch with capacity).

Experts are sharded over the ``model`` mesh axis (expert parallelism); the
dispatch/combine einsums reshard tokens to experts and back, which GSPMD
lowers to the canonical all-to-all pair. Shared experts (DeepSeek-V2) are
plain dense MLPs added to the routed output. The router emits a load-balance
auxiliary loss (Switch-style) that the trainer can weight in.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(key, cfg) -> Dict:
    e = cfg.n_experts
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)

    def expert_bank(k, d_in, d_out):
        return jax.random.normal(k, (e, d_in, d_out), jnp.float32) \
            / jnp.sqrt(jnp.asarray(d_in, jnp.float32))

    p = {
        "router": L.dense_init(ks[0], d, e, scale=0.02),
        "wi": expert_bank(ks[1], d, ff),
        "wg": expert_bank(ks[2], d, ff),
        "wo": expert_bank(ks[3], ff, d),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, ff * cfg.n_shared_experts,
                                 kind=cfg.mlp)
    return p


MOE_TOKEN_CHUNK = 8192  # max tokens per dispatch group (see _moe_tokens)


def _moe_tokens(p: Dict, cfg, xt: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Route one group of tokens: xt [T, D] -> (y [T, D], aux scalar).

    Top-k routing with per-group capacity ``ceil(T*k/E * capacity_factor)``
    (GShard-style one-hot dispatch/combine einsums; experts sharded over
    'model').
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = L.dense_apply(p["router"], xt, dtype=jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    import math as _math
    cap = max(k, int(_math.ceil(t * k / e * cfg.capacity_factor)))

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)       # [T, k, E]
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                # [T, k]
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch [T, E, cap] (combine shares the structure, weighted by gates)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=xt.dtype)                       # [T, k, cap]
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(xt.dtype), pos_oh)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32), gate_vals).astype(xt.dtype)

    xe = jnp.einsum("tec,td->ecd", disp, xt)                      # [E, cap, D]
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(xt.dtype))
    if cfg.mlp in ("swiglu", "geglu"):
        hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xt.dtype))
        act = jax.nn.silu(hg) if cfg.mlp == "swiglu" else \
            jax.nn.gelu(hg, approximate=True)
        hi = hi * act
    else:
        hi = jax.nn.gelu(hi, approximate=True)
    ye = jnp.einsum("ecf,efd->ecd", hi, p["wo"].astype(xt.dtype))  # [E,cap,D]
    y = jnp.einsum("tec,ecd->td", comb, ye)

    # Switch-style load-balance loss: E * sum_e (frac_tokens_e * frac_prob_e)
    me = jnp.mean(probs, axis=0)                                  # [E]
    ce = jnp.mean(jnp.sum(onehot, axis=1).astype(jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce) / k
    return y, aux


def moe_apply(p: Dict, cfg, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y, aux_loss).

    Long sequences are processed in token groups of ``MOE_TOKEN_CHUNK``
    (§Perf iter 10): the [T, E, cap] dispatch one-hots grow as T^2/E, which
    at 65k prefill tokens/device reached ~43 TB — grouped dispatch bounds
    the working set while keeping identical math up to the standard
    per-group capacity semantics.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    chunk = MOE_TOKEN_CHUNK
    if t <= chunk:
        y, aux = _moe_tokens(p, cfg, xt)
    else:
        pad = (-t) % chunk
        xp = jnp.pad(xt, ((0, pad), (0, 0)))
        groups = xp.reshape(-1, chunk, d)

        def one(g):
            return _moe_tokens(p, cfg, g)

        ys, auxs = jax.lax.map(one, groups)
        y = ys.reshape(-1, d)[:t]
        aux = jnp.mean(auxs)

    if cfg.n_shared_experts:
        y = y + L.mlp_apply(p["shared"], xt, kind=cfg.mlp)

    return y.reshape(b, s, d), aux
