"""Shared neural-net layers (pure-function style, params as nested dicts).

Conventions:
  * activations flow in ``cfg.dtype`` (bf16 by default); params are stored in
    f32 ("master" copies — the RoSDHB server state is separate) and cast on
    use; norms/softmax/rope run in f32.
  * attention layouts: q ``[B, S, H, Dh]``, k/v ``[B, S, KV, Dh]``.
  * decode caches are dicts of arrays; positions are absolute; sliding-window
    caches are ring buffers of length ``window``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None,
               bias: bool = False) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_apply(p: Params, x: jnp.ndarray, dtype=None) -> jnp.ndarray:
    dtype = dtype or x.dtype
    y = x @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def norm_init(d: int, kind: str = "rmsnorm") -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: Params, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, Dh]; positions: [B, S] or [S] absolute positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# attention core (XLA path; the Pallas flash kernel mirrors this math)
# --------------------------------------------------------------------------


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)
                            ).reshape(b, s, kv * n_rep, dh)


def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     q_offset,
                     window: Optional[int] = None,
                     kv_len: Optional[jnp.ndarray] = None,
                     chunk: int = 1024) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, query-chunked so that
    logits never materialise beyond ``[B, H, chunk, Sk]`` (the XLA analogue
    of the flash kernel; the ``repro.kernels.flash_attention`` oracle calls
    this with ``chunk >= S``).

    Args:
      q: [B, Sq, H, Dh]; k/v: [B, Sk, KV, Dh] (already roped).
      q_offset: absolute position of q[0] (int or scalar array).
      window: sliding-window size (None = full causal).
      kv_len: optional valid kv length (for decode with partially filled
        caches); defaults to Sk.
    Returns [B, Sq, H, Dh].
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(dh)
    kv_len = sk if kv_len is None else kv_len
    kpos = jnp.arange(sk)

    def attend(q_chunk: jnp.ndarray, qpos: jnp.ndarray) -> jnp.ndarray:
        # q_chunk: [B, C, H, Dh]; qpos: [C] absolute positions.
        # Grouped-head formulation: never materialise the rep-expanded K/V
        # (perf iteration 1, EXPERIMENTS §Perf) — q is reshaped to
        # [B, C, KV, rep, Dh] and contracted against the raw K/V.
        c = q_chunk.shape[1]
        qg = q_chunk.reshape(b, c, kv, rep, dh)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] <= qpos[:, None]
        mask &= kpos[None, :] < kv_len
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bgrqk,bkge->bqgre", probs.astype(q.dtype), v)
        return out.reshape(b, c, h, v.shape[-1])

    if sq <= chunk:
        return attend(q, q_offset + jnp.arange(sq))

    n_chunks = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qs = q.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def body(i, qc):
        return attend(qc, q_offset + i * chunk + jnp.arange(chunk))

    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(n_chunks), qs))
    dv = v.shape[-1]  # may differ from dh (MLA: v_head_dim != qk head dim)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def prefill_cache_write(cache: jnp.ndarray, fresh: jnp.ndarray,
                        window: Optional[int]) -> jnp.ndarray:
    """Write a full prefilled sequence of k or v ([B, S, KV, Dh]) into a
    preallocated cache ([B, W, KV, Dh]).

    Full cache (window None, W >= S): plain write at [0, S).
    Ring cache (W == window): keep the last W entries, rolled so that the
    entry with absolute position p sits at slot p % W.
    """
    s = fresh.shape[1]
    w = cache.shape[1]
    if window is None or s <= w:
        if s == w:
            return fresh.astype(cache.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            cache, fresh.astype(cache.dtype), 0, axis=1)
    last = fresh[:, -w:]
    shift = (s - w) % w
    return jnp.roll(last, shift, axis=1).astype(cache.dtype)


def ring_cache_update(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                      k: jnp.ndarray, v: jnp.ndarray, pos) -> Tuple:
    """Write one decode step's k/v ([B, 1, KV, Dh]) into a ring buffer of
    length W at slot ``pos % W``."""
    w = cache_k.shape[1]
    slot = jnp.mod(pos, w)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    return ck, cv


def decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention against a cache.

    q: [B, 1, H, Dh]; cache_k/v: [B, W, KV, Dh]. ``pos`` is the absolute
    position of the new token. For ring-buffer (sliding window) caches the
    validity mask accounts for wrap-around; for full caches W >= pos+1.
    """
    b, w, kv, dh = cache_k.shape
    sq, h = q.shape[1], q.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(dh)
    # grouped-head contraction: no rep-expanded K/V materialisation
    # (perf iteration 1, EXPERIMENTS §Perf)
    qg = q.reshape(b, sq, kv, rep, dh)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(w)
    if window is None:
        valid = slots <= pos
    else:
        # ring buffer: slot s holds absolute position p with p % W == s and
        # p in (pos - W, pos]; valid iff that p exists, i.e. the buffer has
        # been written there already.
        newest_slot = jnp.mod(pos, w)
        age = jnp.mod(newest_slot - slots, w)  # 0 = newest
        valid = age <= jnp.minimum(pos, w - 1)
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkge->bqgre", probs.astype(q.dtype), cache_v)
    return out.reshape(b, sq, h, cache_v.shape[-1])


# --------------------------------------------------------------------------
# GQA/MQA attention block
# --------------------------------------------------------------------------


def attn_init(key, cfg) -> Params:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd,
                         bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd,
                         bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd,
                         bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model),
    }


def attn_apply(p: Params, cfg, x: jnp.ndarray, *, mode: str = "train",
               pos=0, cache: Optional[Dict] = None,
               kv_x: Optional[jnp.ndarray] = None,
               causal: bool = True) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """GQA attention. ``kv_x`` switches to cross-attention (no causal mask,
    no rope on kv side beyond positions 0..Skv).

    mode: "train" (no cache), "prefill" (returns filled cache),
    "decode" (x is [B,1,D], cache consumed/updated).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    cross = kv_x is not None
    src = kv_x if cross else x
    q = dense_apply(p["wq"], x).reshape(b, s, h, hd)
    k = dense_apply(p["wk"], src).reshape(b, src.shape[1], kvh, hd)
    v = dense_apply(p["wv"], src).reshape(b, src.shape[1], kvh, hd)

    if not cross:
        qpos = pos + jnp.arange(s)
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    new_cache = None
    if cross:
        # cross-attention: full (non-causal) attention over image/audio keys
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, _repeat_kv(k, h // kvh),
                            preferred_element_type=jnp.float32)
        logits = logits / math.sqrt(hd)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype),
                         _repeat_kv(v, h // kvh))
    elif mode == "decode":
        assert cache is not None
        ck, cv = ring_cache_update(cache["k"], cache["v"], k, v, pos)
        out = decode_attention(q, ck, cv, pos, window=cfg.sliding_window)
        new_cache = {"k": ck, "v": cv}
    else:
        flash = getattr(cfg, "use_flash_attention", None)
        if flash is None:
            flash = jax.default_backend() == "tpu"
        if flash and mode == "train" and causal and isinstance(pos, int):
            # kernelised hot path: repro.kernels.flash_attention (interpret
            # mode off-TPU, non-128 head dims zero-padded in ops.attention)
            from repro.kernels.flash_attention import ops as FA
            out = FA.attention(q, k, v, causal=True,
                               window=cfg.sliding_window, q_offset=pos,
                               use_pallas=True,
                               interpret=jax.default_backend() != "tpu")
        else:
            out = causal_attention(q, k, v, q_offset=pos,
                                   window=cfg.sliding_window)
        if mode == "prefill":
            assert cache is not None, "prefill requires a preallocated cache"
            new_cache = {
                "k": prefill_cache_write(cache["k"], k, cfg.sliding_window),
                "v": prefill_cache_write(cache["v"], v, cfg.sliding_window),
            }
    y = dense_apply(p["wo"], out.reshape(b, s, h * hd))
    return y, new_cache


def attn_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict:
    hd = cfg.resolved_head_dim
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, w, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, kind: str = "swiglu") -> Params:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d_model, d_ff),
                "wg": dense_init(ks[1], d_model, d_ff),
                "wo": dense_init(ks[2], d_ff, d_model)}
    return {"wi": dense_init(ks[0], d_model, d_ff),
            "wo": dense_init(ks[2], d_ff, d_model)}


def mlp_apply(p: Params, x: jnp.ndarray, kind: str = "swiglu") -> jnp.ndarray:
    if kind == "swiglu":
        a = jax.nn.silu(dense_apply(p["wg"], x))
        return dense_apply(p["wo"], a * dense_apply(p["wi"], x))
    if kind == "geglu":
        a = jax.nn.gelu(dense_apply(p["wg"], x), approximate=True)
        return dense_apply(p["wo"], a * dense_apply(p["wi"], x))
    return dense_apply(p["wo"],
                       jax.nn.gelu(dense_apply(p["wi"], x), approximate=True))
