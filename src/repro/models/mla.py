"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a per-token latent ``c_kv`` of rank ``kv_lora_rank``
plus a small shared rope key; the decode cache stores only
``[B, S, kv_lora_rank + qk_rope_head_dim]`` — the family's headline memory
win, which is why the deepseek decode shapes are cache-cheap.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def mla_init(key, cfg) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    r = cfg.kv_lora_rank
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": L.dense_init(ks[0], d, h * qd),
        "wdkv": L.dense_init(ks[1], d, r + cfg.qk_rope_head_dim),
        "kv_norm": L.norm_init(r),
        "wukv": L.dense_init(ks[2], r,
                             h * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
        "wo": L.dense_init(ks[3], h * cfg.v_head_dim, d),
    }


def _expand_kv(p: Dict, cfg, ckv: jnp.ndarray, k_rope: jnp.ndarray,
               dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ckv [B,S,r] (already normed), k_rope [B,S,rope] (already roped)
    -> k [B,S,H,qd], v [B,S,H,vd]."""
    b, s, _ = ckv.shape
    h = cfg.n_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    kv = L.dense_apply(p["wukv"], ckv, dtype=dtype).reshape(b, s, h, nope + vd)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    k_r = jnp.broadcast_to(k_rope[:, :, None, :],
                           (b, s, h, cfg.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_r], axis=-1)
    return k, v


def mla_apply(p: Dict, cfg, x: jnp.ndarray, *, mode: str = "train",
              pos=0, cache: Optional[Dict] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    qd = nope + rope

    q = L.dense_apply(p["wq"], x).reshape(b, s, h, qd)
    q_nope, q_rope = jnp.split(q, [nope], axis=-1)
    qpos = pos + jnp.arange(s)
    q_rope = L.apply_rope(q_rope, qpos, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    dkv = L.dense_apply(p["wdkv"], x)
    ckv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    ckv = L.norm_apply(p["kv_norm"], ckv)
    k_rope = L.apply_rope(k_rope[:, :, None, :], qpos, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if mode == "decode":
        assert cache is not None and s == 1
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), pos, axis=1)
        # Absorbed decode (§Perf iter 11): instead of re-expanding every
        # cached latent through W_ukv each step ([B,S,H,nope+vd] transient,
        # S*r*H*(nope+vd) FLOPs), fold W_uk into the query and W_uv into
        # the output — attention runs entirely in the rank-r latent space.
        r = cfg.kv_lora_rank
        wukv = p["wukv"]["w"].astype(x.dtype).reshape(
            r, h, nope + cfg.v_head_dim)
        wuk = wukv[:, :, :nope]            # [r, H, nope]
        wuv = wukv[:, :, nope:]            # [r, H, vd]
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)
        ck = ckv_all.astype(x.dtype)
        kr = kr_all.astype(x.dtype)
        logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ck,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhp,bsp->bhqs", q_rope, kr,
                               preferred_element_type=jnp.float32))
        logits = logits / math.sqrt(qd)
        valid = jnp.arange(ck.shape[1]) <= pos
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        lat = jnp.einsum("bhqs,bsr->bqhr", probs.astype(x.dtype), ck)
        out = jnp.einsum("bqhr,rhv->bqhv", lat, wuv)
        new_cache = {"ckv": ckv_all, "krope": kr_all}
    else:
        k, v = _expand_kv(p, cfg, ckv, k_rope, x.dtype)
        out = L.causal_attention(q, k, v, q_offset=pos,
                                 window=cfg.sliding_window)
        if mode == "prefill":
            assert cache is not None, "prefill requires a preallocated cache"
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1),
                "krope": jax.lax.dynamic_update_slice_in_dim(
                    cache["krope"], k_rope.astype(cache["krope"].dtype), 0,
                    axis=1),
            }
    # v_head_dim may differ from qk dim; out is [B,S,H,v_head_dim]
    y = L.dense_apply(p["wo"], out.reshape(b, s, h * cfg.v_head_dim))
    return y, new_cache


def mla_cache_init(cfg, batch: int, max_len: int, dtype) -> Dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }
