from repro.models.config import ModelConfig
from repro.models.transformer import (
    model_init,
    forward,
    cache_init,
    lm_loss,
    logits_fn,
    chunked_xent,
)
from repro.models.cnn import cnn_init, cnn_apply, cnn_loss, cnn_accuracy
from repro.models.decode import make_decode_step

__all__ = [
    "ModelConfig", "model_init", "forward", "cache_init", "lm_loss",
    "logits_fn", "chunked_xent", "make_decode_step",
    "cnn_init", "cnn_apply", "cnn_loss", "cnn_accuracy",
]
