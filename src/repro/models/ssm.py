"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

TPU adaptation: we use the *chunked matmul* formulation of SSD — within-chunk
attention-like quadratic term + cross-chunk recurrence over chunk states —
which maps onto the MXU (all contractions are matmuls), instead of the
GPU-style selective-scan kernel. Decode keeps an O(heads * head_dim * state)
recurrent state, which is what makes ``long_500k`` natural for this family.

Layout: x [B, S, D]; inner projection produces
  z (gate)        [B, S, d_inner]
  xh (ssm input)  [B, S, H, P]     (d_inner = H * P)
  B, C            [B, S, G, N]     (G groups, N = ssm_state)
  dt              [B, S, H]
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def ssm_init(key, cfg) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h = cfg.ssm_n_heads
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 5)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(ks[3], (h,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * g * n + h),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                    jnp.float32) / math.sqrt(cfg.ssm_conv_width),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "D": jnp.ones((h,), jnp.float32),
        "norm": L.norm_init(di),
        "out_proj": L.dense_init(ks[4], di, d),
    }


def _split_proj(cfg, proj: jnp.ndarray):
    di = cfg.ssm_d_inner
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    h = cfg.ssm_n_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * g * n], axis=-1)
    return z, xbc, dt  # dt: [..., H]


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d. xbc [B, S, C]; w [W, C]. Returns (y, new_state)
    where state is the trailing W-1 inputs for decode continuation."""
    bsz, s, c = xbc.shape
    wlen = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, wlen - 1, c), xbc.dtype)
    ext = jnp.concatenate([state, xbc], axis=1)
    idx = jnp.arange(s)[:, None] + jnp.arange(wlen)[None, :]  # [S, W]
    windows = ext[:, idx]  # [B, S, W, C]
    y = jnp.einsum("bswc,wc->bsc", windows, w.astype(xbc.dtype))
    y = jax.nn.silu(y + b.astype(xbc.dtype))
    new_state = ext[:, -(wlen - 1):] if wlen > 1 else state
    return y, new_state


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    Args:
      xh: [B, S, H, P] inputs; dt: [B, S, H] (post-softplus, >0);
      A:  [H] (negative); Bm/Cm: [B, S, G, N].
      init_state: [B, H, P, N] or None.
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    bsz, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g
    f32 = jnp.float32

    # reshape into chunks
    xc = xh.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    Bc = jnp.repeat(Bm.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(f32)

    da = dtc * A[None, None, None, :]          # [B, NC, L, H] (negative)
    cum = jnp.cumsum(da, axis=2)               # within-chunk cumulative decay

    # ---- intra-chunk (quadratic, attention-like) term ----
    # decay(i<-j) = exp(cum_i - cum_j) for j <= i
    li = cum[:, :, :, None, :]                 # i
    lj = cum[:, :, None, :, :]                 # j
    seg = jnp.exp(li - lj)                     # [B, NC, L, L, H]
    iidx = jnp.arange(chunk)
    causal = (iidx[:, None] >= iidx[None, :])[None, None, :, :, None]
    seg = jnp.where(causal, seg, 0.0)
    cb = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc)        # [B,NC,L,L,H]
    y_diag = jnp.einsum("bclsh,bclsh,bcsh,bcshp->bclhp",
                        cb, seg, dtc, xc)

    # ---- chunk states ----
    # state contribution of chunk c: sum_j exp(cum_last - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # [B,NC,L,H]
    states = jnp.einsum("bclh,bclh,bclhn,bclhp->bchpn",
                        decay_to_end, dtc, Bc, xc)        # [B,NC,H,P,N]

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))            # [B,NC,H]
    s0 = (jnp.zeros((bsz, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def scan_fn(carry, inp):
        st, dec = inp  # st [B,H,P,N], dec [B,H]
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* this chunk

    states_t = jnp.moveaxis(states, 1, 0)        # [NC,B,H,P,N]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)    # [NC,B,H]
    final, prev_states = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,NC,H,P,N]

    # ---- contribution of the incoming state to each position ----
    state_decay = jnp.exp(cum)                   # decay from chunk start to i
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                       Cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(xh.dtype), final


def ssm_apply(p: Dict, cfg, x: jnp.ndarray, *, mode: str = "train",
              cache: Optional[Dict] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 block. mode 'train'/'prefill' run the chunked SSD over the full
    sequence; 'decode' advances the recurrence by one token."""
    bsz, s, _ = x.shape
    h, pdim = cfg.ssm_n_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    di = cfg.ssm_d_inner
    proj = L.dense_apply(p["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    conv_state = cache.get("conv") if cache else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xh, Bm, Cm = jnp.split(xbc, [di, di + g * n], axis=-1)
    xh = xh.reshape(bsz, s, h, pdim)
    Bm = Bm.reshape(bsz, s, g, n)
    Cm = Cm.reshape(bsz, s, g, n)

    if mode == "decode":
        assert cache is not None and s == 1
        st = cache["state"].astype(jnp.float32)  # [B,H,P,N]
        dtv = dt[:, 0]                            # [B,H]
        dec = jnp.exp(dtv * A[None, :])           # [B,H]
        Bv = jnp.repeat(Bm[:, 0], h // g, axis=1).astype(jnp.float32)  # [B,H,N]
        Cv = jnp.repeat(Cm[:, 0], h // g, axis=1).astype(jnp.float32)
        xv = xh[:, 0].astype(jnp.float32)         # [B,H,P]
        new_state = (st * dec[:, :, None, None]
                     + jnp.einsum("bh,bhn,bhp->bhpn", dtv, Bv, xv))
        y = jnp.einsum("bhn,bhpn->bhp", Cv, new_state)
        y = y[:, None]  # [B,1,H,P]
        new_cache = {"state": new_state.astype(cache["state"].dtype),
                     "conv": new_conv}
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            # zero-pad the tail; padded steps have dt=0 => decay 1, no input,
            # so the final state is unaffected and padded outputs are dropped.
            zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +  # noqa: E731
                                   [(0, 0)] * (a.ndim - 2))
            xh_p, dt_p, Bm_p, Cm_p = zf(xh), zf(dt), zf(Bm), zf(Cm)
            y, final = ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, chunk)
            y = y[:, :s]
        else:
            y, final = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
        y = y.astype(x.dtype)
        new_cache = None
        if mode == "prefill":
            assert cache is not None, "prefill requires a preallocated cache"
            new_cache = {"state": final.astype(cache["state"].dtype),
                         "conv": new_conv.astype(cache["conv"].dtype)}

    y = y + p["D"][None, None, :, None].astype(jnp.float32) * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated
    y = L.norm_apply(p["norm"], y)
    out = L.dense_apply(p["out_proj"], y)
    return out, new_cache


def ssm_cache_init(cfg, batch: int, dtype) -> Dict:
    h, pdim, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, pdim, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }
