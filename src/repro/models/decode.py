"""One jitted greedy decode step, shared by the serving entry points.

``launch/serve.py`` and ``examples/serve_demo.py`` both run the
prefill-then-decode loop; the decode step must be compiled ONCE with the
position as a traced scalar — passing a Python-int ``pos`` bakes the
position into the program as a constant and recompiles every token.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import forward, logits_fn


def make_decode_step(cfg, image_embeddings=None) -> Callable:
    """Build the jitted single-token greedy decode step for ``cfg``.

    Returns ``decode_step(params, tok, caches, pos) -> (next_tok, caches)``
    where ``pos`` must be a traced int32 scalar (use
    ``jnp.asarray(p, jnp.int32)`` in the caller's loop) so every decoded
    token reuses one compiled program. For VLM configs pass the prompt's
    ``image_embeddings`` once here; they are closed over as a compile-time
    constant.
    """

    @jax.jit
    def decode_step(params, tok, caches, pos):
        if cfg.input_kind == "tokens":
            db = {"tokens": tok}
        else:
            db = {"embeddings": jax.nn.one_hot(tok, cfg.d_model,
                                               dtype=jnp.float32)}
        if cfg.family == "vlm":
            db["image_embeddings"] = image_embeddings
        h, caches, _ = forward(params, cfg, db, mode="decode", pos=pos,
                               caches=caches)
        return jnp.argmax(logits_fn(params, cfg, h), -1), caches

    return decode_step
