from repro.optim.optimizers import sgd, heavy_ball, adamw, apply_updates, cosine_schedule, Optimizer

__all__ = ["sgd", "heavy_ball", "adamw", "apply_updates", "cosine_schedule", "Optimizer"]
