"""Minimal pytree optimizers (no optax offline).

The RoSDHB *server* update is part of ``repro.core``; these optimizers serve
the substrate roles: reference non-robust training, the examples' inner
loops, and the serve-side fine-tuning demos. API mirrors optax:
``init(params) -> state``, ``update(grads, state, params) -> (updates, state)``
with updates to be *added* to params.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Tree = Any


class Optimizer(NamedTuple):
    init: Callable[[Tree], Tree]
    update: Callable[[Tree, Tree, Tree], Tuple[Tree, Tree]]


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def heavy_ball(lr: float, beta: float = 0.9) -> Optimizer:
    """Polyak momentum in the paper's normalisation:
    m_t = beta m_{t-1} + (1-beta) g_t;  theta -= lr * m_t."""

    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, m, params):
        m = jax.tree_util.tree_map(
            lambda mm, g: beta * mm + (1.0 - beta) * g, m, grads)
        return jax.tree_util.tree_map(lambda mm: -lr * mm, m), m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Tree
    nu: Tree
    count: jnp.ndarray


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return AdamState(z, z, jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            return -lr * (step + weight_decay * p)

        return (jax.tree_util.tree_map(upd, mu, nu, params),
                AdamState(mu, nu, count))

    return Optimizer(init, update)


def apply_updates(params: Tree, updates: Tree) -> Tree:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
