"""Production mesh construction.

NOTE: these are FUNCTIONS, not module-level constants — importing this module
never touches jax device state (the dry-run must set XLA_FLAGS before any
device initialisation).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4; older runtimes use the default typing
    from jax.sharding import AxisType

    def _axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    def _axis_types(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e production mesh: one pod = (16, 16) = ("data", "model")
    (256 chips); two pods = (2, 16, 16) = ("pod", "data", "model").

    The RoSDHB workers are the data-parallel groups: 16 single-pod,
    32 (= pod x data) multi-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh() -> Mesh:
    """Degenerate mesh over however many devices are actually present
    (CPU tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_axis_types(2))
