"""Training launcher.

On a TPU slice this builds the production mesh and runs the full-size
config; on CPU (this container) it automatically reduces the model (same
family) so the pipeline is runnable end-to-end — the full configs are
exercised by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --steps 20 \
        --algo rosdhb --ratio 0.05 --f 2 --attack alie
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import INPUT_SHAPES, get_arch
from repro.configs.base import ArchSpec, InputShape
from repro.core import AggregatorConfig, AttackConfig, SparsifierConfig
from repro.core import algorithms as alg
from repro.data import ChunkPrefetcher
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import (TrainState, build_chunked_train_step,
                                build_train_step, make_train_plan)
from repro.models import model_init


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default="train_4k", choices=list(INPUT_SHAPES))
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--algo", default="rosdhb",
                   choices=["rosdhb", "dasha", "robust_dgd", "dgd"])
    p.add_argument("--ratio", type=float, default=0.05)
    p.add_argument("--f", type=int, default=None)
    p.add_argument("--attack", default="alie")
    p.add_argument("--gamma", type=float, default=1e-3)
    p.add_argument("--local-masks", action="store_true")
    p.add_argument("--momentum-dtype", default="float32")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--checkpoint", default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--stream", action="store_true",
                   help="feed batches through the prefetched ring buffer "
                        "(repro.data.stream) and scan --chunk-size rounds "
                        "per dispatch — O(prefetch_depth) host residency")
    p.add_argument("--chunk-size", type=int, default=8)
    p.add_argument("--prefetch-depth", type=int, default=2)
    args = p.parse_args()

    on_tpu = jax.default_backend() == "tpu"
    spec = get_arch(args.arch)
    if on_tpu:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = INPUT_SHAPES[args.shape]
        n_workers = None
    else:
        print("[train] CPU backend: using reduced config + host mesh "
              "(full configs are compile-proven by repro.launch.dryrun)")
        spec = ArchSpec(model=spec.model.reduced(n_layers=2, d_model=256)
                        .with_overrides(vocab_size=512),
                        citation=spec.citation)
        mesh = make_host_mesh()
        shape = InputShape("host_train", 128, 16, "train")
        n_workers = 8

    f = args.f if args.f is not None else None
    overrides = {
        "name": args.algo, "gamma": args.gamma,
        "momentum_dtype": args.momentum_dtype,
        "sparsifier": SparsifierConfig(
            kind="block", ratio=args.ratio, block_size=512,
            local=args.local_masks),
        "attack": AttackConfig(name=args.attack),
    }
    if f is not None:
        overrides["f"] = f
        overrides["aggregator"] = AggregatorConfig(name="cwtm", f=max(f, 1))
    plan = make_train_plan(spec, shape, mesh, overrides, n_workers=n_workers)
    step = jax.jit(build_train_step(plan, mesh))
    cfg = plan.model

    with mesh:
        params = model_init(jax.random.PRNGKey(args.seed), cfg)
        state = TrainState(
            params=params,
            server=alg.init_state(plan.algo, plan.flat_spec.padded_size),
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(args.seed + 1))
        rng = np.random.default_rng(args.seed)
        lb = shape.global_batch // plan.n_workers
        print(f"[train] {spec.model.name} D={plan.flat_spec.padded_size:,} "
              f"n_workers={plan.n_workers} f={plan.algo.f} "
              f"algo={plan.algo.name} k/d={args.ratio}"
              + (f" stream chunk={args.chunk_size}"
                 f" depth={args.prefetch_depth}" if args.stream else ""))

        def make_batch(gen):
            toks = gen.integers(0, cfg.vocab_size,
                                (plan.n_workers, lb, shape.seq_len))
            toks[..., 1::2] = (toks[..., 0::2] + 1) % cfg.vocab_size
            batch = {"tokens": np.asarray(toks, np.int32)}
            if cfg.input_kind != "tokens":
                batch = {
                    "embeddings": np.asarray(gen.normal(size=(
                        plan.n_workers, lb, shape.seq_len, cfg.d_model)),
                        np.float32),
                    "targets": np.asarray(toks % cfg.vocab_size, np.int32),
                }
            if cfg.family == "vlm":
                batch["image_embeddings"] = np.asarray(
                    gen.normal(size=(plan.n_workers, lb,
                                     cfg.n_image_tokens, cfg.d_model)),
                    np.float32)
            return batch

        t0 = time.time()
        if args.stream:
            # pure-fn-of-t schedule so the prefetch thread owns its RNG
            chunk_step = jax.jit(build_chunked_train_step(
                plan, mesh, args.chunk_size))
            batch_fn = lambda t: make_batch(  # noqa: E731
                np.random.default_rng((args.seed, t)))
            t = 0
            with ChunkPrefetcher(batch_fn, args.steps, args.chunk_size,
                                 args.prefetch_depth) as pf:
                while True:
                    chunks = pf.take(1)
                    if not chunks:
                        break
                    state, metrics = chunk_step(state, chunks[0])
                    t += args.chunk_size
                    print(f"[train] step {t:4d} "
                          f"loss={float(metrics['loss'][-1]):.4f}"
                          f" |R|={float(metrics['dir_norm'][-1]):.3f}"
                          f" ({time.time()-t0:.1f}s)")
                print(f"[train] host high-water: {pf.high_water_bytes:,} B "
                      f"({pf.high_water_chunks} chunks)")
            for t in range(args.steps - args.steps % args.chunk_size,
                           args.steps):  # remainder rounds, one dispatch each
                state, metrics = step(
                    state, jax.device_put(make_batch(
                        np.random.default_rng((args.seed, t)))))
        else:
            for t in range(args.steps):
                state, metrics = step(state, jax.device_put(make_batch(rng)))
                if t % 5 == 0 or t == args.steps - 1:
                    print(f"[train] step {t:4d} "
                          f"loss={float(metrics['loss']):.4f}"
                          f" |R|={float(metrics['dir_norm']):.3f}"
                          f" ({time.time()-t0:.1f}s)")
        if args.checkpoint:
            ckpt.save(args.checkpoint, {"params": state.params},
                      step=args.steps)
            print(f"[train] checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
