# NOTE: do NOT import repro.launch.dryrun here — it sets XLA_FLAGS and must
# be the process entry point. Import submodules explicitly.
from repro.launch.mesh import make_production_mesh, make_host_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
