"""pjit train/serve step builders for the production mesh.

``build_train_step`` wires the paper's algorithm into the sharded model:

  1. per-worker gradients via ``vmap(grad)`` over the stacked worker axis
     (worker axis sharded over the data-parallel mesh axes — each data row
     computes exactly its own worker's gradient, tensor-sharded over
     ``model``);
  2. gradients are flattened to the coordinate-sharded server layout
     ``[n_workers, D]`` with ``D`` sharded over ALL mesh axes — GSPMD lowers
     the resharding to the all-to-all that realises "workers send compressed
     coordinates to the (virtual) server";
  3. ``core.algorithms.server_round`` runs the paper's steps 1-6 (masks,
     unbiased reconstruction, Byzantine overwrite, per-worker momentum,
     robust aggregation) locally per coordinate slice;
  4. the aggregate is unflattened back to the parameter layout (step 7).

``build_serve_step`` is the standard sharded forward (prefill or single-token
decode with KV/SSM caches) — RoSDHB is a training-time mechanism.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, InputShape, model_for_shape
from repro.core import algorithms as alg
from repro.core import aggregators as agg_lib
from repro.core import attacks as atk_lib
from repro.core import compression as comp_lib
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.sharding import partitioning as sp
from repro.sharding import flatten as sf
from repro.utils import tree as T


class TrainState(NamedTuple):
    params: Any            # model parameter pytree (f32 master)
    server: alg.ServerState  # RoSDHB bank [n_workers, Dp] etc.
    step: jnp.ndarray
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Everything the launcher/dry-run needs to build + shard a train step.

    ``flatten``: 'sharded' (default — transpose-major, GSPMD-clean; §Perf
    iter 2) or 'naive' (reshape+concat; kept for the paper-faithful baseline
    ablation — it replicates at scale).
    """

    arch: ArchSpec
    shape: InputShape
    model: ModelConfig
    algo: alg.AlgorithmConfig
    flat_spec: Any
    n_workers: int
    local_batch: int
    flatten: str = "sharded"


def _abstract_params(cfg: ModelConfig):
    # close over cfg: it is a plain dataclass, not a pytree
    return jax.eval_shape(lambda: tf.model_init(jax.random.PRNGKey(0), cfg))


def make_train_plan(spec: ArchSpec, shape: InputShape, mesh: Mesh,
                    algo_overrides: Optional[Dict] = None,
                    n_workers: Optional[int] = None,
                    flatten: str = "sharded") -> TrainPlan:
    cfg = model_for_shape(spec, shape)
    n = n_workers if n_workers is not None else sp.n_workers(mesh)
    if shape.global_batch % n:
        raise ValueError(f"global_batch {shape.global_batch} not divisible "
                         f"by n_workers {n}")
    local_batch = shape.global_batch // n
    abstract = _abstract_params(cfg)
    n_chips = int(np.prod(list(mesh.shape.values())))
    if n != sp.n_workers(mesh):
        # host/simulator mode: the worker axis does not match the mesh's
        # data-parallel extent, so the shard_map bank transforms do not
        # apply — use the naive flatten (fine off-mesh).
        flatten = "naive"
    if flatten == "sharded":
        flat_spec = sf.make_sharded_flat_spec(abstract, mesh,
                                              fsdp=spec.fsdp)
    else:
        flat_spec = T.make_flat_spec(abstract, pad_to=n_chips * 8)

    ov = dict(algo_overrides or {})
    algo = alg.AlgorithmConfig(
        name=ov.pop("name", "rosdhb"),
        n_workers=n,
        f=ov.pop("f", max(1, n // 8)),
        gamma=ov.pop("gamma", 1e-3),
        beta=ov.pop("beta", 0.9),
        sparsifier=ov.pop("sparsifier", comp_lib.SparsifierConfig(
            kind="block_hash", ratio=spec.rosdhb_ratio, block_size=512)),
        aggregator=ov.pop("aggregator", agg_lib.AggregatorConfig(
            name="cwtm", f=max(1, n // 8))),
        attack=ov.pop("attack", atk_lib.AttackConfig(name="alie")),
        momentum_dtype=ov.pop("momentum_dtype", "bfloat16"),
        **ov,
    )
    return TrainPlan(spec, shape, cfg, algo, flat_spec, n, local_batch,
                     flatten)


def build_train_step(plan: TrainPlan, mesh: Mesh):
    """Returns (step_fn, in_shardings-compatible abstract inputs builder)."""
    cfg = plan.model
    fspec = plan.flat_spec
    algo = plan.algo
    bank_sharding = P(None, sp.server_axes(mesh))
    wire_dtype = jnp.dtype(algo.momentum_dtype)

    def loss_fn(params, batch):
        return tf.lm_loss(params, cfg, batch)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        key, round_key = jax.random.split(state.key)

        # (1) per-worker gradients: batch leaves are [n_workers, local, ...].
        # spmd_axis_name pins the vmapped worker dim to the data-parallel
        # mesh axes for every internal intermediate — without it the
        # per-layer saved activations inside the scan are REPLICATED over
        # the worker dim (§Perf iter 5: 283 GiB/chip of f32 residuals at
        # mistral-123B scale).
        dp = sp.dp_axes(mesh)
        # mixed precision (§Perf iter 8): differentiate wrt a bf16 cast of
        # the f32 master params — halves the per-worker gradient transient
        # (the f32 master is only touched by the final update).
        half = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 else p, state.params)
        losses, grads = jax.vmap(
            jax.value_and_grad(loss_fn), in_axes=(None, 0),
            spmd_axis_name=dp if len(dp) > 1 else dp[0])(
                half, batch)

        # (2) flatten to the coordinate-sharded virtual-server layout.
        # 'sharded': transpose-major flatten keeps GSPMD shardings intact in
        # the producer layout [n(data), D(model)]; the reshard to the bank
        # layout [n, D(all axes)] below is the algorithm's one all-to-all
        # ("workers send their k coordinates to the server").
        if plan.flatten == "sharded":
            # hand-scheduled per-leaf all-to-all into the interleaved bank
            # layout (§Perf iter 4c) — the only formulation GSPMD partitions
            gflat = sf.flatten_to_bank(grads, fspec, mesh, dtype=wire_dtype)
        else:
            gflat = T.stacked_ravel(grads, fspec, dtype=wire_dtype)
            gflat = jax.lax.with_sharding_constraint(
                gflat, NamedSharding(mesh, bank_sharding))

        # (3) paper steps 1-6 on the [n, D] bank
        direction, server, aux = alg.server_round(
            algo, state.server, gflat, round_key)

        # (4) step 7: unflatten + SGD update of the master params
        if plan.flatten == "sharded":
            dir_tree = sf.bank_to_param_tree(direction, fspec, mesh)
        else:
            dir_tree = T.tree_unravel(direction, fspec)
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p - algo.gamma * d.astype(p.dtype)),
            state.params, dir_tree)

        metrics = {
            "loss": jnp.mean(losses[algo.f:]),
            "dir_norm": jnp.linalg.norm(direction),
            "payload_floats_per_worker": jnp.asarray(
                aux["payload_floats_per_worker"], jnp.float32),
        }
        return TrainState(new_params, server, state.step + 1, key), metrics

    return train_step


def build_chunked_train_step(plan: TrainPlan, mesh: Mesh,
                             chunk_size: int):
    """Scan ``chunk_size`` rounds of :func:`build_train_step` inside ONE
    compiled program: ``chunk_step(state, chunk) -> (state, metrics)`` with
    ``chunk`` leaves ``[chunk_size, n_workers, ...]`` and metrics stacked
    ``[chunk_size]``.

    This is the device program the streaming launch driver dispatches once
    per ring-buffer chunk (``repro.data.stream.ChunkPrefetcher``): host
    dispatch and batch residency drop from O(steps) to O(chunk), and the
    scan carry is exactly the ``TrainState`` of :func:`train_input_specs` —
    mirror/prev_grad slots pruned by the algorithm's resolved
    ``StateLayout``, so chunking never widens the carry.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    step = build_train_step(plan, mesh)

    def chunk_step(state: TrainState, chunk: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        return jax.lax.scan(step, state, chunk)

    return chunk_step


# --------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct) for lower()/compile() — no allocation
# --------------------------------------------------------------------------


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, pspec))


def _attack_state_specs(algo: alg.AlgorithmConfig, d: int, mesh: Mesh):
    """Abstract ``repro.adversary.AttackState`` matching ``alg.init_state``:
    the ``[d]`` memory slots shard over the server (coordinate) axes like
    the momentum bank; ``None`` for stateless attacks (the shared
    ``needs_attack_state`` predicate keeps this locked to the real state)."""
    from repro.adversary import core as adv
    if not adv.needs_attack_state(algo.attack.name, algo.f):
        return None
    vec = _sds((d,), jnp.float32, mesh, P(sp.server_axes(mesh)))
    return adv.AttackState(
        vec=vec, mu=vec,
        scalars=_sds((adv.NUM_SCALARS,), jnp.float32, mesh, P(None)),
        step=jax.ShapeDtypeStruct((), jnp.int32))


def train_input_specs(plan: TrainPlan, mesh: Mesh):
    """(state, batch) ShapeDtypeStructs for ``jit(train_step).lower``."""
    cfg = plan.model
    abstract = _abstract_params(cfg)
    pspecs = sp.param_specs(abstract, mesh, fsdp=plan.arch.fsdp)
    params = jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, mesh, s), abstract, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    n, d = plan.n_workers, plan.flat_spec.padded_size
    mdt = jnp.dtype(plan.algo.momentum_dtype)
    # the ServerState shape (see alg.init_state): the momentum bank always,
    # mirror/prev_grad only when the resolved StateLayout carries them
    # (dasha needs the variance-reduction slots; rosdhb/dgd/robust_dgd scan
    # momentum-only — the paper's per-client memory gap vs Byz-DASHA-PAGE,
    # 3x at [n, d] f32, see alg.server_state_bytes) — all sharded over the
    # server (coordinate) axes
    layout = plan.algo.resolved_state_layout()
    bank = _sds((n, d), mdt, mesh, P(None, sp.server_axes(mesh)))
    atk = _attack_state_specs(plan.algo, d, mesh)
    server = alg.ServerState(
        bank,
        bank if layout.mirror else None,
        (_sds((n, d), jnp.float32, mesh, P(None, sp.server_axes(mesh)))
         if layout.prev_grad else None),
        jax.ShapeDtypeStruct((), jnp.int32), atk)
    state = TrainState(
        params=params, server=server,
        step=jax.ShapeDtypeStruct((), jnp.int32),
        key=jax.ShapeDtypeStruct((2,), jnp.uint32))

    batch = _train_batch_specs(cfg, plan, mesh)
    return state, batch


def _train_batch_specs(cfg: ModelConfig, plan: TrainPlan, mesh: Mesh):
    n, lb, s = plan.n_workers, plan.local_batch, plan.shape.seq_len
    dp = P(sp.dp_axes(mesh))
    batch: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        batch["tokens"] = _sds((n, lb, s), jnp.int32, mesh,
                               P(sp.dp_axes(mesh), None, None))
    else:
        batch["embeddings"] = _sds((n, lb, s, cfg.d_model),
                                   jnp.dtype(cfg.dtype), mesh,
                                   P(sp.dp_axes(mesh), None, None, None))
        batch["targets"] = _sds((n, lb, s), jnp.int32, mesh,
                                P(sp.dp_axes(mesh), None, None))
    if cfg.family == "vlm":
        batch["image_embeddings"] = _sds(
            (n, lb, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            mesh, P(sp.dp_axes(mesh), None, None, None))
    return batch


def stream_batch_specs(plan: TrainPlan, mesh: Mesh, chunk_size: int):
    """Abstract ``[chunk_size, ...]`` batch chunk for
    ``jit(build_chunked_train_step(...)).lower``: the per-round specs of
    :func:`_train_batch_specs` with a leading replicated round axis (the
    scan axis — every device sees every round, worker sharding unchanged).
    """
    per_round = _train_batch_specs(plan.model, plan, mesh)
    return jax.tree_util.tree_map(
        lambda s: _sds((chunk_size,) + s.shape, s.dtype, mesh,
                       P(*((None,) + s.sharding.spec))),
        per_round,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def build_serve_step(spec: ArchSpec, shape: InputShape, mesh: Mesh):
    """Prefill or decode step. Signature:
       prefill: (params, batch, caches)      -> (logits_last, caches)
       decode:  (params, batch, caches, pos) -> (logits, caches)
    """
    cfg = model_for_shape(spec, shape)

    if shape.kind == "prefill":
        def prefill_step(params, batch, caches):
            hidden, caches, _ = tf.forward(params, cfg, batch,
                                           mode="prefill", pos=0,
                                           caches=caches, remat=False)
            logits = tf.logits_fn(params, cfg, hidden[:, -1:])
            return logits, caches
        return prefill_step

    def decode_step(params, batch, caches, pos):
        hidden, caches, _ = tf.forward(params, cfg, batch, mode="decode",
                                       pos=pos, caches=caches, remat=False)
        logits = tf.logits_fn(params, cfg, hidden)
        return logits, caches
    return decode_step


def serve_input_specs(spec: ArchSpec, shape: InputShape, mesh: Mesh):
    """Abstract (params, batch, caches[, pos]) for the serve step."""
    cfg = model_for_shape(spec, shape)
    abstract = _abstract_params(cfg)
    pspecs = sp.param_specs(abstract, mesh, fsdp=spec.fsdp)
    params = jax.tree_util.tree_map(
        lambda a, s: _sds(a.shape, a.dtype, mesh, s), abstract, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    b = shape.global_batch
    dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "prefill":
        s = shape.seq_len
        max_len = s
    else:
        s = 1
        max_len = shape.seq_len

    batch: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        batch["tokens"] = _sds((b, s), jnp.int32, mesh,
                               sp.batch_spec(mesh, (b, s)))
    else:
        batch["embeddings"] = _sds((b, s, cfg.d_model), dtype, mesh,
                                   sp.batch_spec(mesh, (b, s, cfg.d_model)))
    if cfg.family == "vlm":
        batch["image_embeddings"] = _sds(
            (b, cfg.n_image_tokens, cfg.d_model), dtype, mesh,
            sp.batch_spec(mesh, (b, cfg.n_image_tokens, cfg.d_model)))

    abstract_caches = jax.eval_shape(
        functools.partial(tf.cache_init, cfg, b, max_len, dtype))
    caches = jax.tree_util.tree_map(
        lambda a: _sds(a.shape, a.dtype, mesh,
                       sp.cache_spec(mesh, a.shape, batch=b)),
        abstract_caches)

    if shape.kind == "prefill":
        return params, batch, caches
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return params, batch, caches, pos
