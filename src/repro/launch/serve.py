"""Serving launcher: batched prefill + decode with the dry-run's serve step.

On TPU: production mesh + full config; on CPU: reduced config + host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_1_3b --tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ArchSpec
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import (cache_init, forward, logits_fn, make_decode_step,
                          model_init)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=8)
    args = p.parse_args()

    spec = get_arch(args.arch)
    if jax.default_backend() == "tpu":
        mesh = make_production_mesh()
        cfg = spec.model
    else:
        print("[serve] CPU backend: reduced config + host mesh")
        mesh = make_host_mesh()
        cfg = spec.model.reduced(n_layers=2, d_model=256).with_overrides(
            vocab_size=512, dtype="float32")

    b, s = args.batch, args.prompt_len
    max_len = s + args.tokens
    rng = np.random.default_rng(0)
    with mesh:
        params = model_init(jax.random.PRNGKey(0), cfg)
        batch = {}
        if cfg.input_kind == "tokens":
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        else:
            batch["embeddings"] = jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeddings"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)),
                jnp.float32)
        caches = cache_init(cfg, b, max_len)
        t0 = time.time()
        hidden, caches, _ = forward(params, cfg, batch, mode="prefill",
                                    pos=0, caches=caches)
        tok = jnp.argmax(logits_fn(params, cfg, hidden[:, -1:]), -1)
        print(f"[serve] prefill [{b}x{s}] {time.time()-t0:.2f}s")

        # ONE jitted decode step with a traced position: a Python-int pos
        # would constant-fold into the program and recompile every token
        decode_step = make_decode_step(
            cfg, batch.get("image_embeddings"))
        t0 = time.time()
        for i in range(args.tokens - 1):
            tok, caches = decode_step(params, tok, caches,
                                      jnp.asarray(s + i, jnp.int32))
        jax.block_until_ready(tok)
        n = (args.tokens - 1) * b
        print(f"[serve] decoded {n} tokens in {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
