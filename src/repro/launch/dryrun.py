import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e).

Lowers + compiles the train/serve step for every (architecture x input
shape) on the single-pod (16,16) mesh and the 2-pod (2,16,16) mesh, records
``memory_analysis()`` / ``cost_analysis()``, parses collective bytes from the
optimized HLO, and derives the three §Roofline terms.

Usage:
  python -m repro.launch.dryrun --arch mistral_large_123b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod-only
Options:
  --algo rosdhb|dasha|robust_dgd|dgd   (train shapes; default rosdhb)
  --momentum-dtype bfloat16|float32|float8_e4m3fn
  --ratio 0.05                         (RoSDHB k/d)
"""  # noqa: E402

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_arch, model_for_shape
from repro.core import compression as comp_lib
from repro.launch import steps as steps_lib
from repro.launch.hlo import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, model_flops, count_params


def run_one(arch_id: str, shape_name: str, *, multi_pod: bool = False,
            algo: str = "rosdhb", momentum_dtype: str = "bfloat16",
            server_compute_dtype: str = "float32",
            ratio: Optional[float] = None, verbose: bool = True) -> Dict:
    """Lower+compile one (arch, shape, mesh) combination; return the report."""
    spec = get_arch(arch_id)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    overrides: Dict = {"name": algo, "momentum_dtype": momentum_dtype,
                       "server_compute_dtype": server_compute_dtype}
    if ratio is not None:
        overrides["sparsifier"] = comp_lib.SparsifierConfig(
            kind="block", ratio=ratio, block_size=512)

    with mesh:
        if shape.kind == "train":
            plan = steps_lib.make_train_plan(spec, shape, mesh, overrides)
            step = steps_lib.build_train_step(plan, mesh)
            args = steps_lib.train_input_specs(plan, mesh)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(*args)
        else:
            step = steps_lib.build_serve_step(spec, shape, mesh)
            args = steps_lib.serve_input_specs(spec, shape, mesh)
            # caches are donated (updated in place), as in a real server
            lowered = jax.jit(step, donate_argnums=(2,)).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text(), default_group=n_chips)

    cfg = model_for_shape(spec, shape)
    n_params = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    mf = model_flops(cfg, shape)

    rf = Roofline(
        flops_per_chip=float(ca.get("flops", 0.0)),
        hbm_bytes_per_chip=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_chip=colls.wire_bytes,
        model_flops_total=mf,
        n_chips=n_chips,
    )

    report = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "algo": algo if shape.kind == "train" else None,
        "ok": True,
        "n_params": n_params,
        "n_params_active": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_chip": ma.argument_size_in_bytes,
            "output_bytes_per_chip": ma.output_size_in_bytes,
            "temp_bytes_per_chip": ma.temp_size_in_bytes,
            "alias_bytes_per_chip": ma.alias_size_in_bytes,
            "peak_bytes_per_chip": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        } if ma else None,
        "collectives": {"counts": colls.ops, "result_bytes": colls.result_bytes,
                        "wire_bytes_per_chip": colls.wire_bytes},
        "roofline": rf.as_dict(),
    }
    if verbose:
        mem = report["memory"]["peak_bytes_per_chip"] / 2**30 \
            if report["memory"] else float("nan")
        print(f"[dryrun] {arch_id:22s} {shape_name:12s} "
              f"{report['mesh']:7s} OK  peak={mem:7.2f}GiB/chip "
              f"compute={rf.compute_s*1e3:9.3f}ms mem={rf.memory_s*1e3:9.3f}ms "
              f"coll={rf.collective_s*1e3:9.3f}ms -> {rf.bottleneck}"
              f"  (compile {t_compile:.1f}s)")
    return report


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true",
                   help="also run the 2-pod mesh")
    p.add_argument("--multi-pod-only", action="store_true")
    p.add_argument("--algo", default="rosdhb",
                   choices=["rosdhb", "dasha", "robust_dgd", "dgd"])
    p.add_argument("--momentum-dtype", default="bfloat16")
    p.add_argument("--server-compute-dtype", default="float32")
    p.add_argument("--ratio", type=float, default=None)
    p.add_argument("--out", default=None)
    args = p.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if args.multi_pod or args.multi_pod_only:
        meshes.append(True)

    reports = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    reports.append(run_one(
                        arch, shape, multi_pod=mp, algo=args.algo,
                        momentum_dtype=args.momentum_dtype,
                        server_compute_dtype=args.server_compute_dtype,
                        ratio=args.ratio))
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"[dryrun] {arch} {shape} "
                          f"{'2x16x16' if mp else '16x16'} FAILED: {e}")
                    traceback.print_exc()
                    reports.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "ok": False, "error": str(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=2)
        print(f"[dryrun] wrote {len(reports)} reports to {args.out}")
    print(f"[dryrun] {len(reports) - failures}/{len(reports)} OK")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
