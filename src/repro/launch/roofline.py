"""Three-term roofline from the compiled dry-run artifact (see §Roofline).

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. ``cost_analysis()`` FLOPs/bytes are per-device (post-SPMD
partitioning), so the terms below are already per-chip seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link (1 link assumed per transfer)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_total: float  # 6*N*D (dense) / 6*N_active*D (MoE), all chips

    n_chips: int = 256

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat/redundancy waste). >1 means HLO under-counts (e.g.
        fused ops); <1 means recompute/overhead."""
        hlo_total = self.flops_per_chip * self.n_chips
        if hlo_total <= 0:
            return None
        return self.model_flops_total / hlo_total

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_flops_fraction": self.useful_flops_fraction,
        }


# --------------------------------------------------------------------------
# MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE); decode/prefill
# use 2 * N * D per generated/consumed token.
# --------------------------------------------------------------------------


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count of the assigned config (embeddings included
    once; MoE counts all experts unless active_only)."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    n = 0
    # embeddings + head
    if cfg.input_kind == "tokens":
        n += cfg.vocab_size * d
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        n += d * cfg.vocab_size

    def attn_params() -> int:
        if cfg.use_mla:
            qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            return (d * cfg.n_heads * qd
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                    + cfg.kv_lora_rank * cfg.n_heads
                    * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        return (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                + cfg.n_heads * hd * d)

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        return mult * d * ff

    def ssm_params() -> int:
        di = cfg.ssm_d_inner
        gn = cfg.ssm_n_groups * cfg.ssm_state
        h = cfg.ssm_n_heads
        return (d * (2 * di + 2 * gn + h) + cfg.ssm_conv_width * (di + 2 * gn)
                + di * d + 3 * h + di)

    fam = cfg.family
    if fam in ("dense", "audio"):
        n += L * (attn_params() + mlp_params(cfg.d_ff))
    elif fam == "moe":
        fk = cfg.first_k_dense
        n += fk * (attn_params() + mlp_params(cfg.d_ff))
        e = cfg.top_k if active_only else cfg.n_experts
        per_layer = attn_params() + e * mlp_params(cfg.d_ff) \
            + cfg.n_shared_experts * mlp_params(cfg.d_ff) + d * cfg.n_experts
        n += (L - fk) * per_layer
    elif fam == "ssm":
        n += L * ssm_params()
    elif fam == "hybrid":
        n += L * ssm_params()
        n += attn_params() + mlp_params(cfg.d_ff)  # ONE shared block
    elif fam == "vlm":
        g = L // cfg.cross_attn_every
        n_self = L - g
        n += n_self * (attn_params() + mlp_params(cfg.d_ff))
        n += g * (attn_params() + mlp_params(cfg.d_ff))  # cross layers
    return n


def model_flops(cfg, shape, active_only_params: Optional[int] = None) -> float:
    """6*N*D for training; 2*N*tokens for inference steps."""
    n_active = active_only_params if active_only_params is not None \
        else count_params(cfg, active_only=(cfg.family == "moe"))
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch
