"""Three-term roofline from the compiled dry-run artifact (see §Roofline).

Hardware rates live in :class:`HardwareSpec` (default: TPU v5e — 197
TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI); pick one by name
via :data:`KNOWN_HARDWARE` or let :func:`detect_hardware` read the live
backend. ``cost_analysis()`` FLOPs/bytes are per-device (post-SPMD
partitioning), so the terms below are already per-chip seconds.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peak rates of one accelerator generation."""

    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float      # HBM B/s per chip
    ici_bw: float      # B/s per interconnect link (1 link per transfer)


TPU_V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9)

#: Specs addressable by ``--hardware`` CLI overrides. Rates are public
#: per-chip peaks; ``cpu`` is a rough dev-host stand-in so rooflines stay
#: finite (and obviously not memory-bound-gated) in CI.
KNOWN_HARDWARE: Dict[str, HardwareSpec] = {
    "tpu-v5e": TPU_V5E,
    "tpu-v4": HardwareSpec("tpu-v4", peak_flops=275e12, hbm_bw=1200e9,
                           ici_bw=50e9),
    "tpu-v5p": HardwareSpec("tpu-v5p", peak_flops=459e12, hbm_bw=2765e9,
                            ici_bw=100e9),
    "tpu-v6e": HardwareSpec("tpu-v6e", peak_flops=918e12, hbm_bw=1640e9,
                            ici_bw=100e9),
    "cpu": HardwareSpec("cpu", peak_flops=0.5e12, hbm_bw=50e9, ici_bw=10e9),
}

# Backwards-compatible module constants (pre-HardwareSpec callers).
PEAK_FLOPS = TPU_V5E.peak_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_bw


def detect_hardware(override: Optional[str] = None) -> HardwareSpec:
    """Resolve a :class:`HardwareSpec` from an explicit name or the live
    JAX backend's ``device_kind`` (falling back to the TPU v5e default on
    unrecognised TPU kinds, ``cpu`` on CPU hosts). Unknown ``override``
    names raise ``ValueError`` listing the known ones."""
    if override is not None:
        try:
            return KNOWN_HARDWARE[override]
        except KeyError:
            raise ValueError(
                f"unknown hardware {override!r} "
                f"(known: {sorted(KNOWN_HARDWARE)})") from None
    import jax
    kind = jax.devices()[0].device_kind.lower()
    for name, spec in KNOWN_HARDWARE.items():
        # device_kind strings look like "TPU v5 lite", "TPU v4", "cpu"
        tag = name.replace("tpu-", "tpu ").replace("v5e", "v5 lite")
        if tag in kind or name == kind:
            return spec
    if "tpu" in kind:
        return TPU_V5E
    return KNOWN_HARDWARE["cpu"]


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_total: float  # 6*N*D (dense) / 6*N_active*D (MoE), all chips

    n_chips: int = 256
    spec: HardwareSpec = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.spec.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / self.spec.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_chip / self.spec.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat/redundancy waste). >1 means HLO under-counts (e.g.
        fused ops); <1 means recompute/overhead."""
        hlo_total = self.flops_per_chip * self.n_chips
        if hlo_total <= 0:
            return None
        return self.model_flops_total / hlo_total

    def as_dict(self) -> Dict:
        return {
            "hardware": self.spec.name,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "wire_bytes_per_chip": self.wire_bytes_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_flops_fraction": self.useful_flops_fraction,
        }


def aggregation_roofline(*, batch: int, n: int, d: int,
                         dtype_bytes: int = 4,
                         spec: Optional[HardwareSpec] = None,
                         n_chips: int = 1) -> Roofline:
    """Roofline of one batched robust-aggregation pass (the Pallas kernels
    of ``repro.kernels``): ``batch`` fused grid lanes, each reducing an
    ``[n, d]`` worker stack to ``[d]``.

    Bytes: one read of every worker stack plus one write of the result —
    the single-pass floor the kernels are built to hit. FLOPs: the bitonic
    compare-exchange network (``sort_network_compares``) at one min+max (2
    flops) per lane-pair per coordinate plus the trimmed-window reduction —
    a deliberate overcount of the cheaper median/pairdist paths, yet still
    memory-bound by orders of magnitude at every shape the engine runs
    (``bottleneck == "memory"``), which is the per-kernel check
    ``benchmarks/bench_kernels.py`` records. Wire bytes are zero: the pass
    is chip-local.
    """
    from repro.kernels.cwtm import sort_network_compares
    n_pad = max(2, 1 << (n - 1).bit_length())
    bytes_moved = batch * (n * d + d) * dtype_bytes
    flops = batch * d * (2 * sort_network_compares(n_pad) + n)
    return Roofline(flops_per_chip=flops / n_chips,
                    hbm_bytes_per_chip=bytes_moved / n_chips,
                    wire_bytes_per_chip=0.0,
                    model_flops_total=flops,
                    n_chips=n_chips,
                    spec=spec if spec is not None else TPU_V5E)


# --------------------------------------------------------------------------
# MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE); decode/prefill
# use 2 * N * D per generated/consumed token.
# --------------------------------------------------------------------------


def count_params(cfg, active_only: bool = False) -> int:
    """Analytic parameter count of the assigned config (embeddings included
    once; MoE counts all experts unless active_only)."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    n = 0
    # embeddings + head
    if cfg.input_kind == "tokens":
        n += cfg.vocab_size * d
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        n += d * cfg.vocab_size

    def attn_params() -> int:
        if cfg.use_mla:
            qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            return (d * cfg.n_heads * qd
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                    + cfg.kv_lora_rank * cfg.n_heads
                    * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        return (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                + cfg.n_heads * hd * d)

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        return mult * d * ff

    def ssm_params() -> int:
        di = cfg.ssm_d_inner
        gn = cfg.ssm_n_groups * cfg.ssm_state
        h = cfg.ssm_n_heads
        return (d * (2 * di + 2 * gn + h) + cfg.ssm_conv_width * (di + 2 * gn)
                + di * d + 3 * h + di)

    fam = cfg.family
    if fam in ("dense", "audio"):
        n += L * (attn_params() + mlp_params(cfg.d_ff))
    elif fam == "moe":
        fk = cfg.first_k_dense
        n += fk * (attn_params() + mlp_params(cfg.d_ff))
        e = cfg.top_k if active_only else cfg.n_experts
        per_layer = attn_params() + e * mlp_params(cfg.d_ff) \
            + cfg.n_shared_experts * mlp_params(cfg.d_ff) + d * cfg.n_experts
        n += (L - fk) * per_layer
    elif fam == "ssm":
        n += L * ssm_params()
    elif fam == "hybrid":
        n += L * ssm_params()
        n += attn_params() + mlp_params(cfg.d_ff)  # ONE shared block
    elif fam == "vlm":
        g = L // cfg.cross_attn_every
        n_self = L - g
        n += n_self * (attn_params() + mlp_params(cfg.d_ff))
        n += g * (attn_params() + mlp_params(cfg.d_ff))  # cross layers
    return n


def model_flops(cfg, shape, active_only_params: Optional[int] = None) -> float:
    """6*N*D for training; 2*N*tokens for inference steps."""
    n_active = active_only_params if active_only_params is not None \
        else count_params(cfg, active_only=(cfg.family == "moe"))
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch
