"""Post-optimization HLO inspection: collective operand/result bytes.

``compiled.cost_analysis()`` does not break out collective traffic, so we
parse the optimized HLO text. For every collective op we record the result
bytes (per participating device) and convert to estimated ICI wire bytes per
chip with the standard ring-algorithm factors:

    all-gather        (N-1)/N * result
    reduce-scatter    (N-1)/N * operand  ~= (N-1) * result
    all-reduce        2 (N-1)/N * result      (reduce-scatter + all-gather)
    all-to-all        (N-1)/N * result
    collective-permute        result

``N`` is taken from the op's replica_groups when present, else the full mesh.
This is an estimate of per-chip traffic for the §Roofline collective term; raw
per-op sums are preserved in the report for re-derivation.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum the byte size of all array shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1),       # operand = n * result
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated collective statistics from one compiled executable."""

    ops: Dict[str, int]              # op kind -> count
    result_bytes: Dict[str, int]     # op kind -> summed per-device result B
    wire_bytes: float                # estimated per-chip ICI bytes
    lines: List[str]                 # raw matched op signatures (debugging)


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    ops: Dict[str, int] = {}
    rbytes: Dict[str, int] = {}
    wire = 0.0
    lines: List[str] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                     r"([a-z\-]+)", s)
        if not m:
            continue
        kind = m.group(2)
        base = None
        for c in _COLLECTIVES:
            if kind == c or kind.startswith(c + "-"):  # e.g. all-reduce-start
                base = c
                break
        if base is None or kind.endswith("-done"):
            continue
        size = _shape_bytes(m.group(1))
        gm = _GROUPS_RE.search(s)
        if gm:
            group = max(1, gm.group(1).count(",") + 1)
        else:
            gm2 = _GROUPS_ALT_RE.search(s)
            group = int(gm2.group(2)) if gm2 else default_group
        ops[base] = ops.get(base, 0) + 1
        rbytes[base] = rbytes.get(base, 0) + size
        wire += _WIRE_FACTOR[base](max(group, 2)) * size
        lines.append(s.split(",")[0][:160])
    return CollectiveStats(ops, rbytes, wire, lines)
