"""Pytree checkpointing to .npz + JSON metadata (orbax is not available
offline; this covers the framework's save/restore contract including the
RoSDHB server state)."""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Any, metadata: Optional[Dict] = None,
         step: Optional[int] = None) -> str:
    """Save a pytree. Returns the checkpoint file path."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    meta = dict(metadata or {})
    if step is not None:
        meta["step"] = step
    with open(path.replace(".npz", "") + ".meta.json", "w") as f:
        json.dump(meta, f, indent=2)
    return path


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    f = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in pth)
        arr = f[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def latest_step(path: str) -> Optional[int]:
    meta = path.replace(".npz", "") + ".meta.json"
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("step")
