"""Adversary subsystem: stateful attack banks + (G, B)-heterogeneity.

Three layers (see each module's docstring):

* ``core``          — the :class:`Adversary` API (``init_attack_state`` /
                      ``step``), the uniformly-shaped :class:`AttackState`
                      slab, and :func:`make_attack_bank` — a ``lax.switch``
                      attack bank selected by a traced index, so mixed
                      stateless/stateful attack grids compile to one
                      program per algorithm bank.
* ``heterogeneity`` — Dirichlet(alpha) label partitioners and the empirical
                      $(G, B)$-gradient-dissimilarity probe.
* ``registry``      — named composed scenarios (attack x heterogeneity x
                      byzantine-fraction) expanded into grid plans for the
                      sweep CLI (``--scenario``).
"""

from repro.adversary.core import (
    ADVERSARIES, AttackState, Adversary, DEFAULT_ATTACK_BANK, KNOWN_ATTACKS,
    attack_index, bank_entry, init_attack_state, is_stateful,
    make_attack_bank, needs_attack_state, static_coeffs,
)
from repro.adversary.heterogeneity import (
    GBEstimate, dirichlet_mnist, dirichlet_proportions, gb_probe,
    label_histograms, label_skew, partition_pool,
)
from repro.adversary.registry import (
    REGISTRY, ScenarioSpec, describe, expand_scenario, get_spec, register,
)

__all__ = [
    "ADVERSARIES", "AttackState", "Adversary", "DEFAULT_ATTACK_BANK",
    "KNOWN_ATTACKS", "attack_index", "bank_entry", "init_attack_state",
    "is_stateful", "make_attack_bank", "needs_attack_state", "static_coeffs",
    "GBEstimate", "dirichlet_mnist", "dirichlet_proportions", "gb_probe",
    "label_histograms", "label_skew", "partition_pool",
    "REGISTRY", "ScenarioSpec", "describe", "expand_scenario", "get_spec",
    "register",
]
