"""First-class, stateful, fusible Byzantine adversaries.

The paper's threat model (omniscient colluding Byzantine workers observing
every honest message) is only interesting when the adversary is allowed to
*remember*: the strongest known attacks against robust aggregation track
statistics of the honest updates across rounds (Karimireddy et al.'s mimic
heuristic, spectral perturbations along the top covariance direction,
bandit-style scale probing).  ``repro.core.attacks`` covers the stateless
mean/std linear family; this module promotes adversaries to first-class
citizens with carried state so they can live inside the fused
``lax.scan``/``vmap`` grid engine of ``repro.core.sweep``.

Adversary API
-------------

An :class:`Adversary` is a named pair

* ``init_state(d) -> AttackState`` (shared :func:`init_attack_state`), and
* ``step(state, honest, f, key, coeffs) -> (state, byz)``,

where ``honest`` is the stacked honest wire payload ``[h, d]``, ``byz`` the
``[f, d]`` Byzantine payload, and ``coeffs`` a ``[2]`` per-attack parameter
vector (traced, so a grid of parameterisations shares one program).

Every adversary carries the same uniformly-shaped :class:`AttackState` slab
(two ``[d]`` vector slots + a small scalar slab + a step counter); attacks
use the slots they need and ignore the rest.  Uniform shapes are what makes
:func:`make_attack_bank` possible: a ``lax.switch`` over attack branches
selected by a *traced* index, mirroring
``repro.core.aggregators.make_aggregator_bank`` — a mixed grid of stateless
AND stateful attacks then compiles to ONE XLA program per algorithm bank
(see ``repro.core.sweep.plan_grid``).

The built-in bank:

* ``linear``     — the stateless mean/std family ``a*mu + b*sd`` (alie,
                   signflip, ipm, foe, zero as coefficient choices).
* ``mimic``      — Karimireddy-He-Jaggi mimic with a *tracked* target: an
                   online power iteration over the centered honest updates
                   maintains the max-variance direction ``z``; all Byzantine
                   workers copy the honest worker most aligned with ``z``.
                   Under heterogeneity this consistently over-represents one
                   honest distribution, which plain i.i.d.-minded defences
                   miss.
* ``gauss``      — honest mean + Gaussian noise (weak baseline; stateless
                   but PRNG-consuming).
* ``spectral``   — adaptive spectral attack: a power iteration *carried
                   across rounds* tracks the top eigenvector ``v`` of the
                   honest update covariance; Byzantine workers send
                   ``mu - scale * sigma_v * v`` — an ALIE-style shift aimed
                   along the direction where the honest spread is widest, so
                   it hides inside the empirical spread while maximally
                   displacing coordinate-blind aggregators.
* ``ipm_greedy`` — epsilon-greedy Inner-Product-Manipulation: two arms
                   (weak scale that slips through filters, strong scale that
                   disrupts when undefended), valued by the observed
                   round-to-round displacement of the honest mean; explores
                   with decaying epsilon, exploits the best arm.

``apply_attack`` in ``repro.core.attacks`` remains the stateless legacy
dispatch; ``repro.core.algorithms.server_round`` routes stateful names (and
``name='bank'``) through this module and threads :class:`AttackState`
through its ``ServerState`` so the whole trajectory — adversary memory
included — stays inside one ``lax.scan``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as A


NUM_SCALARS = 4


class AttackState(NamedTuple):
    """Uniformly-shaped adversary state slab shared by every attack.

    ``vec``:     ``[d]`` direction slot (power-iteration vector of the
                 spectral attack; mimic's alignment direction ``z``).
    ``mu``:      ``[d]`` auxiliary vector slot (previous-round honest mean,
                 used by ``ipm_greedy``'s displacement reward).
    ``scalars``: ``[NUM_SCALARS]`` scalar slab (``ipm_greedy``: arm values
                 0-1, last arm index at 2).
    ``step``:    ``[]`` int32 round counter.
    """

    vec: jnp.ndarray
    mu: jnp.ndarray
    scalars: jnp.ndarray
    step: jnp.ndarray


def init_attack_state(d: int, dtype=jnp.float32) -> AttackState:
    """Zero-initialised :class:`AttackState` for a ``d``-dimensional wire."""
    return AttackState(
        vec=jnp.zeros((d,), dtype),
        mu=jnp.zeros((d,), dtype),
        scalars=jnp.zeros((NUM_SCALARS,), dtype),
        step=jnp.zeros((), jnp.int32),
    )


StepFn = Callable[[AttackState, jnp.ndarray, int, jax.Array, jnp.ndarray],
                  Tuple[AttackState, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Adversary:
    """Named adversary: a step function plus its bank metadata.

    ``step(state, honest, f, key, coeffs) -> (state, byz)`` must preserve
    the :class:`AttackState` structure exactly (same shapes/dtypes) — the
    state is a ``lax.scan`` carry and a ``lax.switch`` branch output.
    """

    name: str
    step: StepFn
    stateful: bool = False
    default_coeffs: Tuple[float, float] = (0.0, 0.0)


def _bump(state: AttackState) -> AttackState:
    return state._replace(step=state.step + 1)


def _broadcast(byz: jnp.ndarray, f: int) -> jnp.ndarray:
    return jnp.broadcast_to(byz, (f,) + byz.shape)


def _linear_step(state, honest, f, key, coeffs):
    """Stateless mean/std family (see ``attacks.linear_attack``)."""
    return _bump(state), A.linear_attack(honest, f, coeffs)


def _gauss_step(state, honest, f, key, coeffs):
    """Honest mean + N(0, coeffs[0]^2) noise — matches ``attacks.gauss``
    bit-for-bit for equal std/key."""
    return _bump(state), A.gauss(honest, f, key, std=coeffs[0])


def _power_step(state, honest):
    """One shared online power-iteration step over the centered honest
    updates: returns ``(mu, centered, v)`` with ``v`` unit-norm, seeded from
    the first centered update at round 0 and sign-aligned with the carried
    vector for cross-round stability."""
    h32 = honest.astype(jnp.float32)
    mu = jnp.mean(h32, axis=0)
    c = h32 - mu
    v_prev = jnp.where(state.step == 0, c[0], state.vec)
    w = c.T @ (c @ v_prev) + 1e-12 * v_prev  # leak keeps degenerate rounds alive
    w = w / (jnp.linalg.norm(w) + 1e-12)
    w = jnp.where(jnp.dot(w, v_prev) < 0, -w, w)
    return mu, c, w


def _mimic_step(state, honest, f, key, coeffs):
    """Tracked-target mimic (Karimireddy et al.): copy the honest worker
    whose centered update projects furthest onto the carried max-variance
    direction ``z`` (absolute projection — eigenvector sign is arbitrary)."""
    _, c, z = _power_step(state, honest)
    target = jnp.argmax(jnp.abs(c @ z))
    byz = honest[target]
    return _bump(state)._replace(vec=z), _broadcast(byz, f)


def _spectral_step(state, honest, f, key, coeffs):
    """Adaptive spectral attack: ALIE-style shift of size ``coeffs[0]``
    honest-spread standard deviations along the carried top covariance
    direction."""
    mu, c, v = _power_step(state, honest)
    sigma = jnp.sqrt(jnp.mean(jnp.square(c @ v)) + 1e-12)
    byz = (mu - coeffs[0] * sigma * v).astype(honest.dtype)
    return _bump(state)._replace(vec=v), _broadcast(byz, f)


def _ipm_greedy_step(state, honest, f, key, coeffs):
    """Epsilon-greedy IPM over two scales ``coeffs = (weak, strong)``.

    The adversary observes every honest message, so it can score its
    previous arm by how far the honest mean moved between rounds (a proxy
    for training disruption), keep running arm values, and pick the better
    scale with decaying exploration.
    """
    h32 = honest.astype(jnp.float32)
    mu = jnp.mean(h32, axis=0)
    reward = jnp.linalg.norm(mu - state.mu)
    last_arm = state.scalars[2].astype(jnp.int32)
    vals = state.scalars[:2]
    vals = jnp.where(state.step > 0,
                     vals + 0.2 * (reward - vals) * jax.nn.one_hot(last_arm, 2),
                     vals)
    k_explore, k_arm = jax.random.split(key)
    eps_t = 1.0 / (1.0 + 0.1 * state.step.astype(jnp.float32))
    explore = jax.random.bernoulli(k_explore, eps_t)
    rand_arm = jax.random.bernoulli(k_arm, 0.5).astype(jnp.int32)
    arm = jnp.where(explore, rand_arm, jnp.argmax(vals).astype(jnp.int32))
    scale = jnp.where(arm == 0, coeffs[0], coeffs[1])
    byz = (-scale * mu).astype(honest.dtype)
    scalars = jnp.stack([vals[0], vals[1], arm.astype(jnp.float32),
                         state.scalars[3]])
    new = _bump(state)._replace(mu=mu, scalars=scalars)
    return new, _broadcast(byz, f)


#: The adversary registry. ``linear`` covers the whole stateless mean/std
#: family via coefficients; the rest are the stateful/stochastic attacks.
ADVERSARIES = {
    "linear": Adversary("linear", _linear_step, stateful=False),
    "mimic": Adversary("mimic", _mimic_step, stateful=True),
    "gauss": Adversary("gauss", _gauss_step, stateful=False,
                       default_coeffs=(1.0, 0.0)),
    "spectral": Adversary("spectral", _spectral_step, stateful=True,
                          default_coeffs=(1.5, 0.0)),
    "ipm_greedy": Adversary("ipm_greedy", _ipm_greedy_step, stateful=True,
                            default_coeffs=(0.5, 5.0)),
}

#: Default branch order of the full attack bank.
DEFAULT_ATTACK_BANK: Tuple[str, ...] = ("linear", "mimic", "gauss",
                                        "spectral", "ipm_greedy")

#: Attack names accepted by ``AttackConfig``/the sweep CLI. ``linear`` and
#: ``bank`` are engine-internal (their parameters arrive as traced data) and
#: are deliberately NOT valid grid-scenario names.
KNOWN_ATTACKS: Tuple[str, ...] = (
    "none", "alie", "signflip", "ipm", "foe", "zero",
    "mimic", "gauss", "spectral", "ipm_greedy")


def is_stateful(name: str) -> bool:
    a = ADVERSARIES.get(name)
    return a is not None and a.stateful


def needs_attack_state(attack_name: str, f: int) -> bool:
    """Whether a config needs the :class:`AttackState` slab in its server
    state — THE single predicate shared by ``algorithms.init_state`` and the
    launch path's abstract input specs (``launch.steps``), so the real
    pytree and the jit-lowering specs can never diverge."""
    if f == 0 or attack_name == "none":
        return False
    return attack_name == "bank" or is_stateful(attack_name)


def bank_entry(cfg: "A.AttackConfig", n: int, f: int
               ) -> Optional[Tuple[str, Tuple[float, float]]]:
    """Map an :class:`attacks.AttackConfig` onto its attack-bank branch.

    Returns ``(branch_name, coeffs)`` — the branch of
    :data:`DEFAULT_ATTACK_BANK` executing ``cfg`` and the ``[2]`` parameter
    vector reproducing it — or ``None`` when the attack cannot join a bank
    (``none``, and the engine-internal ``linear``/``bank`` whose parameters
    are traced, not named).
    """
    coeffs = A.linear_coeffs(cfg, n, f)
    if coeffs is not None:
        return ("linear", coeffs)
    if cfg.name == "mimic":
        return ("mimic", (0.0, 0.0))
    if cfg.name == "gauss":
        return ("gauss", (cfg.scale or 1.0, 0.0))
    if cfg.name == "spectral":
        return ("spectral", (cfg.scale or 1.5, 0.0))
    if cfg.name == "ipm_greedy":
        return ("ipm_greedy", (cfg.scale or 0.5, 5.0))
    return None


def static_coeffs(cfg: "A.AttackConfig", n: int, f: int) -> jnp.ndarray:
    """The ``[2]`` coefficient vector of a *statically configured* attack
    (the per-scenario, non-bank path)."""
    entry = bank_entry(cfg, n, f)
    if entry is None:
        raise ValueError(f"attack {cfg.name!r} has no bank entry")
    return jnp.asarray(entry[1], jnp.float32)


def attack_index(name: str,
                 entries: Optional[Sequence[str]] = None) -> int:
    """Branch index of adversary ``name`` inside ``entries`` (default the
    full :data:`DEFAULT_ATTACK_BANK`)."""
    entries = tuple(entries) if entries is not None else DEFAULT_ATTACK_BANK
    try:
        return entries.index(name)
    except ValueError:
        raise ValueError(
            f"adversary {name!r} is not a branch of the attack bank "
            f"{entries}") from None


BankStepFn = Callable[
    [AttackState, jnp.ndarray, jax.Array, jnp.ndarray, jnp.ndarray],
    Tuple[AttackState, jnp.ndarray]]


def make_attack_bank(entries: Sequence[str], f: int) -> BankStepFn:
    """Build the switch-based attack bank ``step(state, honest, key, idx,
    coeffs) -> (state, byz)``.

    A ``lax.switch`` over uniformly-shaped adversary branches (every branch
    maps the shared :class:`AttackState` slab + ``[h, d]`` honest payload to
    the same slab + ``[f, d]`` Byzantine payload), selected by the *traced*
    integer ``idx`` — so the attack choice is data and a mixed
    stateless/stateful attack grid joins the one-program fusion axis of
    ``repro.core.sweep``.  ``f`` is static across branches (a fused bank
    requires every grid cell to share it).  As with the aggregator bank,
    under ``vmap`` a switch on per-lane indices computes every branch per
    lane — keep ``entries`` restricted to the attacks the grid uses.
    """
    entries = tuple(entries)
    unknown = [e for e in entries if e not in ADVERSARIES]
    if unknown:
        raise ValueError(
            f"unknown attack-bank entries {unknown} (known adversaries: "
            f"{'|'.join(ADVERSARIES)})")
    if not entries:
        raise ValueError("attack bank needs at least one entry")
    branches = tuple(
        (lambda step: lambda st, h, k, c: step(st, h, f, k, c))(
            ADVERSARIES[e].step)
        for e in entries)

    def apply(state: AttackState, honest: jnp.ndarray, key: jax.Array,
              idx: jnp.ndarray, coeffs: jnp.ndarray
              ) -> Tuple[AttackState, jnp.ndarray]:
        if len(branches) == 1:
            return branches[0](state, honest, key, coeffs)
        return jax.lax.switch(idx, branches, state, honest, key, coeffs)

    return apply
