"""Named adversarial-scenario registry: attack x heterogeneity x byz-fraction.

"As many scenarios as you can imagine" needs names, not flag soup.  A
:class:`ScenarioSpec` composes the three adversarial axes —

* **attack**: any mix of stateless (alie/signflip/ipm/foe/zero) and
  stateful (mimic/gauss/spectral/ipm_greedy) adversaries,
* **heterogeneity**: the Dirichlet(alpha) label split of the testbed
  (``alpha_het=None`` = i.i.d.; see ``repro.adversary.heterogeneity``),
* **byzantine fraction**: one or more ``f`` values at fixed total worker
  count ``n_workers`` (fixed ``n`` keeps one stacked batch pytree per run),

plus the aggregator/algorithm grid, and expands into labelled
``repro.core.sweep.Scenario`` cells that ``plan_grid`` fuses into
one-program banks.  The *algorithm* axis fuses too (the ``lax.switch``
algorithm bank over the unified server state, ``repro.core.algorithms``),
so Table-1-style algo x attack x aggregator compositions — ``table1``,
``table1-mini``, ``table1-cross-algo`` — compile to literally ONE XLA
program.  The sweep CLI exposes the registry as ``--scenario NAME`` /
``--list-scenarios``:

    PYTHONPATH=src python -m repro.core.sweep --scenario mixed-attacks

Register project-specific compositions with :func:`register`; unknown
names raise ``ValueError`` listing everything known.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.sweep import Scenario, grid_scenarios


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named composed scenario (attack x heterogeneity x byz-fraction).

    Attributes:
      name: registry key (also the label prefix of every expanded cell).
      description: one line for ``--list-scenarios``.
      algos/attacks/aggregators: the grid axes (see
        ``sweep.grid_scenarios``).
      byz_f: Byzantine counts to sweep at fixed ``n_workers``; multi-valued
        specs tag each cell's label with ``f<k>``.
      n_workers: total worker count n (honest = n - f per cell).
      ratio: sparsifier keep-ratio.
      gamma: learning rate.
      alpha_het: Dirichlet concentration of the data split; ``None`` =
        i.i.d.  Applied by the CLI when building the testbed (quadratic
        testbeds ignore it — their heterogeneity is the target spread).
      testbed: ``quadratic`` | ``mnist`` | ``transformer`` — the testbed
        the CLI should use (``transformer`` = reduced ``stablelm_3b``
        causal LM on synthetic token streams; pairs with ``--stream``).
    """

    name: str
    description: str
    algos: Tuple[str, ...] = ("rosdhb",)
    attacks: Tuple[str, ...] = ("alie",)
    aggregators: Tuple[str, ...] = ("cwtm",)
    byz_f: Tuple[int, ...] = (3,)
    n_workers: int = 13
    ratio: float = 0.1
    gamma: float = 0.05
    alpha_het: Optional[float] = None
    testbed: str = "quadratic"

    def expand(self) -> List[Scenario]:
        """Expand into labelled grid cells (``<name>[/f<k>]/<algo>/<attack>/
        <agg>``), one ``grid_scenarios`` product per Byzantine count."""
        out: List[Scenario] = []
        for f in self.byz_f:
            if not 0 <= f < self.n_workers:
                raise ValueError(
                    f"scenario {self.name!r}: byz_f={f} outside "
                    f"[0, n_workers={self.n_workers})")
            cells = grid_scenarios(
                self.algos, self.attacks, self.aggregators,
                n_honest=self.n_workers - f, f=f, ratio=self.ratio,
                gamma=self.gamma)
            tag = f"f{f}/" if len(self.byz_f) > 1 else ""
            out += [dataclasses.replace(sc,
                                        label=f"{self.name}/{tag}{sc.label}")
                    for sc in cells]
        return out


REGISTRY: Dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    """Add ``spec`` to the registry (last registration wins on name)."""
    REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> ScenarioSpec:
    """Look up a named scenario; unknown names list everything known."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario: {name!r} (known scenarios: "
            f"{', '.join(sorted(REGISTRY))})") from None


def expand_scenario(name: str) -> List[Scenario]:
    return get_spec(name).expand()


def describe() -> str:
    width = max((len(n) for n in REGISTRY), default=0)
    return "\n".join(f"{s.name:<{width}}  {s.description}"
                     for s in REGISTRY.values())


for _spec in (
    ScenarioSpec(
        "fig1-alie",
        "paper Fig. 1: RoSDHB vs ALIE under CWTM+NNM, f=3 of 13",
        attacks=("alie",)),
    ScenarioSpec(
        "stateless-linear",
        "the full mean/std attack family x 3 robust rules (one fused bank)",
        attacks=("alie", "signflip", "ipm", "foe", "zero"),
        aggregators=("cwtm", "median", "geomed")),
    ScenarioSpec(
        "stateful-core",
        "the stateful adversaries (tracked mimic, spectral, eps-greedy IPM)"
        " + gauss baseline under CWTM+NNM",
        attacks=("mimic", "gauss", "spectral", "ipm_greedy")),
    ScenarioSpec(
        "mixed-attacks",
        "acceptance grid: 6 stateless+stateful attacks x 3 aggregators,"
        " ONE compiled program",
        attacks=("alie", "signflip", "foe", "mimic", "gauss", "spectral"),
        aggregators=("cwtm", "median", "geomed")),
    ScenarioSpec(
        "byz-fraction",
        "ALIE at f = 1..4 of n = 13 (byzantine-fraction axis, fixed n)",
        attacks=("alie",), byz_f=(1, 2, 3, 4)),
    ScenarioSpec(
        "table1-cross-algo",
        "all four algorithms x {alie, foe}: the Table-1-style comparison"
        " (ONE compiled program via the algorithm bank)",
        algos=("rosdhb", "dasha", "robust_dgd", "dgd"),
        attacks=("alie", "foe")),
    ScenarioSpec(
        "table1",
        "the full Table-1 grid: 4 algorithms x 3 attacks x 2 robust rules,"
        " fused into ONE compiled cross-algorithm program",
        algos=("rosdhb", "dasha", "robust_dgd", "dgd"),
        attacks=("alie", "foe", "signflip"),
        aggregators=("cwtm", "median")),
    ScenarioSpec(
        "table1-mini",
        "quickstart-sized Table-1 cut: 4 algorithms x {alie, foe} x"
        " CWTM+NNM, 2 of 10 workers Byzantine, as one program"
        " (examples/quickstart.py)",
        algos=("rosdhb", "dasha", "robust_dgd", "dgd"),
        attacks=("alie", "foe"), byz_f=(2,), n_workers=10),
    ScenarioSpec(
        "mimic-dirichlet01",
        "tracked mimic + alie on a strongly heterogeneous Dirichlet(0.1)"
        " MNIST split (mimic's favourite regime)",
        attacks=("mimic", "alie"), alpha_het=0.1, testbed="mnist"),
    ScenarioSpec(
        "mimic-dirichlet1",
        "tracked mimic + alie on a mildly heterogeneous Dirichlet(1.0)"
        " MNIST split",
        attacks=("mimic", "alie"), alpha_het=1.0, testbed="mnist"),
    ScenarioSpec(
        "mimic-iid",
        "tracked mimic + alie on the i.i.d. MNIST split (control for the"
        " dirichlet variants)",
        attacks=("mimic", "alie"), testbed="mnist"),
    ScenarioSpec(
        "chaos-serve",
        "the chaos-harness serving cell: RoSDHB vs ALIE under CWTM+NNM,"
        " f=3 of 13 — pair with a repro.serve.chaos scenario"
        " (python -m repro.serve --chaos combined)",
        attacks=("alie",)),
    ScenarioSpec(
        "transformer-table1",
        "Table-1 cut on a reduced stablelm_3b LM: rosdhb + robust_dgd x"
        " {alie, signflip} x CWTM+NNM, streamed from the prefetched ring"
        " buffer (run with --testbed transformer --stream)",
        algos=("rosdhb", "robust_dgd"),
        attacks=("alie", "signflip"),
        byz_f=(2,), n_workers=9, ratio=0.1, gamma=0.1,
        testbed="transformer"),
):
    register(_spec)
