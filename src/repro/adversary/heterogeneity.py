"""(G, B)-gradient-dissimilarity: controlling and measuring heterogeneity.

The paper's convergence guarantees are stated under the
$(G, B)$-gradient-dissimilarity model (its Assumption on heterogeneity):

    (1/h) sum_i ||grad f_i(x) - grad f(x)||^2  <=  G^2 + B^2 ||grad f(x)||^2

for all x, where f is the honest average loss.  Robustness claims are only
meaningful when heterogeneity is *controlled* — stateful attacks like mimic
specifically exploit inter-worker dissimilarity — so this module provides
both directions:

* **control**: Dirichlet(alpha) label partitioners for the synthetic
  MNIST-like dataset (``repro.data.SyntheticMNIST`` draws per-worker label
  proportions from Dirichlet(alpha); :func:`partition_pool` additionally
  splits a pooled labelled dataset class-by-class with Dirichlet weights —
  the standard federated non-i.i.d. protocol).  ``alpha -> inf`` recovers
  the i.i.d. split; ``alpha ~ 0.1`` gives near-single-class workers.
* **measurement**: an empirical probe (:func:`gb_probe`) that evaluates
  per-worker gradients at randomly perturbed parameter points and fits the
  smallest ``(G^2, B^2)`` intercept/slope explaining the observed
  dissimilarity-vs-||grad f||^2 scatter.

Label-skew summary helpers (:func:`label_histograms`, :func:`label_skew`)
quantify how non-i.i.d. a realised split is: skew is the mean total
variation distance between each worker's label histogram and the pooled
mix, monotone in 1/alpha.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import tree as T


def dirichlet_proportions(rng: np.random.Generator, n_workers: int,
                          n_classes: int, alpha: float) -> np.ndarray:
    """Per-worker label proportions ``[n_workers, n_classes]`` drawn from
    Dirichlet(alpha) (large alpha -> uniform/homogeneous)."""
    return rng.dirichlet([alpha] * n_classes, size=n_workers)


def partition_pool(rng: np.random.Generator, labels: np.ndarray,
                   n_workers: int, alpha: float) -> List[np.ndarray]:
    """Dirichlet label partition of a pooled dataset.

    The standard federated non-i.i.d. protocol (Hsu et al.): for each class,
    shuffle its sample indices and split them among workers with
    Dirichlet(alpha) weights.  Returns one index array per worker; every
    pool index is assigned to exactly one worker.
    """
    labels = np.asarray(labels)
    out: List[list] = [[] for _ in range(n_workers)]
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        w = rng.dirichlet([alpha] * n_workers)
        cuts = (np.cumsum(w)[:-1] * len(idx)).astype(np.int64)
        for worker, part in enumerate(np.split(idx, cuts)):
            out[worker].extend(part.tolist())
    return [np.asarray(o, np.int64) for o in out]


def label_histograms(labels: np.ndarray, n_classes: int) -> np.ndarray:
    """Normalised per-worker label histograms ``[n_workers, n_classes]``
    from stacked worker labels ``[n_workers, m]``."""
    labels = np.asarray(labels)
    hists = np.stack([np.bincount(row, minlength=n_classes)
                      for row in labels]).astype(np.float64)
    return hists / np.maximum(hists.sum(axis=1, keepdims=True), 1.0)


def label_skew(hists: np.ndarray) -> float:
    """Mean total-variation distance between each worker's label histogram
    and the pooled mix — 0 for i.i.d. splits, -> (n-1)/n for single-class
    workers; monotone in 1/alpha under Dirichlet partitions."""
    hists = np.asarray(hists, np.float64)
    pooled = hists.mean(axis=0)
    return float(0.5 * np.abs(hists - pooled).sum(axis=-1).mean())


def dirichlet_mnist(n_workers: int = 10, alpha: Optional[float] = None,
                    per_worker: int = 800, seed: int = 0, **kwargs):
    """``SyntheticMNIST`` with a Dirichlet(alpha) label split (``None`` =
    i.i.d.); the dataset exposes the realised proportions as
    ``ds.label_props``."""
    from repro.data import SyntheticMNIST
    return SyntheticMNIST(
        n_workers=n_workers, per_worker=per_worker, seed=seed,
        alpha_het=(1e6 if alpha is None else alpha), **kwargs)


@dataclasses.dataclass(frozen=True)
class GBEstimate:
    """Empirical $(G, B)$-dissimilarity fit.

    ``dissimilarity[k]`` is ``(1/h) sum_i ||g_i - gbar||^2`` and
    ``grad_sq[k]`` is ``||gbar||^2`` at probe point k; ``G``/``B`` are the
    nonnegative least-squares intercept/slope of the first on the second
    (in the paper's units: ``dissimilarity <= G^2 + B^2 grad_sq``).
    """

    G: float
    B: float
    dissimilarity: np.ndarray
    grad_sq: np.ndarray


def gb_probe(loss_fn: Callable[[Any, Any], jnp.ndarray], params0: Any,
             worker_batches: Any, *, f: int = 0, n_probes: int = 8,
             radius: float = 0.5, seed: int = 0) -> GBEstimate:
    """Empirically probe the $(G, B)$-dissimilarity of a worker split.

    Evaluates per-worker gradients of ``loss_fn`` at ``params0`` plus
    ``n_probes - 1`` Gaussian perturbations of scale ``radius``, drops the
    first ``f`` (Byzantine) workers, and fits ``dissimilarity = G^2 +
    B^2 * ||grad f||^2`` by nonnegative least squares over the probe
    points.  ``worker_batches`` is a stacked per-worker batch pytree with
    leading dim ``n_workers`` (one round's batches).
    """
    if n_probes < 2:
        raise ValueError("gb_probe needs at least 2 probe points")
    spec = T.make_flat_spec(params0)
    flat0 = T.tree_ravel(params0, spec)
    deltas = radius * jax.random.normal(
        jax.random.PRNGKey(seed), (n_probes - 1, flat0.shape[0]), flat0.dtype)
    points = jnp.concatenate([flat0[None], flat0[None] + deltas], axis=0)

    def probe(flat):
        params = T.tree_unravel(flat, spec)
        grads = jax.vmap(
            lambda b: T.tree_ravel(jax.grad(loss_fn)(params, b), spec)
        )(worker_batches)
        g = grads[f:]
        gbar = jnp.mean(g, axis=0)
        v = jnp.mean(jnp.sum(jnp.square(g - gbar[None]), axis=-1))
        return v, jnp.sum(jnp.square(gbar))

    v, s = jax.jit(jax.vmap(probe))(points)
    v = np.asarray(v, np.float64)
    s = np.asarray(s, np.float64)
    # least-squares slope/intercept with matching (population) normalisation
    # in numerator and denominator
    var_s = float(np.mean(np.square(s - s.mean())))
    cov_sv = float(np.mean((s - s.mean()) * (v - v.mean())))
    b2 = max(0.0, cov_sv / var_s) if var_s > 1e-12 else 0.0
    g2 = max(0.0, float(v.mean() - b2 * s.mean()))
    return GBEstimate(G=float(np.sqrt(g2)), B=float(np.sqrt(b2)),
                      dissimilarity=v, grad_sq=s)
