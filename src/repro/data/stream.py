"""Streaming batch pipeline: host prefetch thread -> fixed-depth ring buffer.

The scan engine's legacy input contract materialises the ENTIRE batch
schedule host-side as one ``[steps, n_workers, ...]`` pytree
(``core.simulator.stack_batches``) before the rollout starts — O(steps)
host memory, which caps trajectories at MNIST-CNN scale. This module
replaces that with a bounded producer/consumer pipeline:

* a **prefetch thread** calls ``batch_fn(t)`` ahead of the consumer,
  stacks ``chunk_size`` rounds into one chunk, and hands each chunk to the
  device with its own ``jax.device_put`` — the host-side numpy copy dies
  as soon as the transfer completes;
* a **fixed-depth ring buffer** (a bounded queue of device-resident
  chunks) decouples the two sides: the producer blocks when
  ``prefetch_depth`` chunks are waiting, so peak residency is
  O(prefetch_depth) chunks regardless of trajectory length.

``Simulator.rollout_streaming`` consumes the buffer ``prefetch_depth``
chunks at a time inside one jitted ``lax.while_loop``-over-scan-chunks
program (early exit between chunks); ``repro.launch`` consumers drive a
chunked pjit train step the same way. The producer side is deliberately
framework-free — any ``batch_fn(t) -> pytree`` works, including the
stateful ``data.BatchFn`` (chunks are built in strict step order).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, List, Optional

import jax
import numpy as np

__all__ = ["ChunkPrefetcher", "StackedChunkSource", "batch_bytes",
           "stack_chunk", "split_chunks"]


def batch_bytes(batch: Any) -> int:
    """Total leaf bytes of one batch pytree (numpy or jax leaves)."""
    return int(sum(np.asarray(l).nbytes
                   for l in jax.tree_util.tree_leaves(batch)))


def stack_chunk(batch_fn: Callable[[int], Any], start: int,
                length: int) -> Any:
    """Materialise ``length`` consecutive batches stacked on a leading round
    axis — ONE chunk of the stream (host-side, numpy)."""
    rows = [batch_fn(t) for t in range(start, start + length)]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *rows)


def split_chunks(batches: Any, chunk_size: int) -> List[Any]:
    """Slice a pre-stacked ``[steps, ...]`` pytree into full ``chunk_size``
    chunks (the tail remainder is NOT included — callers handle it with the
    fixed-length path)."""
    steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
    n_chunks = steps // chunk_size
    return [jax.tree_util.tree_map(
        lambda l: l[c * chunk_size:(c + 1) * chunk_size], batches)
        for c in range(n_chunks)]


class StackedChunkSource:
    """Chunk source over a pre-stacked ``[steps, ...]`` pytree — the same
    ``take(k)`` contract as :class:`ChunkPrefetcher`, but chunks are sliced
    from the given array and device-put one at a time (no thread). Used by
    parity tests to feed BOTH the materialised and streaming paths from one
    identical array."""

    def __init__(self, batches: Any, steps: int, chunk_size: int,
                 device: Optional[Any] = None):
        self.chunk_size = chunk_size
        self.n_chunks = steps // chunk_size
        self.remainder = steps % chunk_size
        self._batches = batches
        self._device = device
        self._taken = 0
        self.chunk_bytes = 0
        self.high_water_chunks = 0
        self.high_water_bytes = 0

    def take(self, k: int, timeout: float = 0.0) -> List[Any]:
        want = min(k, self.n_chunks - self._taken)
        out: List[Any] = []
        for _ in range(max(0, want)):
            c = self._taken
            host = jax.tree_util.tree_map(
                lambda l: l[c * self.chunk_size:(c + 1) * self.chunk_size],
                self._batches)
            if not self.chunk_bytes:
                self.chunk_bytes = batch_bytes(host)
            out.append(jax.device_put(host, self._device)
                       if self._device is not None
                       else jax.device_put(host))
            self._taken += 1
        self.high_water_chunks = max(self.high_water_chunks, len(out))
        self.high_water_bytes = self.high_water_chunks * self.chunk_bytes
        return out

    def close(self) -> None:
        pass


class ChunkPrefetcher:
    """Host prefetch thread filling a fixed-depth ring buffer of device chunks.

    Args:
      batch_fn: ``batch_fn(t) -> pytree`` of per-worker batches for round t.
        Called strictly in step order on the producer thread (stateful
        ``data.BatchFn`` implementations reproduce the materialised stream).
      steps: total rounds to produce (``start .. start + steps - 1``).
      chunk_size: rounds per chunk (the scan length of one chunk program).
      prefetch_depth: ring-buffer depth — at most this many chunks are ever
        resident beyond the one being built, so host/producer memory is
        O(prefetch_depth * chunk_bytes) instead of O(steps * batch_bytes).
      start: first round index.
      device: optional ``jax.Device`` / ``Sharding`` for the per-chunk
        ``device_put`` handoff (default device when None).

    Attributes (after the first chunk):
      chunk_bytes: bytes of one device-put chunk.
      high_water_chunks / high_water_bytes: peak resident chunks/bytes
        observed on the producer side (queued + one in flight) — the number
        the O(prefetch_depth) claim is gated on in benchmarks/bench_llm.py.
    """

    def __init__(self, batch_fn: Callable[[int], Any], steps: int,
                 chunk_size: int, prefetch_depth: int = 4, start: int = 0,
                 device: Optional[Any] = None):
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        if prefetch_depth <= 0:
            raise ValueError(
                f"prefetch_depth must be positive, got {prefetch_depth}")
        self.chunk_size = chunk_size
        self.prefetch_depth = prefetch_depth
        self.n_chunks = steps // chunk_size
        self.remainder = steps % chunk_size
        self._batch_fn = batch_fn
        self._start = start
        self._device = device
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=prefetch_depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self.chunk_bytes = 0
        self.high_water_chunks = 0
        self.high_water_bytes = 0
        self._taken = 0
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="repro-chunk-prefetch")
        self._thread.start()

    # ------------------------------------------------------------------ #
    # producer thread
    # ------------------------------------------------------------------ #

    def _produce(self) -> None:
        try:
            for c in range(self.n_chunks):
                if self._stop.is_set():
                    return
                host = stack_chunk(self._batch_fn,
                                   self._start + c * self.chunk_size,
                                   self.chunk_size)
                if not self.chunk_bytes:
                    self.chunk_bytes = batch_bytes(host)
                chunk = (jax.device_put(host, self._device)
                         if self._device is not None
                         else jax.device_put(host))
                del host  # the host copy dies with the transfer
                queued = False
                while not self._stop.is_set():
                    try:
                        self._q.put(chunk, timeout=0.05)
                        queued = True
                        break
                    except queue.Full:
                        continue
                if not queued:  # consumer closed early
                    return
                # queued chunks + the one about to be built next
                resident = self._q.qsize() + 1
                self.high_water_chunks = max(self.high_water_chunks, resident)
                self.high_water_bytes = self.high_water_chunks \
                    * self.chunk_bytes
        except BaseException as e:  # surfaced to the consumer in take()
            self._error = e

    # ------------------------------------------------------------------ #
    # consumer side
    # ------------------------------------------------------------------ #

    def take(self, k: int, timeout: float = 120.0) -> List[Any]:
        """Block for up to ``min(k, chunks remaining)`` device chunks, in
        stream order. Returns ``[]`` once the stream is exhausted."""
        want = min(k, self.n_chunks - self._taken)
        out: List[Any] = []
        for _ in range(max(0, want)):
            deadline = timeout
            while True:
                if self._error is not None:
                    raise RuntimeError(
                        "ChunkPrefetcher producer thread failed"
                    ) from self._error
                try:
                    out.append(self._q.get(timeout=0.05))
                    break
                except queue.Empty:
                    deadline -= 0.05
                    if deadline <= 0:
                        raise TimeoutError(
                            f"prefetch thread produced nothing for "
                            f"{timeout}s (chunk {self._taken + len(out)}"
                            f"/{self.n_chunks})")
            self._taken += 1
        return out

    def close(self) -> None:
        """Stop the producer (early exit): drain the queue so a blocked
        ``put`` wakes up, then join the thread."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    def __enter__(self) -> "ChunkPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
