"""Synthetic datasets (the container is offline — no downloads).

Two generators:
  * token streams for the LLM-scale examples;
  * a class-separable MNIST-like image dataset for the paper-faithful
    reproduction: each class has a smooth random 28x28 prototype; samples are
    prototype + Gaussian noise, so a ~12k-parameter CNN can reach >=85%
    accuracy (the paper's tau) within a few hundred rounds, mirroring the
    paper's experimental regime.

Heterogeneity across workers is controlled with a Dirichlet(alpha_het) label
partition (alpha -> inf reproduces the paper's random-permutation split).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def synthetic_token_batch(rng: np.random.Generator, n_workers: int,
                          local_batch: int, seq_len: int,
                          vocab: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream (learnable bigram structure)."""
    base = rng.integers(0, vocab, size=(n_workers, local_batch, seq_len))
    # inject predictable structure: every other token repeats its neighbor
    base[..., 1::2] = (base[..., 0::2] + 1) % vocab
    return {"tokens": base.astype(np.int32)}


@dataclasses.dataclass
class SyntheticMNIST:
    """Class-separable image dataset, partitioned across workers."""

    n_workers: int = 10
    per_worker: int = 6000
    n_classes: int = 10
    noise: float = 0.35
    alpha_het: float = 1e6  # Dirichlet concentration; large = homogeneous
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # smooth prototypes: low-frequency random fields
        protos = []
        for _ in range(self.n_classes):
            low = rng.normal(size=(7, 7))
            img = np.kron(low, np.ones((4, 4)))  # 28x28 blocks
            img = (img - img.min()) / (np.ptp(img) + 1e-9)
            protos.append(img.astype(np.float32))
        self.prototypes = np.stack(protos)  # [10, 28, 28]

        # label proportions per worker: the Dirichlet(alpha) split of
        # repro.adversary.heterogeneity (exposed as label_props so the
        # (G, B)-dissimilarity probes can correlate skew with gradients)
        props = rng.dirichlet([self.alpha_het] * self.n_classes,
                              size=self.n_workers)
        self.label_props = props
        self.images = np.zeros((self.n_workers, self.per_worker, 28, 28, 1),
                               np.float32)
        self.labels = np.zeros((self.n_workers, self.per_worker), np.int32)
        for w in range(self.n_workers):
            counts = rng.multinomial(self.per_worker, props[w])
            labels = np.repeat(np.arange(self.n_classes), counts)
            rng.shuffle(labels)
            noise = rng.normal(scale=self.noise,
                               size=(self.per_worker, 28, 28)).astype(np.float32)
            self.images[w, :, :, :, 0] = self.prototypes[labels] + noise
            self.labels[w] = labels

        # held-out eval set (drawn iid from the same distribution)
        n_eval = 2000
        elabels = rng.integers(0, self.n_classes, n_eval)
        enoise = rng.normal(scale=self.noise, size=(n_eval, 28, 28)
                            ).astype(np.float32)
        self.eval_images = (self.prototypes[elabels] + enoise)[..., None]
        self.eval_labels = elabels.astype(np.int32)
        self._rng = rng

    def worker_batches(self, batch_size: int) -> "BatchFn":
        return BatchFn(self, batch_size)

    @property
    def eval_batch(self) -> Dict[str, np.ndarray]:
        return {"images": self.eval_images, "labels": self.eval_labels}


class BatchFn:
    """Callable ``batch_fn(step) -> stacked per-worker batches`` for the
    simulator (deterministic given the dataset seed)."""

    def __init__(self, ds: SyntheticMNIST, batch_size: int):
        self.ds = ds
        self.bs = batch_size
        self.rng = np.random.default_rng(ds.seed + 1)

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        idx = self.rng.integers(0, self.ds.per_worker,
                                size=(self.ds.n_workers, self.bs))
        take = np.take_along_axis
        imgs = np.stack([self.ds.images[w, idx[w]]
                         for w in range(self.ds.n_workers)])
        labs = np.stack([self.ds.labels[w, idx[w]]
                         for w in range(self.ds.n_workers)])
        return {"images": imgs, "labels": labs}
