from repro.data.synthetic import SyntheticMNIST, BatchFn, synthetic_token_batch

__all__ = ["SyntheticMNIST", "BatchFn", "synthetic_token_batch"]
