from repro.data.synthetic import SyntheticMNIST, BatchFn, synthetic_token_batch
from repro.data.stream import (
    ChunkPrefetcher, batch_bytes, split_chunks, stack_chunk,
)

__all__ = ["SyntheticMNIST", "BatchFn", "synthetic_token_batch",
           "ChunkPrefetcher", "batch_bytes", "split_chunks", "stack_chunk"]
