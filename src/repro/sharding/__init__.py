from repro.sharding.partitioning import (
    param_specs,
    param_shardings,
    batch_spec,
    bank_spec,
    server_axes,
    constrain_activation,
    cache_spec,
    cache_shardings,
    dp_axes,
    all_axes,
    n_workers,
    sweep_mesh,
    grid_sharding,
    replicated_sharding,
)

__all__ = [
    "param_specs", "param_shardings", "batch_spec", "bank_spec", "server_axes", "constrain_activation",
    "cache_spec", "cache_shardings", "dp_axes", "all_axes", "n_workers",
    "sweep_mesh", "grid_sharding", "replicated_sharding",
]
