from repro.sharding.partitioning import (
    param_specs,
    param_shardings,
    batch_spec,
    bank_spec,
    server_axes,
    constrain_activation,
    cache_spec,
    cache_shardings,
    dp_axes,
    all_axes,
    n_workers,
)

__all__ = [
    "param_specs", "param_shardings", "batch_spec", "bank_spec", "server_axes", "constrain_activation",
    "cache_spec", "cache_shardings", "dp_axes", "all_axes", "n_workers",
]
