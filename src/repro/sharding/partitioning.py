"""PartitionSpec rules for the production mesh.

Axis semantics (DESIGN §3):
  pod   — pods in the multi-pod mesh (data-parallel across pods)
  data  — data parallelism; its groups ARE the RoSDHB workers
  model — tensor / expert parallelism

Rules are matched on the flattened parameter path. Every sharding decision is
guarded by divisibility: if a dim does not divide evenly over the requested
axis, the axis is dropped (GSPMD would handle uneven shards, but even shards
keep the collective schedule predictable — and the dry-run honest).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_workers(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0 and dim >= size


def _spec(mesh: Mesh, shape: Sequence[int], *axes) -> P:
    """Build a PartitionSpec, dropping axes that don't divide their dim."""
    out = []
    for dim, ax in zip(shape, axes):
        out.append(ax if (ax is not None and _fits(dim, mesh, ax)) else None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(path: str, shape: Sequence[int], mesh: Mesh,
               fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf (trailing dims = logical shape;
    extra leading dims are stacked layer/group dims, replicated)."""
    fs = "data" if fsdp else None
    nd = len(shape)

    def with_lead(rule_ndim: int, *axes) -> P:
        lead = nd - rule_ndim
        spec = _spec(mesh, shape[lead:], *axes)
        return P(*([None] * lead + list(spec)))

    name = path.split("/")[-1]  # 'w' | 'b' | 'scale' | tensor name
    parent = path.split("/")[-2] if "/" in path else ""

    # --- embeddings / head ---
    if name == "embed":
        return with_lead(2, "model", fs)
    if name == "lm_head":
        return with_lead(2, fs, "model")

    # --- MoE expert banks [E, din, dout] ---
    if parent and path.split("/")[-3:-1] and "moe" in path.split("/"):
        if name in ("wi", "wg"):
            return with_lead(3, "model", fs, None)
        if name == "wo":
            return with_lead(3, "model", None, fs)

    # --- dense-style projections {w, b} ---
    if name == "w":
        if parent in ("wq", "wk", "wv", "wi", "wg", "wukv"):
            return with_lead(2, fs, "model")
        if parent == "wo":
            return with_lead(2, "model", fs)
        if parent in ("wdkv", "router"):
            return with_lead(2, fs, None)
        if parent in ("in_proj",):
            return with_lead(2, fs, "model")
        if parent == "out_proj":
            return with_lead(2, "model", fs)
        if parent in ("fc1", "fc2", "conv1", "conv2"):
            return P(*([None] * nd))  # paper CNN: replicated
        return P(*([None] * nd))
    if name == "b":
        return P(*([None] * nd))

    # --- SSM tensors ---
    if name == "conv_w":
        return with_lead(2, None, "model")
    if name in ("conv_b", "A_log", "dt_bias", "D"):
        return P(*([None] * nd))

    # --- norms, gates, everything else: replicated ---
    return P(*([None] * nd))


def param_specs(abstract_params: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpecs matching ``abstract_params``."""
    def leaf(path, x):
        return param_spec(_path_str(path), x.shape, mesh, fsdp)
    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


def param_shardings(abstract_params: Any, mesh: Mesh,
                    fsdp: bool = False) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(abstract_params, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# activations / batches / caches / server state
# --------------------------------------------------------------------------


def batch_spec(mesh: Mesh, shape: Sequence[int],
               worker_dim: bool = False) -> P:
    """Spec for a batch array: leading dim(s) over data-parallel axes.

    worker_dim=True: dim0 is the stacked worker axis [n_workers, ...]
    (train step); else dim0 is the plain batch dim (serve steps).
    """
    dp = dp_axes(mesh)
    lead = dp if _fits(shape[0], mesh, dp) else None
    return P(lead, *([None] * (len(shape) - 1)))


def server_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axis order for the server's coordinate dim: MODEL-MAJOR.

    The producer layout of the flattened gradients is [n(data), D(model)].
    With a model-major coordinate tiling, the reshard to the bank layout is
    a pure all-to-all over the data axis (each chip keeps its model column);
    with data-major tiling GSPMD has no efficient path and replicates whole
    [1, D] rows ("involuntary full rematerialization") — ~456 GiB/chip at
    123B params. See EXPERIMENTS §Perf iteration 4.
    """
    return ("model",) + dp_axes(mesh)


def bank_spec(mesh: Mesh) -> P:
    """RoSDHB momentum bank [n_workers, D]: workers replicated, coordinates
    sharded over the whole mesh (the coordinate-sharded virtual server),
    model-major (see server_axes)."""
    return P(None, server_axes(mesh))


def cache_spec(mesh: Mesh, shape: Sequence[int],
               batch: Optional[int] = None) -> P:
    """KV/SSM cache specs for decode.

    Caches may carry a leading stacked-layer dim, so dims are identified by
    value: the batch dim (== ``batch``) is sharded over dp; the model axis
    goes on a trailing head/state-like dim (iterating from the last dim
    backwards, skipping seq-like dims >= 4096). The seq dim is NEVER
    sharded: decode writes a dynamic-update-slice at a runtime position and
    GSPMD replicates DUS on a sharded dim (§Perf iter 9 — 355 GiB/chip on
    mistral decode_32k).
    """
    dp = dp_axes(mesh)
    spec: list = [None] * len(shape)
    batch_dim = None
    for i, dim in enumerate(shape):
        if batch is not None and dim == batch and _fits(dim, mesh, dp):
            batch_dim = i
            spec[i] = dp
            break
    start = (batch_dim + 1) if batch_dim is not None else 1
    for i in range(len(shape) - 1, start - 1, -1):
        if shape[i] < 4096 and _fits(shape[i], mesh, "model"):
            spec[i] = "model"
            break
    return P(*spec)


def cache_shardings(abstract_caches: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, cache_spec(mesh, x.shape)),
        abstract_caches)


# --------------------------------------------------------------------------
# sweep grid axis (repro.core.sweep's flat fusion axis over devices)
# --------------------------------------------------------------------------


def sweep_mesh(devices: Optional[Sequence[Any]] = None) -> Mesh:
    """1-D mesh over ``devices`` (default all local devices) with the single
    axis ``grid`` — the layout target for the sweep's flat fusion axis
    (``[n_cells * n_seeds]``, see ``repro.core.sweep.fused_grid_rollout``)."""
    devs = list(devices) if devices is not None else jax.devices()
    return Mesh(np.asarray(devs), ("grid",))


def grid_sharding(mesh: Mesh) -> NamedSharding:
    """Shard dim 0 over the ``grid`` axis, replicate every other dim. The
    spec is rank-agnostic (trailing dims default to replicated), so one
    sharding serves every leaf of a batched state pytree."""
    return NamedSharding(mesh, P("grid"))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated across the mesh (e.g. the shared batch stream)."""
    return NamedSharding(mesh, P())


def constrain_activation(x):
    """Mesh-aware activation constraint: shard the trailing (d_model) dim
    over 'model' when divisible. A no-op outside a mesh context, so model
    code can call it unconditionally. Inside ``vmap(..., spmd_axis_name=dp)``
    the constraint is lifted with the worker dim pinned to the data axes —
    this is what keeps the scan's saved residuals worker-sharded
    (EXPERIMENTS §Perf iter 5)."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names:
        return x
    last = "model" if x.shape[-1] % mesh.shape["model"] == 0 else None
    spec = P(*([None] * (x.ndim - 1) + [last]))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
