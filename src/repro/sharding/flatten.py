"""Sharding-aware pytree <-> virtual-server-bank transforms.

THE problem (EXPERIMENTS §Perf, iterations 2-4): turning per-worker gradient
pytrees into the server's ``[n_workers, D]`` bank is a *global layout
permutation*, and every GSPMD-mediated formulation of it degenerates to
"replicate, then re-slice" (~456 GiB/chip at 123B params):

  * naive reshape+concat makes the sharded dim minor -> unrepresentable;
  * transpose-major reshapes fix the per-leaf layout, but the final
    *concatenation* along the sharded coordinate dim has shard ranges that
    span operands -> no partitioned lowering exists;
  * ``with_sharding_constraint`` / explicit producer specs cannot help
    because the concat itself is the unpartitionable op.

Fix (iteration 4c): never materialise the concatenated vector in a global
layout at all. One ``shard_map`` performs, per leaf,

    local [1, c_i/M]  --reshape-->  [n_dp, c_i/(M*n_dp)]
                      --all_to_all(dp)-->  [n_dp, c_i/(M*n_dp)]

and concatenates the received pieces LOCALLY. This defines the bank's
coordinate order as a fixed shard-major interleave — a relabelling that is
immaterial to the algorithm (masks, momentum, aggregation are coordinate-
wise) and exactly invertible. Per-chip wire cost is the information-
theoretic minimum for this permutation: (n-1)/n * n*D/n_chips bytes.

The inverse (for the aggregated direction R) is a per-leaf all-gather over
the data axis inside the same kind of shard_map, emitting each leaf in a
model-major flat layout that reshapes cleanly back to parameter form.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import partitioning as sp

try:  # jax >= 0.6 moved shard_map
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map  # type: ignore


@dataclasses.dataclass(frozen=True)
class ShardedFlatSpec:
    """Static plan for the bank transforms.

    ``model_dims[i]``: index of leaf i's model-sharded dim (-1 replicated).
    ``chunk_sizes[i]``: leaf i's flat size padded to ``unit``.
    ``padded_size``: total bank coordinate count D (sum of chunks).
    """

    treedef: Any
    shapes: Tuple
    dtypes: Tuple
    model_dims: Tuple
    chunk_sizes: Tuple
    offsets: Tuple
    padded_size: int
    unit: int


def make_sharded_flat_spec(abstract_params: Any, mesh: Mesh,
                           fsdp: bool = False,
                           align: int = 8) -> ShardedFlatSpec:
    leaves, treedef = jax.tree_util.tree_flatten(abstract_params)
    pspecs = jax.tree_util.tree_leaves(
        sp.param_specs(abstract_params, mesh, fsdp),
        is_leaf=lambda x: isinstance(x, P))
    n_chips = int(np.prod(list(mesh.shape.values())))
    unit = n_chips * align

    shapes, dtypes, mdims, chunks, offsets = [], [], [], [], []
    off = 0
    for leaf, spec in zip(leaves, pspecs):
        shape = tuple(leaf.shape)
        mdim = -1
        for i, ax in enumerate(spec):
            if ax == "model" or (isinstance(ax, tuple) and "model" in ax):
                mdim = i
                break
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        padded = int(-(-size // unit) * unit)
        shapes.append(shape)
        dtypes.append(jnp.dtype(leaf.dtype))
        mdims.append(mdim)
        chunks.append(padded)
        offsets.append(off)
        off += padded
    return ShardedFlatSpec(treedef, tuple(shapes), tuple(dtypes),
                           tuple(mdims), tuple(chunks), tuple(offsets),
                           off, unit)


def _leaf_parts(tree: Any, spec: ShardedFlatSpec, mesh: Mesh,
                dtype) -> Tuple[List[jnp.ndarray], List[P]]:
    """Per leaf: model dim to front, flatten to [n, c_i] (padded), with the
    flat dim major-sharded over 'model' when the leaf is model-sharded."""
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    dp = sp.dp_axes(mesh)
    parts, specs = [], []
    for leaf, mdim, chunk in zip(leaves, spec.model_dims, spec.chunk_sizes):
        arr = leaf.astype(dtype)
        if mdim >= 0:
            arr = jnp.moveaxis(arr, 1 + mdim, 1)
        flat = arr.reshape(n, -1)
        pad = chunk - flat.shape[1]
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        pspec = P(dp, "model" if mdim >= 0 else None)
        parts.append(jax.lax.with_sharding_constraint(
            flat, NamedSharding(mesh, pspec)))
        specs.append(pspec)
    return parts, specs


def flatten_to_bank(tree: Any, spec: ShardedFlatSpec, mesh: Mesh,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Stacked gradient pytree (leading worker axis n) -> bank ``[n, D]``
    laid out ``P(None, ("model",) + dp)`` without ever materialising an
    unsharded coordinate vector."""
    dp = sp.dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape["model"]
    parts, in_specs = _leaf_parts(tree, spec, mesh, dtype)

    def body(*locals_):
        m = jax.lax.axis_index("model")
        outs = []
        for loc, mdim in zip(locals_, spec.model_dims):
            if mdim >= 0:
                col = loc[0]                       # [c_i / msize]
            else:
                c = loc.shape[1]
                col = jax.lax.dynamic_slice_in_dim(
                    loc[0], m * (c // msize), c // msize)
            pieces = col.reshape(n_dp, -1)
            outs.append(jax.lax.all_to_all(pieces, dp, 0, 0, tiled=True))
        return jnp.concatenate(outs, axis=1)       # LOCAL concat

    return shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=P(None, ("model",) + dp),
        check_rep=False,
    )(*parts)


def bank_to_param_tree(vec: jnp.ndarray, spec: ShardedFlatSpec,
                       mesh: Mesh) -> Any:
    """Aggregated direction ``[D]`` in bank layout -> parameter pytree."""
    dp = sp.dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape["model"]
    n_chips = n_dp * msize

    local_sizes = [c // n_chips for c in spec.chunk_sizes]

    def body(loc):  # [D / n_chips] local slice on chip (d, m)
        outs = []
        off = 0
        for ls in local_sizes:
            piece = jax.lax.dynamic_slice_in_dim(loc, off, ls)
            off += ls
            # gather this leaf's model column (pieces across the dp axis)
            outs.append(jax.lax.all_gather(piece, dp, tiled=True))
        return tuple(outs)

    out_specs = tuple(P(("model",)) for _ in local_sizes)
    cols = shard_map(body, mesh=mesh,
                     in_specs=P(("model",) + dp),
                     out_specs=out_specs,
                     check_rep=False)(vec)

    leaves = []
    for col, shape, dtype, mdim in zip(cols, spec.shapes, spec.dtypes,
                                       spec.model_dims):
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        flat = col[:size] if col.shape[0] != size else col
        if mdim >= 0 and len(shape):
            perm_shape = (shape[mdim],) + tuple(
                s for i, s in enumerate(shape) if i != mdim)
            arr = flat.reshape(perm_shape)
            arr = jnp.moveaxis(arr, 0, mdim)
        else:
            arr = flat.reshape(shape)
        leaves.append(arr.astype(dtype))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


