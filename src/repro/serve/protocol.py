"""Wire format of the streaming byzantine-robust parameter server.

Downlink, once per round (:class:`RoundAnnouncement`): the current flat
parameter vector plus the round's two broadcast PRNG keys — the coordinated
sparsification mask key (RoSDHB's 0-byte mask broadcast: clients re-derive
the global mask from the shared key instead of shipping indices) and the
attack key consumed by the simulated adversary. The announcement's key
chain replicates the simulator's exactly (``split(key) -> (carry,
round_key)``, then ``split(round_key) -> (mask_key, atk_key)``), which is
what makes server and ``Simulator.rollout`` trajectories bit-for-bit
comparable.

Uplink, once per client per round (:class:`ClientUpdate`): the update
values, the coordinated-mask id they were sparsified under, round/client
ids, and the *accounted* wire cost. Values are carried as the dense
unbiased reconstruction ``[padded_D]`` (what the server computes in
Algorithm 1 step 4 — the simulation convention of ``repro.core
.compression``), while ``payload_bytes`` prices the REAL wire format
through :func:`repro.core.wire.per_worker_payload_bytes`, the same
accounting ``Simulator.payload_bytes_per_round`` uses — simulator and
server cannot disagree on communication cost.

The byte-level **frame layer** at the bottom of this module is what the
pluggable transports (``repro.serve.transport``) actually move: every
message is one length-prefixed frame — a fixed 16-byte header (magic,
version, message type, sender id, payload length, CRC32) followed by the
payload. Float32 values round-trip through ``tobytes``/``frombuffer``
bit-for-bit, so a served trajectory over the loopback transport is still
bit-identical to the in-process server. A corrupted payload fails the
CRC and decodes to :class:`BadChecksum` *carrying the sender id from the
intact header*, which is what lets the server attribute protocol faults
to a client and count them against the Byzantine budget instead of
crashing the batcher.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Optional, Tuple

import numpy as np

from repro.core import algorithms as alg
from repro.core import wire as W


def mask_id(mask_key) -> int:
    """Stable integer id of a coordinated mask: the round's broadcast mask
    key folded to 64 bits. Clients echo it back so the server can reject
    updates sparsified under a different round's mask."""
    raw = np.asarray(mask_key, np.uint32).reshape(-1)
    lo = int(raw[-1])
    hi = int(raw[0]) if raw.size > 1 else 0
    return (hi << 32) | lo


@dataclasses.dataclass(frozen=True)
class RoundAnnouncement:
    """Downlink broadcast opening round ``round_id``."""

    round_id: int
    params: np.ndarray       # flat [padded_D] f32 parameter vector
    mask_key: np.ndarray     # broadcast coordinated-sparsification key
    atk_key: np.ndarray      # broadcast adversary key (simulation only)

    @property
    def mask_id(self) -> int:
        return mask_id(self.mask_key)


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One client's uplink payload for one round."""

    client_id: int
    round_id: int
    mask_id: int             # coordinated mask the values were built under
    values: np.ndarray       # dense unbiased reconstruction [padded_D]
    payload_bytes: int       # accounted REAL wire cost (repro.core.wire)
    sent_at: float = 0.0     # client-side send timestamp (perf_counter)


def update_payload_bytes(cfg: alg.AlgorithmConfig, d: int,
                         bytes_per_value: int = 4) -> int:
    """Accounted uplink bytes of one :class:`ClientUpdate` under ``cfg``'s
    algorithm (``d`` is the true model dimension, unpadded) — shared with
    ``Simulator.payload_bytes_per_round`` via :mod:`repro.core.wire`."""
    return W.per_worker_payload_bytes(cfg.name, d, cfg.sparsifier,
                                      bytes_per_value=bytes_per_value)


def make_update(cfg: alg.AlgorithmConfig, d: int, client_id: int,
                ann: RoundAnnouncement, values: np.ndarray,
                sent_at: float = 0.0,
                payload_bytes: Optional[int] = None) -> ClientUpdate:
    """Build a :class:`ClientUpdate` answering ``ann`` with priced wire
    cost (``d`` is the true model dimension used for byte accounting)."""
    if payload_bytes is None:
        payload_bytes = update_payload_bytes(cfg, d)
    return ClientUpdate(client_id=client_id, round_id=ann.round_id,
                        mask_id=ann.mask_id, values=values,
                        payload_bytes=payload_bytes, sent_at=sent_at)


# --------------------------------------------------------------------------
# Frame layer: what the transports actually move
# --------------------------------------------------------------------------

#: Frame header: magic u16, version u8, msg type u8, sender i32 (client id,
#: SERVER_SENDER for the server), payload length u32, payload CRC32 u32.
HEADER = struct.Struct("<HBBiII")
HEADER_SIZE = HEADER.size
MAGIC = 0x5242            # "BR"
VERSION = 1
SERVER_SENDER = -1

#: Message types.
MSG_ANNOUNCE_REQ = 1      # client -> server: send me the round >= min_round
MSG_ANNOUNCE = 2          # server -> client: RoundAnnouncement
MSG_UPDATE = 3            # client -> server: ClientUpdate
MSG_ACK = 4               # server -> client: status string for a request

_ANN_HEAD = struct.Struct("<qII")       # round_id, mask words, atk words
_UPDATE_HEAD = struct.Struct("<qQqd")   # round_id, mask_id, bytes, sent_at
_ACK_HEAD = struct.Struct("<q")         # round_id (-1 when not applicable)


class FrameError(ValueError):
    """A frame that cannot be decoded (bad magic/version/type/length)."""


class BadChecksum(FrameError):
    """Payload CRC mismatch. The header survived, so the sender id is
    attributable — the server counts this against the protocol-fault
    budget of ``sender`` instead of crashing."""

    def __init__(self, message: str, sender: int):
        super().__init__(message)
        self.sender = sender


def encode_frame(msg_type: int, payload: bytes,
                 sender: int = SERVER_SENDER) -> bytes:
    """One length-prefixed checksummed frame: header + payload."""
    return HEADER.pack(MAGIC, VERSION, msg_type, sender, len(payload),
                       zlib.crc32(payload) & 0xFFFFFFFF) + payload


def frame_length(header_bytes: bytes) -> int:
    """Total frame length (header + payload) from the raw 16-byte header —
    used by stream transports to split frames WITHOUT validating the CRC
    (a corrupt payload must still frame correctly so the next message on
    the connection survives)."""
    if len(header_bytes) < HEADER_SIZE:
        raise FrameError(
            f"short header: {len(header_bytes)} < {HEADER_SIZE} bytes")
    magic, version, _, _, length, _ = HEADER.unpack_from(header_bytes)
    if magic != MAGIC or version != VERSION:
        raise FrameError(
            f"bad magic/version {magic:#x}/{version} "
            f"(expected {MAGIC:#x}/{VERSION})")
    return HEADER_SIZE + length


def decode_frame(raw: bytes) -> Tuple[int, int, bytes]:
    """Validate + split one frame. Returns ``(msg_type, sender, payload)``;
    raises :class:`FrameError` on malformed framing and
    :class:`BadChecksum` (with the sender id) on a CRC mismatch."""
    if len(raw) < HEADER_SIZE:
        raise FrameError(f"short frame: {len(raw)} < {HEADER_SIZE} bytes")
    magic, version, msg_type, sender, length, crc = HEADER.unpack_from(raw)
    if magic != MAGIC or version != VERSION:
        raise FrameError(
            f"bad magic/version {magic:#x}/{version} "
            f"(expected {MAGIC:#x}/{VERSION})")
    payload = raw[HEADER_SIZE:]
    if len(payload) != length:
        raise FrameError(
            f"payload length {len(payload)} != header length {length}")
    if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
        raise BadChecksum(
            f"payload checksum mismatch for sender {sender} "
            f"(msg_type={msg_type}, {length} bytes)", sender=sender)
    return msg_type, sender, payload


# -- per-message payload codecs --------------------------------------------


def encode_announce_req(min_round: int, client_id: int) -> bytes:
    """Client request: the announcement for a round ``>= min_round``."""
    return encode_frame(MSG_ANNOUNCE_REQ, struct.pack("<q", min_round),
                        sender=client_id)


def decode_announce_req(payload: bytes) -> int:
    if len(payload) != 8:
        raise FrameError(f"announce_req payload {len(payload)} != 8 bytes")
    return struct.unpack("<q", payload)[0]


def encode_announcement(ann: RoundAnnouncement) -> bytes:
    mask = np.ascontiguousarray(ann.mask_key, dtype=np.uint32)
    atk = np.ascontiguousarray(ann.atk_key, dtype=np.uint32)
    params = np.ascontiguousarray(ann.params, dtype=np.float32)
    payload = (_ANN_HEAD.pack(ann.round_id, mask.size, atk.size)
               + mask.tobytes() + atk.tobytes() + params.tobytes())
    return encode_frame(MSG_ANNOUNCE, payload)


def decode_announcement(payload: bytes) -> RoundAnnouncement:
    if len(payload) < _ANN_HEAD.size:
        raise FrameError("announcement payload too short")
    round_id, n_mask, n_atk = _ANN_HEAD.unpack_from(payload)
    off = _ANN_HEAD.size
    need = off + 4 * (n_mask + n_atk)
    if len(payload) < need or (len(payload) - need) % 4:
        raise FrameError("announcement payload length inconsistent")
    mask = np.frombuffer(payload, np.uint32, count=n_mask, offset=off)
    off += 4 * n_mask
    atk = np.frombuffer(payload, np.uint32, count=n_atk, offset=off)
    off += 4 * n_atk
    params = np.frombuffer(payload, np.float32, offset=off)
    return RoundAnnouncement(round_id=round_id, params=params,
                             mask_key=mask, atk_key=atk)


def encode_update(update: ClientUpdate) -> bytes:
    values = np.ascontiguousarray(update.values, dtype=np.float32)
    payload = (_UPDATE_HEAD.pack(update.round_id, update.mask_id,
                                 update.payload_bytes, update.sent_at)
               + values.tobytes())
    return encode_frame(MSG_UPDATE, payload, sender=update.client_id)


def decode_update(payload: bytes, sender: int) -> ClientUpdate:
    if len(payload) < _UPDATE_HEAD.size:
        raise FrameError("update payload too short")
    round_id, mid, pbytes, sent_at = _UPDATE_HEAD.unpack_from(payload)
    if (len(payload) - _UPDATE_HEAD.size) % 4:
        raise FrameError("update values not a float32 array")
    values = np.frombuffer(payload, np.float32, offset=_UPDATE_HEAD.size)
    return ClientUpdate(client_id=sender, round_id=round_id, mask_id=mid,
                        values=values, payload_bytes=pbytes,
                        sent_at=sent_at)


def encode_ack(round_id: int, status: str) -> bytes:
    return encode_frame(MSG_ACK,
                        _ACK_HEAD.pack(round_id) + status.encode("utf-8"))


def decode_ack(payload: bytes) -> Tuple[int, str]:
    if len(payload) < _ACK_HEAD.size:
        raise FrameError("ack payload too short")
    (round_id,) = _ACK_HEAD.unpack_from(payload)
    return round_id, payload[_ACK_HEAD.size:].decode("utf-8", "replace")
