"""Wire format of the streaming byzantine-robust parameter server.

Downlink, once per round (:class:`RoundAnnouncement`): the current flat
parameter vector plus the round's two broadcast PRNG keys — the coordinated
sparsification mask key (RoSDHB's 0-byte mask broadcast: clients re-derive
the global mask from the shared key instead of shipping indices) and the
attack key consumed by the simulated adversary. The announcement's key
chain replicates the simulator's exactly (``split(key) -> (carry,
round_key)``, then ``split(round_key) -> (mask_key, atk_key)``), which is
what makes server and ``Simulator.rollout`` trajectories bit-for-bit
comparable.

Uplink, once per client per round (:class:`ClientUpdate`): the update
values, the coordinated-mask id they were sparsified under, round/client
ids, and the *accounted* wire cost. Values are carried as the dense
unbiased reconstruction ``[padded_D]`` (what the server computes in
Algorithm 1 step 4 — the simulation convention of ``repro.core
.compression``), while ``payload_bytes`` prices the REAL wire format
through :func:`repro.core.wire.per_worker_payload_bytes`, the same
accounting ``Simulator.payload_bytes_per_round`` uses — simulator and
server cannot disagree on communication cost.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import algorithms as alg
from repro.core import wire as W


def mask_id(mask_key) -> int:
    """Stable integer id of a coordinated mask: the round's broadcast mask
    key folded to 64 bits. Clients echo it back so the server can reject
    updates sparsified under a different round's mask."""
    raw = np.asarray(mask_key, np.uint32).reshape(-1)
    lo = int(raw[-1])
    hi = int(raw[0]) if raw.size > 1 else 0
    return (hi << 32) | lo


@dataclasses.dataclass(frozen=True)
class RoundAnnouncement:
    """Downlink broadcast opening round ``round_id``."""

    round_id: int
    params: np.ndarray       # flat [padded_D] f32 parameter vector
    mask_key: np.ndarray     # broadcast coordinated-sparsification key
    atk_key: np.ndarray      # broadcast adversary key (simulation only)

    @property
    def mask_id(self) -> int:
        return mask_id(self.mask_key)


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """One client's uplink payload for one round."""

    client_id: int
    round_id: int
    mask_id: int             # coordinated mask the values were built under
    values: np.ndarray       # dense unbiased reconstruction [padded_D]
    payload_bytes: int       # accounted REAL wire cost (repro.core.wire)
    sent_at: float = 0.0     # client-side send timestamp (perf_counter)


def update_payload_bytes(cfg: alg.AlgorithmConfig, d: int,
                         bytes_per_value: int = 4) -> int:
    """Accounted uplink bytes of one :class:`ClientUpdate` under ``cfg``'s
    algorithm (``d`` is the true model dimension, unpadded) — shared with
    ``Simulator.payload_bytes_per_round`` via :mod:`repro.core.wire`."""
    return W.per_worker_payload_bytes(cfg.name, d, cfg.sparsifier,
                                      bytes_per_value=bytes_per_value)


def make_update(cfg: alg.AlgorithmConfig, d: int, client_id: int,
                ann: RoundAnnouncement, values: np.ndarray,
                sent_at: float = 0.0,
                payload_bytes: Optional[int] = None) -> ClientUpdate:
    """Build a :class:`ClientUpdate` answering ``ann`` with priced wire
    cost (``d`` is the true model dimension used for byte accounting)."""
    if payload_bytes is None:
        payload_bytes = update_payload_bytes(cfg, d)
    return ClientUpdate(client_id=client_id, round_id=ann.round_id,
                        mask_id=ann.mask_id, values=values,
                        payload_bytes=payload_bytes, sent_at=sent_at)
