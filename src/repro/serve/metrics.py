"""Service metrics: sustained throughput, round latency, participation,
ingest-classification histograms, quorum transitions, fault events.

The server records one :class:`RoundRecord` per fired round plus a running
count of ingest decisions (now keyed per round, so the
``RoundBuffer.add`` classification — duplicate / future / stale_dropped /
bad_mask / bad_checksum — is observable as per-round histograms, not just
totals); :meth:`ServeMetrics.summary` folds them into the numbers
``results/BENCH_serve.json`` and ``results/BENCH_chaos.json`` report —
sustained updates/sec and rounds/sec over the measured span, p50/p99 round
latency (round open -> parameters applied), per-round participation +
staleness + classification histograms, the quorum degradation/recovery
transition log, and liveness-watchdog + fault-budget events.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: The RoundBuffer.add classifications surfaced as per-round histograms
#: (a satellite of the chaos PR: previously classified but unobservable).
DECISION_CLASSES = ("accepted", "replaced", "duplicate", "future",
                    "stale_dropped", "bad_mask", "bad_client",
                    "bad_checksum")


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy, so metrics
    stay importable host-side anywhere."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    rank = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[rank])


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One fired round, as observed by the batcher."""

    round_id: int
    n_updates: int                 # rows aggregated (accepted updates)
    fired_by: str                  # "quorum" | "timeout"
    staleness: Tuple[int, ...]     # per accepted update, in client-id order
    latency_s: float               # round open -> params applied
    step_s: float                  # jitted aggregate-and-apply wall time
    payload_bytes: int             # accounted uplink bytes this round
    quorum: int = 0                # effective quorum when the round fired


@dataclasses.dataclass(frozen=True)
class QuorumTransition:
    """One graceful-degradation (or recovery) step of the effective
    quorum, always bounded inside [2f+1 floor, configured quorum]."""

    round_id: int
    old: int
    new: int
    reason: str                    # "degrade" | "recover"


@dataclasses.dataclass
class WatchdogEvent:
    """The liveness watchdog observed a stalled round."""

    round_id: int
    open_s: float                  # how long the round had been open
    buffered: int                  # accepted updates at fire time
    quorum: int                    # effective quorum it was waiting for
    resolved: bool = False         # the round did eventually fire


class ServeMetrics:
    """Accumulates round records + ingest decisions for one service run."""

    def __init__(self):
        self.rounds: List[RoundRecord] = []
        self.decisions: Dict[str, int] = {}
        self.round_decisions: Dict[int, Dict[str, int]] = {}
        self.quorum_transitions: List[QuorumTransition] = []
        self.watchdog_events: List[WatchdogEvent] = []
        self.fault_budget_events: List[Dict[str, object]] = []
        self.started_at: float = 0.0
        self.finished_at: float = 0.0

    def observe_decision(self, status: str,
                         round_id: Optional[int] = None) -> None:
        self.decisions[status] = self.decisions.get(status, 0) + 1
        if round_id is not None:
            per = self.round_decisions.setdefault(round_id, {})
            per[status] = per.get(status, 0) + 1

    def observe_round(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    def observe_quorum_transition(self, round_id: int, old: int, new: int,
                                  reason: str) -> None:
        self.quorum_transitions.append(
            QuorumTransition(round_id, old, new, reason))

    def observe_watchdog(self, round_id: int, open_s: float, buffered: int,
                         quorum: int) -> WatchdogEvent:
        ev = WatchdogEvent(round_id, open_s, buffered, quorum)
        self.watchdog_events.append(ev)
        return ev

    def resolve_watchdog(self, round_id: int) -> None:
        for ev in self.watchdog_events:
            if ev.round_id == round_id:
                ev.resolved = True

    def observe_fault_budget(self, round_id: int, faulty: Sequence[int],
                             declared_byzantine: int, f: int) -> None:
        self.fault_budget_events.append({
            "round_id": round_id, "protocol_faulty": sorted(faulty),
            "declared_byzantine": declared_byzantine, "f": f})

    def span(self, start: float, end: float) -> None:
        self.started_at, self.finished_at = start, end

    # -- summaries ---------------------------------------------------------

    def participation_histogram(self) -> Dict[int, int]:
        """rounds keyed by how many updates they aggregated."""
        h: Dict[int, int] = {}
        for r in self.rounds:
            h[r.n_updates] = h.get(r.n_updates, 0) + 1
        return dict(sorted(h.items()))

    def staleness_histogram(self) -> Dict[int, int]:
        """accepted updates keyed by their staleness (rounds late)."""
        h: Dict[int, int] = {}
        for r in self.rounds:
            for s in r.staleness:
                h[s] = h.get(s, 0) + 1
        return dict(sorted(h.items()))

    def decision_round_histogram(self, status: str) -> Dict[int, int]:
        """Rounds keyed by how many ``status`` classifications they saw
        (zero bucket included, over every round with any decision), e.g.
        ``{0: 37, 1: 2, 4: 1}`` = 2 rounds saw one duplicate, 1 saw four."""
        h: Dict[int, int] = {}
        for per in self.round_decisions.values():
            k = per.get(status, 0)
            h[k] = h.get(k, 0) + 1
        return dict(sorted(h.items()))

    def quorum_histogram(self) -> Dict[int, int]:
        """rounds keyed by the effective quorum they fired under — the
        degradation trace in histogram form."""
        h: Dict[int, int] = {}
        for r in self.rounds:
            h[r.quorum] = h.get(r.quorum, 0) + 1
        return dict(sorted(h.items()))

    def watchdog_summary(self) -> Dict[str, int]:
        fired = len(self.watchdog_events)
        unresolved = sum(1 for ev in self.watchdog_events
                         if not ev.resolved)
        return {"fired": fired, "resolved": fired - unresolved,
                "unresolved": unresolved}

    def summary(self) -> Dict[str, object]:
        wall = max(self.finished_at - self.started_at, 1e-12)
        lat = [r.latency_s for r in self.rounds]
        updates = sum(r.n_updates for r in self.rounds)
        return {
            "rounds": len(self.rounds),
            "updates_accepted": updates,
            "wall_s": wall,
            "rounds_per_sec": len(self.rounds) / wall,
            "updates_per_sec": updates / wall,
            "latency_p50_ms": percentile(lat, 50) * 1e3,
            "latency_p99_ms": percentile(lat, 99) * 1e3,
            "latency_max_ms": (max(lat) if lat else float("nan")) * 1e3,
            "step_p50_ms": percentile(
                [r.step_s for r in self.rounds], 50) * 1e3,
            "fired_by": {
                k: sum(1 for r in self.rounds if r.fired_by == k)
                for k in ("quorum", "timeout")},
            "participation_histogram": {
                str(k): v for k, v in self.participation_histogram().items()},
            "staleness_histogram": {
                str(k): v for k, v in self.staleness_histogram().items()},
            "ingest_decisions": dict(sorted(self.decisions.items())),
            "decision_round_histograms": {
                status: {str(k): v for k, v
                         in self.decision_round_histogram(status).items()}
                for status in DECISION_CLASSES
                if status in self.decisions},
            "quorum_histogram": {
                str(k): v for k, v in self.quorum_histogram().items()},
            "quorum_transitions": [
                dataclasses.asdict(t) for t in self.quorum_transitions],
            "watchdog": self.watchdog_summary(),
            "fault_budget_events": list(self.fault_budget_events),
            "uplink_bytes": sum(r.payload_bytes for r in self.rounds),
        }
