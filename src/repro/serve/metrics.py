"""Service metrics: sustained throughput, round latency, participation.

The server records one :class:`RoundRecord` per fired round plus a running
count of ingest decisions; :meth:`ServeMetrics.summary` folds them into the
numbers ``results/BENCH_serve.json`` reports — sustained updates/sec and
rounds/sec over the measured span, p50/p99 round latency (round open ->
parameters applied), and per-round participation + staleness histograms.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy, so metrics
    stay importable host-side anywhere."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    rank = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[rank])


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One fired round, as observed by the batcher."""

    round_id: int
    n_updates: int                 # rows aggregated (accepted updates)
    fired_by: str                  # "quorum" | "timeout"
    staleness: Tuple[int, ...]     # per accepted update, in client-id order
    latency_s: float               # round open -> params applied
    step_s: float                  # jitted aggregate-and-apply wall time
    payload_bytes: int             # accounted uplink bytes this round


class ServeMetrics:
    """Accumulates round records + ingest decisions for one service run."""

    def __init__(self):
        self.rounds: List[RoundRecord] = []
        self.decisions: Dict[str, int] = {}
        self.started_at: float = 0.0
        self.finished_at: float = 0.0

    def observe_decision(self, status: str) -> None:
        self.decisions[status] = self.decisions.get(status, 0) + 1

    def observe_round(self, rec: RoundRecord) -> None:
        self.rounds.append(rec)

    def span(self, start: float, end: float) -> None:
        self.started_at, self.finished_at = start, end

    # -- summaries ---------------------------------------------------------

    def participation_histogram(self) -> Dict[int, int]:
        """rounds keyed by how many updates they aggregated."""
        h: Dict[int, int] = {}
        for r in self.rounds:
            h[r.n_updates] = h.get(r.n_updates, 0) + 1
        return dict(sorted(h.items()))

    def staleness_histogram(self) -> Dict[int, int]:
        """accepted updates keyed by their staleness (rounds late)."""
        h: Dict[int, int] = {}
        for r in self.rounds:
            for s in r.staleness:
                h[s] = h.get(s, 0) + 1
        return dict(sorted(h.items()))

    def summary(self) -> Dict[str, object]:
        wall = max(self.finished_at - self.started_at, 1e-12)
        lat = [r.latency_s for r in self.rounds]
        updates = sum(r.n_updates for r in self.rounds)
        return {
            "rounds": len(self.rounds),
            "updates_accepted": updates,
            "wall_s": wall,
            "rounds_per_sec": len(self.rounds) / wall,
            "updates_per_sec": updates / wall,
            "latency_p50_ms": percentile(lat, 50) * 1e3,
            "latency_p99_ms": percentile(lat, 99) * 1e3,
            "latency_max_ms": (max(lat) if lat else float("nan")) * 1e3,
            "step_p50_ms": percentile(
                [r.step_s for r in self.rounds], 50) * 1e3,
            "fired_by": {
                k: sum(1 for r in self.rounds if r.fired_by == k)
                for k in ("quorum", "timeout")},
            "participation_histogram": {
                str(k): v for k, v in self.participation_histogram().items()},
            "staleness_histogram": {
                str(k): v for k, v in self.staleness_histogram().items()},
            "ingest_decisions": dict(sorted(self.decisions.items())),
            "uplink_bytes": sum(r.payload_bytes for r in self.rounds),
        }
