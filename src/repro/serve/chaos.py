"""Named chaos scenarios: fault plans composed with the serving stack.

A :class:`ChaosScenario` bundles everything one fault-injection experiment
needs — a :class:`~repro.serve.faults.FaultSpec` (+ seed), the transport
kind, the client retry policy, straggler behaviour, the server's
degradation/watchdog knobs, and an optional mid-round kill-and-restart —
under a registry name, mirroring ``repro.adversary.registry`` for the
*transport* axis of adversity. The Byzantine axis still comes from the
adversary registry: a chaos run takes any serveable scenario cell, so
``chaos x attack x aggregator`` composes freely.

:func:`run_chaos` is the driver: a lock-step announce -> submit -> apply
loop (mirroring ``run_service``, which keeps the fault-free scenario
bit-for-bit comparable to the in-process server) where every frame
crosses a real transport boundary through a :class:`FaultyEndpoint` and a
:class:`RetryingClient`. With ``kill_at_round`` set, the server is killed
*mid-round* — after only half the clients submitted — checkpointed,
rebuilt, restored, and rebound to the same transport; the surviving
clients' in-flight updates then land on the restarted server, which
resumes the interrupted round.

``benchmarks/bench_chaos.py`` gates the composition: loopback parity
(fault-free chaos == in-process server, max |diff| 0.0), combined-fault
convergence (final honest loss within rtol 0.1 of fault-free), and
single-compilation (``step_traces == 1`` per server instance).
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import algorithms as alg
from repro.serve.client import (
    ClientBehavior, ClientGaveUp, ClientPool, RetryingClient, RetryPolicy,
)
from repro.serve.faults import FaultPlan, FaultSpec, FaultyEndpoint
from repro.serve.server import (
    ByzantineRobustServer, RoundResult, ServeConfig,
)
from repro.serve.transport import TransportError, make_transport


@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """One named fault-injection experiment over the serving stack.

    Attributes:
      name/description: registry identity.
      faults: the per-attempt fault rates + partition schedule.
      fault_seed: seed of the :class:`FaultPlan` (replayability).
      transport: ``loopback`` | ``tcp``.
      retry: client-side backoff policy.
      quorum: server firing quorum (``None`` = all n).
      timeout_s / staleness_window / stale_policy: round-buffer knobs —
        chaos scenarios usually need a wall-clock deadline so a round with
        dropped clients still fires.
      degrade_after / recover_after / watchdog_s / fault_tolerance: the
        server's fault-domain knobs (see :class:`ServeConfig`).
      stragglers / straggle_rounds: always-late clients (pool-side).
      kill_at_round: kill + checkpoint + restore + rebind the server in
        the middle of this round (``None`` = never).
    """

    name: str
    description: str
    faults: FaultSpec = FaultSpec()
    fault_seed: int = 0
    transport: str = "loopback"
    retry: RetryPolicy = RetryPolicy()
    quorum: Optional[int] = None
    timeout_s: float = 0.0
    staleness_window: int = 0
    stale_policy: str = "discount"
    degrade_after: int = 0
    recover_after: int = 2
    watchdog_s: float = 0.0
    fault_tolerance: int = 3
    stragglers: Tuple[int, ...] = ()
    straggle_rounds: int = 1
    kill_at_round: Optional[int] = None

    def serve_config(self) -> ServeConfig:
        return ServeConfig(
            quorum=self.quorum, timeout_s=self.timeout_s,
            staleness_window=self.staleness_window,
            stale_policy=self.stale_policy,
            degrade_after=self.degrade_after,
            recover_after=self.recover_after,
            watchdog_s=self.watchdog_s,
            fault_tolerance=self.fault_tolerance)

    def behavior(self, seed: int) -> ClientBehavior:
        return ClientBehavior(stragglers=self.stragglers,
                              straggle_rounds=self.straggle_rounds,
                              seed=seed)


CHAOS_REGISTRY: Dict[str, ChaosScenario] = {}


def register_chaos(sc: ChaosScenario) -> ChaosScenario:
    CHAOS_REGISTRY[sc.name] = sc
    return sc


def get_chaos(name: str) -> ChaosScenario:
    try:
        return CHAOS_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown chaos scenario: {name!r} (known: "
            f"{', '.join(sorted(CHAOS_REGISTRY))})") from None


def describe_chaos() -> str:
    width = max((len(n) for n in CHAOS_REGISTRY), default=0)
    return "\n".join(f"{s.name:<{width}}  {s.description}"
                     for s in CHAOS_REGISTRY.values())


for _sc in (
    ChaosScenario(
        "fault-free",
        "clean transport, full quorum — the parity + loss baseline"),
    ChaosScenario(
        "drop-storm",
        "15% of frames vanish; retries + wall-clock rounds keep serving",
        faults=FaultSpec(drop=0.15), timeout_s=0.25, staleness_window=2),
    ChaosScenario(
        "dup-flood",
        "half of all deliveries are duplicated (retransmission storm); "
        "the buffer's freshest-wins dedup absorbs every copy",
        faults=FaultSpec(duplicate=0.5), timeout_s=0.25,
        staleness_window=2),
    ChaosScenario(
        "corrupt-burst",
        "25% of frames arrive with flipped payload bytes; CRC rejection + "
        "retransmission repair them without charging honest clients",
        faults=FaultSpec(corrupt=0.25), timeout_s=0.25,
        staleness_window=2, fault_tolerance=6),
    ChaosScenario(
        "partition-heal",
        "4 clients partitioned for rounds 5..9; quorum degrades toward "
        "the 2f+1 floor, then recovers after the heal",
        faults=FaultSpec(partitions=((5, 10, (3, 4, 5, 6)),)),
        timeout_s=0.2, staleness_window=2, degrade_after=2,
        recover_after=2),
    ChaosScenario(
        "reset-storm",
        "30% of exchanges reset mid-flight (half before, half after "
        "delivery — the after-delivery retries must dedup)",
        faults=FaultSpec(reset=0.3), timeout_s=0.25, staleness_window=2),
    ChaosScenario(
        "straggler-degrade",
        "3 fixed stragglers always one round late; consecutive wall-clock "
        "rounds walk the quorum down, their stale (discounted) updates "
        "still count",
        timeout_s=0.15, staleness_window=2, degrade_after=2,
        stragglers=(10, 11, 12)),
    ChaosScenario(
        "kill-restart",
        "clean transport, server killed MID-ROUND at round 5 and restored "
        "from checkpoint — resumes the interrupted round bit-for-bit",
        kill_at_round=5),
    ChaosScenario(
        "combined",
        "everything at once: drop + duplicate + corrupt + delay + reset + "
        "a straggler + mid-round kill-and-restart, under graceful "
        "degradation and the liveness watchdog (the bench's loss gate)",
        faults=FaultSpec(drop=0.1, duplicate=0.2, corrupt=0.1, reset=0.1,
                         delay=0.2, delay_s=0.002),
        timeout_s=0.3, staleness_window=2, degrade_after=3,
        watchdog_s=10.0, fault_tolerance=6,
        stragglers=(10,), kill_at_round=5),
):
    register_chaos(_sc)


@dataclasses.dataclass
class ChaosResult:
    """What one chaos run produced (per restarted server instance where
    it applies)."""

    final_params: np.ndarray           # flat [padded_D] served parameters
    results: List[RoundResult]         # one per driven round, in order
    summaries: List[Dict[str, Any]]    # ServeMetrics.summary per instance
    step_traces: List[int]             # compiles per server instance
    injected: Dict[str, int]           # fault counters across endpoints
    client_stats: Dict[str, int]       # retry counters across clients
    restarts: int
    rounds_driven: int
    unresolved_watchdogs: int

    def all_rounds_terminated(self) -> bool:
        return (len(self.results) == self.rounds_driven
                and self.unresolved_watchdogs == 0)


def _fetch_announcement(clients: List[RetryingClient], min_round: int):
    """Ask the clients (in id order) for the round's announcement; any
    one success is enough — the pool answers for everyone. A client whose
    endpoint is partitioned/faulted just gives way to the next."""
    last: Optional[Exception] = None
    for c in clients:
        try:
            return c.fetch_announcement(min_round)
        except (ClientGaveUp, TransportError) as e:
            last = e
    raise RuntimeError(
        f"no client could fetch the round {min_round} announcement "
        f"(last: {last})")


def run_chaos(cfg: alg.AlgorithmConfig, params0: Any,
              batch_fn: Callable[[int], Any],
              loss_fn: Callable[[Any, Any], Any],
              chaos: ChaosScenario, rounds: int, *, seed: int = 0,
              checkpoint_dir: Optional[str] = None,
              round_timeout: float = 60.0) -> ChaosResult:
    """Drive ``rounds`` announce -> submit -> apply cycles across a fault-
    injected transport (the chaos mirror of ``run_service``)."""
    serve = chaos.serve_config()
    plan = FaultPlan(chaos.faults, seed=chaos.fault_seed)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn,
                      behavior=chaos.behavior(seed))
    n = cfg.n_workers

    server = ByzantineRobustServer(cfg, params0, serve, seed=seed)
    transport = make_transport(chaos.transport)
    transport.bind(server)
    server.start()
    servers = [server]

    endpoints = [FaultyEndpoint(transport.connect(cid), cid, plan)
                 for cid in range(n)]
    clients = [RetryingClient(ep, cid, chaos.retry)
               for cid, ep in enumerate(endpoints)]

    owned_tmp = None
    if chaos.kill_at_round is not None and checkpoint_dir is None:
        owned_tmp = tempfile.TemporaryDirectory(prefix="repro_chaos_")
        checkpoint_dir = owned_tmp.name

    def restart_mid_round() -> ByzantineRobustServer:
        """Kill + checkpoint + restore + rebind: the crash-recovery path.
        (Checkpoint first models a server whose durable state survived
        the crash; the restore path is identical either way.)"""
        nonlocal server
        path = server.save_checkpoint(
            os.path.join(checkpoint_dir, "chaos_kill"))
        transport.unbind()
        server.stop()
        server = ByzantineRobustServer(cfg, params0, serve, seed=seed)
        server.restore(path)
        transport.bind(server)
        server.start()
        servers.append(server)
        return server

    pending: List[Tuple[int, Any]] = []
    results: List[RoundResult] = []
    restarts = 0
    t_start = time.perf_counter()
    try:
        expect = 0
        for _ in range(rounds):
            ann = _fetch_announcement(clients, min_round=expect)
            t = ann.round_id
            due = [u for dr, u in pending if dr <= t]
            pending = [(dr, u) for dr, u in pending if dr > t]
            sched = pool.round_payloads(ann)
            kill_here = (chaos.kill_at_round == t)
            to_send: List[Any] = [u for u in due]
            for s in sched:
                if s.drop:
                    continue
                if s.deliver_round <= t:
                    to_send.append(s.update)
                else:
                    pending.append((s.deliver_round, s.update))
            to_send.sort(key=lambda u: u.client_id)
            for k, u in enumerate(to_send):
                if kill_here and k == len(to_send) // 2:
                    # mid-round crash: half the round's updates are
                    # in-flight server-side when the process dies
                    restart_mid_round()
                    restarts += 1
                try:
                    clients[u.client_id].submit(u)
                except (ClientGaveUp, ValueError):
                    pass       # this client's update is lost this round
            for ep in endpoints:
                ep.flush()     # deliver any held (reordered) frames
            results.append(server.wait_round(t, timeout=round_timeout))
            expect = t + 1
    finally:
        server.metrics.span(t_start, time.perf_counter())
        for c in clients:
            try:
                c.close()
            except TransportError:
                pass
        server.stop()
        transport.close()
        if owned_tmp is not None:
            owned_tmp.cleanup()

    injected: Dict[str, int] = {}
    for ep in endpoints:
        for k, v in ep.injected.items():
            injected[k] = injected.get(k, 0) + v
    client_stats: Dict[str, int] = {}
    for c in clients:
        for k, v in c.stats.items():
            client_stats[k] = client_stats.get(k, 0) + v
    summaries = [s.metrics.summary() for s in servers]
    unresolved = sum(s["watchdog"]["unresolved"] for s in summaries)
    return ChaosResult(
        final_params=np.asarray(server.params_flat),
        results=results,
        summaries=summaries,
        step_traces=[s.step_traces for s in servers],
        injected=injected,
        client_stats=client_stats,
        restarts=restarts,
        rounds_driven=rounds,
        unresolved_watchdogs=unresolved)
