"""Streaming byzantine-robust parameter server (``python -m repro.serve``).

The engine turned inside out: instead of simulating all workers in one
``lax.scan``, clients push compressed updates onto a queue, a round buffer
collects them under a participation quorum / wall-clock timeout / bounded
staleness window, and ONE jitted aggregate-and-apply step (the same
``make_aggregator`` + rosdhb/robust_dgd/dgd apply halves the simulator
runs) fires per round — padding absent clients so the step never retraces
across participation levels.

Module map:
  protocol  — wire format (RoundAnnouncement down, ClientUpdate up; byte
              accounting shared with the simulator via repro.core.wire)
              + the length-prefixed checksummed frame layer
  buffer    — the round buffer (quorum, timeout, staleness policies)
  server    — ingest thread + queue + batcher loop around the jitted step,
              plus the fault domain (typed ServeTimeout, protocol-fault
              budget, graceful quorum degradation, liveness watchdog,
              mid-round crash recovery)
  client    — simulated client pool (honest + byzantine via repro.adversary,
              straggler/drop/late-arrival injection) + RetryingClient
              (backoff + jitter, idempotent resubmission) over transports
  transport — pluggable frame movers: in-process loopback + real TCP
  faults    — seeded deterministic fault injection (FaultPlan: delay/drop/
              duplicate/reorder/corrupt/partition/reset per attempt)
  chaos     — named chaos scenarios composing fault plans with the stack
              (run_chaos driver; bench_chaos gates)
  metrics   — updates/sec, rounds/sec, p50/p99 round latency, histograms,
              quorum transitions, watchdog + fault-budget events

With full participation and zero timeout the server's parameter trajectory
matches ``Simulator.rollout`` bit-for-bit — including over the loopback
transport's framed path (tests/test_serve.py, benchmarks/bench_serve.py,
benchmarks/bench_chaos.py gates).
"""

from repro.serve.buffer import RoundBuffer
from repro.serve.chaos import (
    CHAOS_REGISTRY, ChaosResult, ChaosScenario, get_chaos, register_chaos,
    run_chaos,
)
from repro.serve.client import (
    ClientBehavior, ClientGaveUp, ClientPool, RetryingClient, RetryPolicy,
)
from repro.serve.faults import (
    FaultDecision, FaultPlan, FaultSpec, FaultyEndpoint, faulty_endpoints,
)
from repro.serve.metrics import ServeMetrics
from repro.serve.protocol import ClientUpdate, RoundAnnouncement, mask_id
from repro.serve.server import (
    ByzantineRobustServer, FaultBudgetExceeded, RoundResult, ServeConfig,
    ServeTimeout, run_service,
)
from repro.serve.transport import (
    LoopbackTransport, TcpTransport, TransportError, TransportReset,
    TransportTimeout, make_transport,
)

__all__ = [
    "ByzantineRobustServer", "CHAOS_REGISTRY", "ChaosResult",
    "ChaosScenario", "ClientBehavior", "ClientGaveUp", "ClientPool",
    "ClientUpdate", "FaultBudgetExceeded", "FaultDecision", "FaultPlan",
    "FaultSpec", "FaultyEndpoint", "LoopbackTransport", "RetryingClient",
    "RetryPolicy", "RoundAnnouncement", "RoundBuffer", "RoundResult",
    "ServeConfig", "ServeMetrics", "ServeTimeout", "TcpTransport",
    "TransportError", "TransportReset", "TransportTimeout",
    "faulty_endpoints", "get_chaos", "make_transport", "mask_id",
    "register_chaos", "run_chaos", "run_service",
]
