"""Streaming byzantine-robust parameter server (``python -m repro.serve``).

The engine turned inside out: instead of simulating all workers in one
``lax.scan``, clients push compressed updates onto a queue, a round buffer
collects them under a participation quorum / wall-clock timeout / bounded
staleness window, and ONE jitted aggregate-and-apply step (the same
``make_aggregator`` + rosdhb/robust_dgd/dgd apply halves the simulator
runs) fires per round — padding absent clients so the step never retraces
across participation levels.

Module map:
  protocol  — wire format (RoundAnnouncement down, ClientUpdate up; byte
              accounting shared with the simulator via repro.core.wire)
  buffer    — the round buffer (quorum, timeout, staleness policies)
  server    — ingest thread + queue + batcher loop around the jitted step
  client    — simulated client pool (honest + byzantine via repro.adversary,
              straggler/drop/late-arrival injection)
  metrics   — updates/sec, rounds/sec, p50/p99 round latency, histograms

With full participation and zero timeout the server's parameter trajectory
matches ``Simulator.rollout`` bit-for-bit (tests/test_serve.py,
benchmarks/bench_serve.py gate).
"""

from repro.serve.buffer import RoundBuffer
from repro.serve.client import ClientBehavior, ClientPool
from repro.serve.metrics import ServeMetrics
from repro.serve.protocol import ClientUpdate, RoundAnnouncement, mask_id
from repro.serve.server import (
    ByzantineRobustServer, RoundResult, ServeConfig, run_service,
)

__all__ = [
    "ByzantineRobustServer", "ClientBehavior", "ClientPool", "ClientUpdate",
    "RoundAnnouncement", "RoundBuffer", "RoundResult", "ServeConfig",
    "ServeMetrics", "mask_id", "run_service",
]
