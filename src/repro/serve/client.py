"""Simulated client pool for the streaming parameter server.

Honest clients compute local gradients and put the algorithm's wire
quantity on the uplink (``algorithms.make_wire_fn`` — sparsified unbiased
reconstructions under the round's broadcast coordinated mask); Byzantine
clients (rows ``[0, f)``) are driven by the first-class ``repro.adversary``
API through the same ``_byzantine_overwrite`` dispatch the simulator uses,
with stateful adversaries carrying their ``AttackState`` pool-side. The
whole pool answers a round announcement with ONE jitted vmapped program —
the exact op sequence of the simulator's round up to the server apply, so
full-participation service trajectories are bit-for-bit
``Simulator.rollout``'s.

:class:`ClientBehavior` injects the failure modes the closed-world scan
cannot express: per-round drop probability, probabilistic late arrival,
and fixed stragglers that are always ``straggle_rounds`` late.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.serve import protocol
from repro.utils import tree as T


@dataclasses.dataclass(frozen=True)
class ClientBehavior:
    """Failure-mode injection, drawn from a seeded host-side RNG.

    Attributes:
      drop_prob: per client per round probability the update never arrives.
      late_prob: probability an update is delivered ``late_rounds`` late.
      late_rounds: lateness of probabilistically-late updates.
      stragglers: client ids that are ALWAYS late (e.g. the f byzantine
        ids, for the all-byzantine-late scenario).
      straggle_rounds: how late stragglers deliver.
      seed: RNG seed for the drop/late draws.
    """

    drop_prob: float = 0.0
    late_prob: float = 0.0
    late_rounds: int = 1
    stragglers: Tuple[int, ...] = ()
    straggle_rounds: int = 1
    seed: int = 0


class ScheduledUpdate(NamedTuple):
    """A client's payload plus its injected delivery fate."""

    update: protocol.ClientUpdate
    deliver_round: int
    drop: bool


class ClientPool:
    """All n simulated clients (honest + byzantine) answering one server."""

    def __init__(self, loss_fn: Callable[[Any, Any], jnp.ndarray],
                 params0: Any, cfg: alg.AlgorithmConfig,
                 batch_fn: Callable[[int], Any],
                 behavior: Optional[ClientBehavior] = None):
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.behavior = behavior or ClientBehavior()
        self.spec = T.make_flat_spec(params0)
        self.d = self.spec.size
        self._rng = np.random.default_rng(self.behavior.seed)
        from repro.adversary import core as adv
        self.attack_state = (adv.init_attack_state(self.spec.padded_size)
                             if adv.needs_attack_state(cfg.attack.name,
                                                       cfg.f) else None)
        wire_fn = alg.make_wire_fn(cfg)
        self.pool_traces = 0

        def _pool_round(params_flat, worker_batches, atk_state, mask_key,
                        atk_key):
            # the simulator's round, up to (and excluding) the server-side
            # apply: same vmapped grads, same clip, same wire half — this
            # op-for-op match is what the bit-for-bit parity gate rests on
            self.pool_traces += 1  # trace-time (python) side effect only
            params = T.tree_unravel(params_flat, self.spec)

            def worker_grad(batch):
                l, g = jax.value_and_grad(loss_fn)(params, batch)
                return l, T.tree_ravel(g, self.spec)

            losses, grads = jax.vmap(worker_grad)(worker_batches)
            if cfg.clip_norm is not None:
                norms = jnp.linalg.norm(grads.astype(jnp.float32), axis=1,
                                        keepdims=True)
                scale = jnp.minimum(1.0, cfg.clip_norm
                                    / jnp.maximum(norms, 1e-12))
                grads = grads * scale.astype(grads.dtype)
            wire, atk_state = wire_fn(atk_state, grads, mask_key, atk_key)
            return wire, atk_state, losses

        self._pool_round = jax.jit(_pool_round)

    def round_payloads(self, ann: protocol.RoundAnnouncement
                       ) -> List[ScheduledUpdate]:
        """Answer one round announcement: every client's update, tagged
        with its injected delivery fate (drop / deliver at round t+k)."""
        b = self.behavior
        wire, self.attack_state, losses = self._pool_round(
            jnp.asarray(ann.params), self.batch_fn(ann.round_id),
            self.attack_state, jnp.asarray(ann.mask_key),
            jnp.asarray(ann.atk_key))
        wire = np.asarray(wire)
        self.last_losses = np.asarray(losses)
        out: List[ScheduledUpdate] = []
        now = time.perf_counter()
        for cid in range(self.cfg.n_workers):
            u_drop, u_late = self._rng.random(2)
            if cid in b.stragglers:
                deliver, drop = ann.round_id + b.straggle_rounds, False
            elif u_drop < b.drop_prob:
                deliver, drop = ann.round_id, True
            elif u_late < b.late_prob:
                deliver, drop = ann.round_id + b.late_rounds, False
            else:
                deliver, drop = ann.round_id, False
            out.append(ScheduledUpdate(
                update=protocol.make_update(self.cfg, self.d, cid, ann,
                                            wire[cid], sent_at=now),
                deliver_round=deliver, drop=drop))
        return out
