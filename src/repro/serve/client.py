"""Simulated client pool for the streaming parameter server.

Honest clients compute local gradients and put the algorithm's wire
quantity on the uplink (``algorithms.make_wire_fn`` — sparsified unbiased
reconstructions under the round's broadcast coordinated mask); Byzantine
clients (rows ``[0, f)``) are driven by the first-class ``repro.adversary``
API through the same ``_byzantine_overwrite`` dispatch the simulator uses,
with stateful adversaries carrying their ``AttackState`` pool-side. The
whole pool answers a round announcement with ONE jitted vmapped program —
the exact op sequence of the simulator's round up to the server apply, so
full-participation service trajectories are bit-for-bit
``Simulator.rollout``'s.

:class:`ClientBehavior` injects the failure modes the closed-world scan
cannot express: per-round drop probability, probabilistic late arrival,
and fixed stragglers that are always ``straggle_rounds`` late.

:class:`RetryingClient` is the *transport-hardened* half: it speaks the
frame protocol over any endpoint (loopback, TCP, fault-injected) with
exponential backoff + seeded jitter, idempotent resubmission (the server's
freshest-wins dedup makes retransmission safe), and re-announcement on
timeout — the client-side discipline that turns injected transport chaos
into mere latency.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.serve import protocol
from repro.serve.transport import TransportError
from repro.utils import tree as T


@dataclasses.dataclass(frozen=True)
class ClientBehavior:
    """Failure-mode injection, drawn from a seeded host-side RNG.

    Attributes:
      drop_prob: per client per round probability the update never arrives.
      late_prob: probability an update is delivered ``late_rounds`` late.
      late_rounds: lateness of probabilistically-late updates.
      stragglers: client ids that are ALWAYS late (e.g. the f byzantine
        ids, for the all-byzantine-late scenario).
      straggle_rounds: how late stragglers deliver.
      seed: RNG seed for the drop/late draws.
    """

    drop_prob: float = 0.0
    late_prob: float = 0.0
    late_rounds: int = 1
    stragglers: Tuple[int, ...] = ()
    straggle_rounds: int = 1
    seed: int = 0


class ScheduledUpdate(NamedTuple):
    """A client's payload plus its injected delivery fate."""

    update: protocol.ClientUpdate
    deliver_round: int
    drop: bool


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic (seeded) jitter.

    Attempt ``k`` (0-based) sleeps ``min(base * 2**k, cap) * (1 + jitter
    * u)`` with ``u ~ U[0, 1)`` drawn from a per-client stream — seeded so
    a chaos replay backs off identically.
    """

    max_attempts: int = 5
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.5
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts={self.max_attempts} < 1")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff times must be >= 0")
        if not 0.0 <= self.jitter:
            raise ValueError(f"jitter={self.jitter} < 0")

    def backoff_s(self, client_id: int, attempt: int,
                  rng: np.random.Generator) -> float:
        base = min(self.backoff_base_s * (2.0 ** attempt),
                   self.backoff_cap_s)
        return base * (1.0 + self.jitter * float(rng.random()))


class ClientGaveUp(RuntimeError):
    """Every retry attempt failed (transport faults or NACKs)."""

    def __init__(self, message: str, *, client_id: int, op: str,
                 attempts: int, last_error: Optional[str] = None):
        super().__init__(message)
        self.client_id = client_id
        self.op = op
        self.attempts = attempts
        self.last_error = last_error


class RetryingClient:
    """One client's fault-tolerant protocol driver over a transport
    endpoint.

    * ``fetch_announcement`` retries through transport faults and
      ``no_round`` NACKs until an announcement for ``round >= min_round``
      arrives — the *re-announcement on timeout* half of recovery (a
      client that missed a round just asks again and is told the current
      one).
    * ``submit`` retries the SAME update frame until the server acks it.
      Resubmission is idempotent: duplicate deliveries land in the
      ``RoundBuffer``'s freshest-wins dedup, and a ``bad_checksum`` NACK
      (payload corrupted in flight) is repaired by retransmission — the
      retry re-encodes from the intact local update.

    Sleep is injectable so tests run backoff schedules at time-warp.
    """

    def __init__(self, endpoint, client_id: int,
                 policy: Optional[RetryPolicy] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.endpoint = endpoint
        self.client_id = client_id
        self.policy = policy or RetryPolicy()
        self._sleep = sleep
        self._rng = np.random.default_rng(
            (self.policy.seed, int(client_id)))
        #: observability counters: attempts, retries, and give-ups per op
        self.stats = {"announce_attempts": 0, "update_attempts": 0,
                      "retries": 0, "gave_up": 0}

    def _retry(self, op: str, round_id: int, build: Callable[[], bytes],
               accept: Callable[[int, int, bytes], Optional[Any]]) -> Any:
        """Run build -> request -> accept with backoff until ``accept``
        returns non-None or the policy's attempts are exhausted."""
        p = self.policy
        last: Optional[str] = None
        for attempt in range(p.max_attempts):
            self.stats[f"{op}_attempts"] += 1
            if attempt > 0:
                self.stats["retries"] += 1
                self._sleep(p.backoff_s(self.client_id, attempt - 1,
                                        self._rng))
            try:
                raw = self.endpoint.request(
                    build(), round_id=round_id, op=op, attempt=attempt)
                msg_type, sender, payload = protocol.decode_frame(raw)
            except TransportError as e:
                last = f"{type(e).__name__}: {e}"
                continue
            except protocol.FrameError as e:
                last = f"corrupt response: {e}"
                continue
            out = accept(msg_type, sender, payload)
            if out is not None:
                return out
            last = f"nacked (msg_type={msg_type})"
        self.stats["gave_up"] += 1
        raise ClientGaveUp(
            f"client {self.client_id} gave up on {op} for round "
            f"{round_id} after {p.max_attempts} attempts "
            f"(last: {last})", client_id=self.client_id, op=op,
            attempts=p.max_attempts, last_error=last)

    def fetch_announcement(self, min_round: int = 0
                           ) -> protocol.RoundAnnouncement:
        def accept(msg_type, sender, payload):
            if msg_type != protocol.MSG_ANNOUNCE:
                return None                  # ACK("no_round") etc: retry
            ann = protocol.decode_announcement(payload)
            return ann if ann.round_id >= min_round else None

        return self._retry(
            "announce", min_round,
            lambda: protocol.encode_announce_req(min_round, self.client_id),
            accept)

    def submit(self, update: protocol.ClientUpdate) -> str:
        """Deliver one update; returns the server's ack status (e.g.
        ``"queued"``). Raises :class:`ClientGaveUp` when every attempt
        fails."""
        def accept(msg_type, sender, payload):
            if msg_type != protocol.MSG_ACK:
                return None
            _, status = protocol.decode_ack(payload)
            if status == "queued":
                return status
            if status.startswith("rejected"):
                # a validation rejection is not a transport fault: the
                # update itself is malformed — retrying cannot help
                raise ValueError(
                    f"client {self.client_id} update for round "
                    f"{update.round_id} rejected: {status}")
            return None                      # bad_checksum/bad_frame: retry

        return self._retry(
            "update", update.round_id,
            lambda: protocol.encode_update(update), accept)

    def close(self) -> None:
        self.endpoint.close()


class ClientPool:
    """All n simulated clients (honest + byzantine) answering one server."""

    def __init__(self, loss_fn: Callable[[Any, Any], jnp.ndarray],
                 params0: Any, cfg: alg.AlgorithmConfig,
                 batch_fn: Callable[[int], Any],
                 behavior: Optional[ClientBehavior] = None):
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.behavior = behavior or ClientBehavior()
        self.spec = T.make_flat_spec(params0)
        self.d = self.spec.size
        self._rng = np.random.default_rng(self.behavior.seed)
        from repro.adversary import core as adv
        self.attack_state = (adv.init_attack_state(self.spec.padded_size)
                             if adv.needs_attack_state(cfg.attack.name,
                                                       cfg.f) else None)
        wire_fn = alg.make_wire_fn(cfg)
        self.pool_traces = 0

        def _pool_round(params_flat, worker_batches, atk_state, mask_key,
                        atk_key):
            # the simulator's round, up to (and excluding) the server-side
            # apply: same vmapped grads, same clip, same wire half — this
            # op-for-op match is what the bit-for-bit parity gate rests on
            self.pool_traces += 1  # trace-time (python) side effect only
            params = T.tree_unravel(params_flat, self.spec)

            def worker_grad(batch):
                l, g = jax.value_and_grad(loss_fn)(params, batch)
                return l, T.tree_ravel(g, self.spec)

            losses, grads = jax.vmap(worker_grad)(worker_batches)
            if cfg.clip_norm is not None:
                norms = jnp.linalg.norm(grads.astype(jnp.float32), axis=1,
                                        keepdims=True)
                scale = jnp.minimum(1.0, cfg.clip_norm
                                    / jnp.maximum(norms, 1e-12))
                grads = grads * scale.astype(grads.dtype)
            wire, atk_state = wire_fn(atk_state, grads, mask_key, atk_key)
            return wire, atk_state, losses

        self._pool_round = jax.jit(_pool_round)

    def round_payloads(self, ann: protocol.RoundAnnouncement
                       ) -> List[ScheduledUpdate]:
        """Answer one round announcement: every client's update, tagged
        with its injected delivery fate (drop / deliver at round t+k)."""
        b = self.behavior
        wire, self.attack_state, losses = self._pool_round(
            jnp.asarray(ann.params), self.batch_fn(ann.round_id),
            self.attack_state, jnp.asarray(ann.mask_key),
            jnp.asarray(ann.atk_key))
        wire = np.asarray(wire)
        self.last_losses = np.asarray(losses)
        out: List[ScheduledUpdate] = []
        now = time.perf_counter()
        for cid in range(self.cfg.n_workers):
            u_drop, u_late = self._rng.random(2)
            if cid in b.stragglers:
                deliver, drop = ann.round_id + b.straggle_rounds, False
            elif u_drop < b.drop_prob:
                deliver, drop = ann.round_id, True
            elif u_late < b.late_prob:
                deliver, drop = ann.round_id + b.late_rounds, False
            else:
                deliver, drop = ann.round_id, False
            out.append(ScheduledUpdate(
                update=protocol.make_update(self.cfg, self.d, cid, ann,
                                            wire[cid], sent_at=now),
                deliver_round=deliver, drop=drop))
        return out
