"""Round buffer: participation quorum, wall-clock timeout, staleness window.

The buffer collects :class:`~repro.serve.protocol.ClientUpdate`s for the
server's *current* round and decides when the jitted aggregate-and-apply
step may fire:

* **quorum** — fire as soon as ``quorum`` distinct clients have an accepted
  update. A quorum below ``2f + 1`` raises loudly at construction: with
  fewer than ``2f + 1`` reports the ``f`` Byzantine rows can be a majority
  of the round and no (f, kappa)-robust rule retains its guarantee.
* **timeout** — with ``timeout_s > 0``, fire once the round has been open
  that long AND at least one update was accepted (partial participation);
  ``timeout_s == 0`` disables the clock — the round fires on quorum only.
* **staleness window** — a late update from round ``t - k`` is accepted
  while ``k <= staleness_window`` under ``stale_policy='discount'``
  (momentum-discounted by ``beta^k`` at apply time) and recorded with its
  staleness; under ``'drop'`` (or beyond the window) it is discarded. Per
  client only the freshest update is kept.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.protocol import ClientUpdate

#: Selectable late-update policies.
STALE_POLICIES = ("discount", "drop")


@dataclasses.dataclass
class BufferedUpdate:
    update: ClientUpdate
    staleness: int           # rounds late (0 = fresh for the current round)
    accepted_at: float


class RoundBuffer:
    """Accumulates one round's updates and decides when to fire."""

    def __init__(self, n_clients: int, f: int, quorum: Optional[int] = None,
                 timeout_s: float = 0.0, staleness_window: int = 0,
                 stale_policy: str = "discount"):
        quorum = n_clients if quorum is None else quorum
        if not 1 <= quorum <= n_clients:
            raise ValueError(
                f"quorum={quorum} outside [1, n_clients={n_clients}]")
        if quorum < 2 * f + 1:
            raise ValueError(
                f"quorum={quorum} < 2f+1 = {2 * f + 1}: with fewer than "
                f"2f+1 reports the f={f} byzantine clients can be a majority "
                "of a round and no (f, kappa)-robust aggregator retains its "
                "guarantee — raise the quorum or lower f")
        if stale_policy not in STALE_POLICIES:
            raise ValueError(
                f"unknown stale_policy {stale_policy!r} "
                f"(expected one of {STALE_POLICIES})")
        if staleness_window < 0:
            raise ValueError(f"staleness_window={staleness_window} < 0")
        if timeout_s < 0:
            raise ValueError(f"timeout_s={timeout_s} < 0")
        self.n_clients = n_clients
        self.f = f
        self.quorum = quorum
        #: the configured quorum; ``quorum`` itself is the EFFECTIVE one —
        #: graceful degradation may step it down toward the 2f+1 floor
        #: (never below) and back up, via :meth:`set_quorum`.
        self.base_quorum = quorum
        self.timeout_s = timeout_s
        self.staleness_window = staleness_window
        self.stale_policy = stale_policy
        self.round_id = 0
        self.opened_at = 0.0
        self.first_update_at: Optional[float] = None
        self._rows: Dict[int, BufferedUpdate] = {}
        self._future: List[ClientUpdate] = []
        # mask ids of recent rounds (round_id -> id), for validating that a
        # (possibly stale) update was built under its round's broadcast mask
        self._mask_ids: Dict[int, int] = {}

    # -- round lifecycle ---------------------------------------------------

    def open(self, round_id: int, now: float, mask_id: Optional[int] = None
             ) -> List[Tuple[ClientUpdate, str]]:
        """Open ``round_id``: clear the row bank, remember the round's mask
        id, and re-feed any updates that arrived early for it. Returns the
        ``(update, status)`` decisions for the re-fed updates."""
        self.round_id = round_id
        self.opened_at = now
        self.first_update_at = None
        self._rows = {}
        if mask_id is not None:
            self._mask_ids[round_id] = mask_id
            horizon = round_id - self.staleness_window - 1
            self._mask_ids = {r: m for r, m in self._mask_ids.items()
                              if r > horizon}
        pending, self._future = self._future, []
        return [(u, self.add(u, now)) for u in pending]

    def register_mask(self, round_id: int, mask_id: int) -> None:
        """Record ``round_id``'s broadcast mask id (when the announcement is
        built after the round was opened)."""
        self._mask_ids[round_id] = mask_id
        horizon = self.round_id - self.staleness_window - 1
        self._mask_ids = {r: m for r, m in self._mask_ids.items()
                          if r > horizon}

    def set_quorum(self, quorum: int) -> None:
        """Step the EFFECTIVE quorum (graceful degradation / recovery).
        The validated floor is ``2f + 1`` — stepping below it would void
        the robustness guarantee, so it raises exactly like construction."""
        if not 1 <= quorum <= self.n_clients:
            raise ValueError(
                f"quorum={quorum} outside [1, n_clients={self.n_clients}]")
        if quorum < 2 * self.f + 1:
            raise ValueError(
                f"quorum={quorum} < 2f+1 = {2 * self.f + 1}: the "
                "degradation floor is the robustness floor")
        self.quorum = quorum

    def rows(self) -> Dict[int, BufferedUpdate]:
        """The current (not-yet-drained) row bank — read-only view for
        mid-round checkpointing."""
        return dict(self._rows)

    # -- ingest ------------------------------------------------------------

    def add(self, update: ClientUpdate, now: float) -> str:
        """Classify + buffer one update. Returns the decision:
        ``accepted`` | ``replaced`` (fresher duplicate) | ``stale_dropped``
        | ``future`` | ``duplicate`` | ``bad_client`` | ``bad_mask``."""
        cid = update.client_id
        if not 0 <= cid < self.n_clients:
            return "bad_client"
        expect = self._mask_ids.get(update.round_id)
        if expect is not None and update.mask_id != expect:
            return "bad_mask"
        staleness = self.round_id - update.round_id
        if staleness < 0:
            self._future.append(update)
            return "future"
        if staleness > self.staleness_window or (
                staleness > 0 and self.stale_policy == "drop"):
            return "stale_dropped"
        prev = self._rows.get(cid)
        if prev is not None:
            if staleness >= prev.staleness:
                return "duplicate"
            self._rows[cid] = BufferedUpdate(update, staleness, now)
            return "replaced"
        if self.first_update_at is None:
            self.first_update_at = now
        self._rows[cid] = BufferedUpdate(update, staleness, now)
        return "accepted"

    # -- firing decision ---------------------------------------------------

    @property
    def count(self) -> int:
        return len(self._rows)

    def ready(self, now: float) -> bool:
        """Quorum reached, or (timeout enabled) the round has been open past
        the deadline with at least one accepted update."""
        if self.count >= self.quorum:
            return True
        return (self.timeout_s > 0 and self.count >= 1
                and now - self.opened_at >= self.timeout_s)

    def fired_by(self) -> str:
        return "quorum" if self.count >= self.quorum else "timeout"

    def drain(self) -> Dict[int, BufferedUpdate]:
        rows, self._rows = self._rows, {}
        return rows
