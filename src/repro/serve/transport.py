"""Pluggable transport boundary for the streaming parameter server.

Every transport moves the SAME length-prefixed checksummed frames
(``repro.serve.protocol``'s frame layer) between client endpoints and one
:class:`ServerBinding` — the server-side dispatcher that decodes a frame,
drives the :class:`~repro.serve.server.ByzantineRobustServer`, and encodes
the response:

* ``ANNOUNCE_REQ``  -> the current :class:`RoundAnnouncement` frame
  (blocking through an in-flight apply until the next round is open);
* ``UPDATE``        -> ``server.submit`` + an ``ACK("queued")`` frame —
  submission is queue-and-classify, so resubmitting the same update is
  idempotent (the :class:`RoundBuffer` dedups duplicate deliveries);
* a frame whose payload fails its CRC -> the server is told to count a
  protocol fault against the (attributable) sender and the client gets
  ``ACK("bad_checksum")`` — corruption NEVER reaches the batcher.

Two transports ship:

:class:`LoopbackTransport`
    In-process: a client endpoint's ``request()`` runs the binding on the
    calling thread. Frames still encode/decode (float32 values round-trip
    bit-for-bit), so loopback trajectories are bit-identical to the PR 8
    in-process server — the parity gate ``benchmarks/bench_chaos.py``
    enforces.

:class:`TcpTransport`
    Real sockets on localhost (or any interface): a listener thread
    accepts connections, one reader thread per connection splits frames by
    the header's length field (a corrupt payload still frames correctly —
    the CRC is validated later, by the binding) and writes responses back.

Both support ``bind(server)`` / ``unbind()`` re-binding so a chaos
harness can kill a server mid-round and attach a restarted one to the
same endpoints: client requests between unbind and rebind raise
:class:`TransportReset`, which the retrying clients back off and retry.
"""

from __future__ import annotations

import socket
import struct
import threading
from typing import Dict, List, Optional, Tuple

from repro.serve import protocol


class TransportError(Exception):
    """Base class of transport-level delivery failures (retryable)."""


class TransportTimeout(TransportError):
    """The request (or its response) never arrived in time."""


class TransportReset(TransportError):
    """The connection was reset mid-exchange (server kill, socket reset)."""


class ServerBinding:
    """Server-side frame dispatcher shared by every transport."""

    def __init__(self, server, announce_timeout_s: float = 30.0):
        self.server = server
        self.announce_timeout_s = announce_timeout_s

    def handle(self, raw: bytes) -> bytes:
        """Decode one request frame, drive the server, encode the
        response. Never raises on malformed input — protocol faults are
        classified and NACKed, which is what keeps the batcher alive under
        byte-level corruption."""
        try:
            msg_type, sender, payload = protocol.decode_frame(raw)
        except protocol.BadChecksum as e:
            if e.sender is not None and e.sender >= 0:
                self.server.note_protocol_fault(e.sender)
            return protocol.encode_ack(-1, "bad_checksum")
        except protocol.FrameError:
            return protocol.encode_ack(-1, "bad_frame")

        if msg_type == protocol.MSG_ANNOUNCE_REQ:
            try:
                min_round = protocol.decode_announce_req(payload)
            except protocol.FrameError:
                return protocol.encode_ack(-1, "bad_frame")
            try:
                ann = self.server.announce(timeout=self.announce_timeout_s,
                                           min_round=min_round)
            except TimeoutError:
                return protocol.encode_ack(-1, "no_round")
            return protocol.encode_announcement(ann)

        if msg_type == protocol.MSG_UPDATE:
            try:
                update = protocol.decode_update(payload, sender)
            except protocol.FrameError:
                return protocol.encode_ack(-1, "bad_frame")
            if sender >= 0:
                self.server.note_protocol_ok(sender)
            try:
                self.server.submit(update)
            except ValueError as e:
                return protocol.encode_ack(update.round_id,
                                           f"rejected: {e}")
            return protocol.encode_ack(update.round_id, "queued")

        return protocol.encode_ack(-1, "bad_type")


# --------------------------------------------------------------------------
# Loopback: in-process frames, bit-for-bit the PR 8 server
# --------------------------------------------------------------------------


class LoopbackEndpoint:
    """One client's in-process endpoint (thread-safe: the binding locks on
    the server's own condition)."""

    def __init__(self, transport: "LoopbackTransport", client_id: int):
        self._transport = transport
        self.client_id = client_id

    def request(self, raw: bytes, **ctx) -> bytes:
        binding = self._transport._binding
        if binding is None:
            raise TransportReset("loopback: no server bound")
        return binding.handle(raw)

    def close(self) -> None:
        pass


class LoopbackTransport:
    """In-process transport: frames are handed straight to the binding."""

    def __init__(self, server=None, announce_timeout_s: float = 30.0):
        self.announce_timeout_s = announce_timeout_s
        self._binding: Optional[ServerBinding] = None
        if server is not None:
            self.bind(server)

    def bind(self, server) -> "LoopbackTransport":
        self._binding = ServerBinding(server, self.announce_timeout_s)
        return self

    def unbind(self) -> None:
        self._binding = None

    def connect(self, client_id: int) -> LoopbackEndpoint:
        return LoopbackEndpoint(self, client_id)

    def close(self) -> None:
        self.unbind()


# --------------------------------------------------------------------------
# TCP: real sockets, framed by the header length field
# --------------------------------------------------------------------------


def _read_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionResetError("peer closed mid-frame")
        buf += chunk
    return buf


def _read_frame(sock: socket.socket) -> bytes:
    header = _read_exact(sock, protocol.HEADER_SIZE)
    total = protocol.frame_length(header)   # raises FrameError on bad magic
    return header + _read_exact(sock, total - protocol.HEADER_SIZE)


class TcpEndpoint:
    """One client's socket endpoint. Connects lazily, reconnects after a
    reset (the transport's address survives a server restart)."""

    def __init__(self, transport: "TcpTransport", client_id: int,
                 timeout_s: float = 2.0):
        self._transport = transport
        self.client_id = client_id
        self.timeout_s = timeout_s
        self._sock: Optional[socket.socket] = None

    def _connect(self) -> socket.socket:
        addr = self._transport.address
        if addr is None:
            raise TransportReset("tcp: no server bound")
        try:
            sock = socket.create_connection(addr, timeout=self.timeout_s)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except socket.timeout as e:
            raise TransportTimeout(f"tcp connect to {addr}: {e}") from e
        except OSError as e:
            raise TransportReset(f"tcp connect to {addr}: {e}") from e
        return sock

    def request(self, raw: bytes, **ctx) -> bytes:
        if self._sock is None:
            self._sock = self._connect()
        try:
            self._sock.sendall(raw)
            return _read_frame(self._sock)
        except socket.timeout as e:
            self.close()
            raise TransportTimeout(f"tcp request: {e}") from e
        except (ConnectionError, BrokenPipeError, OSError,
                protocol.FrameError) as e:
            self.close()
            raise TransportReset(f"tcp request: {e}") from e

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None


class TcpTransport:
    """Socket transport: a listener + one reader thread per connection."""

    def __init__(self, server=None, host: str = "127.0.0.1", port: int = 0,
                 announce_timeout_s: float = 30.0,
                 client_timeout_s: float = 2.0):
        self.host = host
        self._requested_port = port
        self.announce_timeout_s = announce_timeout_s
        self.client_timeout_s = client_timeout_s
        self._binding: Optional[ServerBinding] = None
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: List[socket.socket] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.address: Optional[Tuple[str, int]] = None
        if server is not None:
            self.bind(server)

    def bind(self, server) -> "TcpTransport":
        if self._listener is not None:
            self.unbind()
        self._binding = ServerBinding(server, self.announce_timeout_s)
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self.host, self._requested_port))
        listener.listen(64)
        # a finite accept timeout so the accept thread polls _stop: a
        # close() from another thread does NOT wake a blocked accept() on
        # Linux — the in-flight syscall keeps the kernel socket alive and
        # the port stays bound (EADDRINUSE on the crash-restart rebind)
        listener.settimeout(0.25)
        # keep the SAME port across a rebind so endpoints survive restarts
        self._requested_port = listener.getsockname()[1]
        self.address = listener.getsockname()[:2]
        self._listener = listener
        self._stop.clear()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="serve-tcp-accept", daemon=True)
        self._accept_thread.start()
        return self

    def unbind(self) -> None:
        self._stop.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
            self._accept_thread = None
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                # abortive close (RST): a graceful close leaves the
                # (host, port) tuples in FIN_WAIT/TIME_WAIT and blocks the
                # crash-restart rebind of the SAME port with EADDRINUSE;
                # retrying clients reconnect regardless
                c.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                             struct.pack("ii", 1, 0))
            except OSError:
                pass
            try:
                # close() alone leaves a reader thread blocked in recv()
                # holding the kernel socket open — shutdown() wakes it
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._binding = None

    close = unbind

    def connect(self, client_id: int) -> TcpEndpoint:
        return TcpEndpoint(self, client_id, timeout_s=self.client_timeout_s)

    # -- server-side loops -------------------------------------------------

    def _accept_loop(self) -> None:
        listener = self._listener
        while not self._stop.is_set() and listener is not None:
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue                    # poll _stop (see bind())
            except OSError:
                return                      # listener closed (unbind)
            conn.settimeout(None)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="serve-tcp-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        binding = self._binding
        try:
            while not self._stop.is_set() and binding is not None:
                try:
                    raw = _read_frame(conn)
                except protocol.FrameError:
                    return                  # unframeable stream: drop conn
                conn.sendall(binding.handle(raw))
        except (ConnectionError, BrokenPipeError, OSError):
            pass
        finally:
            try:
                conn.close()
            except OSError:
                pass
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)


TRANSPORTS = ("loopback", "tcp")


def make_transport(kind: str, **kw):
    """Build an unbound transport by name (``loopback`` | ``tcp``)."""
    if kind == "loopback":
        return LoopbackTransport(**kw)
    if kind == "tcp":
        return TcpTransport(**kw)
    raise ValueError(
        f"unknown transport {kind!r} (expected one of {TRANSPORTS})")
