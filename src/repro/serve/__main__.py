"""CLI: stand up the streaming byzantine-robust parameter server against a
simulated client pool, wired through the adversarial scenario registry.

    PYTHONPATH=src python -m repro.serve --scenario fig1-alie --rounds 200
    PYTHONPATH=src python -m repro.serve --scenario stateless-linear \
        --cell rosdhb/foe/median --drop-prob 0.2 --timeout-ms 50 \
        --staleness-window 2 --stale-policy discount
    PYTHONPATH=src python -m repro.serve --scenario chaos-serve \
        --chaos combined --transport loopback --rounds 30

``--chaos NAME`` routes the run through the fault-injected transport
harness (``repro.serve.chaos``): every frame crosses the selected
``--transport`` through a seeded fault plan and retry/backoff clients;
``--list-chaos`` enumerates the scenarios.

Scenario cells with a non-serveable algorithm (dasha: its per-client
control variates go stale under partial participation) are rejected loudly;
pick a serveable cell with ``--cell`` or ``--list-cells``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Optional, Sequence

from repro.adversary import registry
from repro.core import algorithms as alg
from repro.core.sweep import quadratic_testbed
from repro.serve import chaos as chaos_mod
from repro.serve.client import ClientBehavior, ClientPool
from repro.serve.server import ByzantineRobustServer, ServeConfig, run_service
from repro.serve.transport import TRANSPORTS


def _pick_cell(name: str, cell: Optional[str]):
    cells = registry.expand_scenario(name)
    if cell is not None:
        match = [s for s in cells if s.label == cell
                 or s.label.endswith("/" + cell) or cell in s.label]
        if not match:
            raise SystemExit(
                f"no cell matching {cell!r} in scenario {name!r}; cells:\n  "
                + "\n  ".join(s.label for s in cells))
        return match[0]
    serveable = [s for s in cells
                 if s.cfg.name in alg.SERVE_ALGORITHMS]
    if not serveable:
        raise SystemExit(
            f"scenario {name!r} has no serveable cell "
            f"(serveable algorithms: {'|'.join(alg.SERVE_ALGORITHMS)})")
    return serveable[0]


def main(argv: Optional[Sequence[str]] = None) -> dict:
    p = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="streaming byzantine-robust parameter server")
    p.add_argument("--scenario", default="fig1-alie",
                   help="registry scenario name (--list-scenarios)")
    p.add_argument("--cell", default=None,
                   help="cell label (or substring) within the scenario")
    p.add_argument("--list-scenarios", action="store_true")
    p.add_argument("--list-cells", action="store_true")
    p.add_argument("--rounds", type=int, default=100)
    p.add_argument("--d", type=int, default=64,
                   help="quadratic-testbed model dimension")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quorum", type=int, default=None,
                   help="clients required to fire (default: all n)")
    p.add_argument("--timeout-ms", type=float, default=0.0,
                   help="round wall-clock deadline (0 = quorum only)")
    p.add_argument("--staleness-window", type=int, default=0)
    p.add_argument("--stale-policy", default="discount",
                   choices=("discount", "drop"))
    p.add_argument("--drop-prob", type=float, default=0.0)
    p.add_argument("--late-prob", type=float, default=0.0)
    p.add_argument("--late-rounds", type=int, default=1)
    p.add_argument("--stragglers", default="",
                   help="comma-separated always-late client ids")
    p.add_argument("--straggle-rounds", type=int, default=1)
    p.add_argument("--checkpoint-every", type=int, default=0)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--chaos", default=None,
                   help="run through the fault-injected transport harness "
                        "with this chaos scenario (--list-chaos)")
    p.add_argument("--transport", default=None, choices=TRANSPORTS,
                   help="transport for --chaos runs (default: the "
                        "scenario's own, usually loopback)")
    p.add_argument("--list-chaos", action="store_true")
    p.add_argument("--out", default=None, help="optional JSON output path")
    args = p.parse_args(argv)

    if args.list_scenarios:
        print(registry.describe())
        return {}
    if args.list_chaos:
        print(chaos_mod.describe_chaos())
        return {}
    if args.list_cells:
        for s in registry.expand_scenario(args.scenario):
            tag = ("" if s.cfg.name in alg.SERVE_ALGORITHMS
                   else "  [not serveable]")
            print(f"{s.label}{tag}")
        return {}

    scenario = _pick_cell(args.scenario, args.cell)
    cfg = scenario.cfg
    loss_fn, params0, batch_fn, _ = quadratic_testbed(cfg.n_workers,
                                                      d=args.d)

    if args.chaos is not None:
        sc = chaos_mod.get_chaos(args.chaos)
        if args.transport is not None:
            sc = dataclasses.replace(sc, transport=args.transport)
        print(f"[serve] chaos {sc.name!r} over {sc.transport} transport: "
              f"{scenario.label} n={cfg.n_workers} f={cfg.f}")
        res = chaos_mod.run_chaos(
            cfg, params0, batch_fn, loss_fn, sc, args.rounds,
            seed=args.seed, checkpoint_dir=args.checkpoint_dir)
        summary = {
            "scenario": scenario.label, "chaos": sc.name,
            "transport": sc.transport,
            "rounds_driven": res.rounds_driven,
            "restarts": res.restarts,
            "all_rounds_terminated": res.all_rounds_terminated(),
            "step_traces": res.step_traces,
            "injected_faults": res.injected,
            "client_stats": res.client_stats,
            "servers": res.summaries,
        }
        print(json.dumps(summary, indent=2))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(summary, f, indent=2)
            print(f"[serve] wrote {args.out}", file=sys.stderr)
        return summary

    serve = ServeConfig(
        quorum=args.quorum, timeout_s=args.timeout_ms / 1e3,
        staleness_window=args.staleness_window,
        stale_policy=args.stale_policy,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir)
    behavior = ClientBehavior(
        drop_prob=args.drop_prob, late_prob=args.late_prob,
        late_rounds=args.late_rounds,
        stragglers=tuple(int(x) for x in args.stragglers.split(",") if x),
        straggle_rounds=args.straggle_rounds, seed=args.seed)
    server = ByzantineRobustServer(cfg, params0, serve, seed=args.seed)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn, behavior=behavior)
    print(f"[serve] {scenario.label}: n={cfg.n_workers} f={cfg.f} "
          f"agg={cfg.aggregator.name} backend={server.agg_backend} "
          f"quorum={server._buffer.quorum} "
          f"timeout={serve.timeout_s * 1e3:.0f}ms")
    run_service(server, pool, args.rounds)
    summary = server.metrics.summary()
    summary["scenario"] = scenario.label
    summary["step_traces"] = server.step_traces
    summary["final_honest_loss"] = float(
        pool.last_losses[cfg.f:].mean())
    print(json.dumps(summary, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[serve] wrote {args.out}", file=sys.stderr)
    return summary


if __name__ == "__main__":
    main()
