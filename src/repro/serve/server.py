"""The continuously-batching byzantine-robust parameter server.

Architecture (the offline-inference queue/thread/batcher idiom around one
jitted engine step):

* ``submit()`` enqueues :class:`~repro.serve.protocol.ClientUpdate`s onto a
  ``queue.Queue`` from any thread;
* the **ingest thread** drains the queue into the
  :class:`~repro.serve.buffer.RoundBuffer` (quorum / timeout / staleness
  classification) and wakes the batcher;
* the **batcher thread** watches the buffer and, on quorum-or-timeout,
  fires ONE jitted aggregate-and-apply step — the same ``make_aggregator``
  rule (Pallas kernels included via ``AggregatorConfig.use_pallas``) and
  rosdhb/robust_dgd/dgd apply halves the simulator runs
  (``algorithms.make_serve_apply_fn``) against the ``StateLayout``-pruned
  ``ServerState``. Absent clients are padded: participation enters the step
  as a traced ``present`` row mask and staleness as a traced ``discount``
  weight over a static ``[n, D]`` wire bank, so the step **never retraces
  across participation levels** (``step_traces`` counts XLA programs; the
  bench gates it at exactly 1).

The PRNG chain replicates the simulator's exactly — per round the carried
key splits into ``(carry, round_key)`` and the round key into
``(mask_key, atk_key)``, both broadcast in the round announcement — so with
full participation and zero timeout the served parameter trajectory is
bit-for-bit ``Simulator.rollout``'s (tests/test_serve.py).

``repro.checkpoint`` is wired in: with ``checkpoint_every > 0`` the server
periodically persists ``{params, ServerState, key}`` and a fresh server can
``restore()`` and continue with identical results under full participation.
Checkpoints also carry the OPEN round's announcement keys plus the
in-flight ``RoundBuffer`` rows, so a server killed *mid-round* restores
into the interrupted round — same announcement (clients' already-sent
updates still pass mask validation), already-ingested rows re-fed — and
resumes instead of replaying from the last boundary.

Fault domain (the chaos-hardening layer):

* **typed timeouts** — ``announce``/``wait_round`` raise
  :class:`ServeTimeout` carrying the round id, effective/base quorum, and
  buffer classification counts, so a chaos test can assert on *why* a
  round stalled;
* **protocol-fault budget** — corrupt/bad-checksum frames reported by the
  transport binding (``note_protocol_fault``) are tracked per client;
  a client whose corruption persists past ``fault_tolerance`` frames with
  no valid update in between is classified *protocol-faulty* and counted
  against the Byzantine budget ``f``. Once protocol-faulty + declared-
  Byzantine clients would exceed ``f``, the server rejects loudly
  (:class:`FaultBudgetExceeded` from ``wait_round``) — the robustness
  guarantee is void and silence would be a lie;
* **graceful quorum degradation** — after ``degrade_after`` consecutive
  wall-clock-fired rounds the effective quorum steps down one client
  (floor: the validated ``2f + 1``), and after ``recover_after``
  consecutive quorum-fired rounds it steps back up; every transition is
  logged and surfaced in ``ServeMetrics.quorum_transitions``;
* **liveness watchdog** — a round open longer than ``watchdog_s`` with no
  way to fire records a watchdog event and makes ``announce``/
  ``wait_round`` fail fast with a ``reason="watchdog"`` ServeTimeout
  instead of hanging; the event is marked resolved if the round does
  eventually fire.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as G
from repro.core import algorithms as alg
from repro.serve import protocol
from repro.serve.buffer import RoundBuffer
from repro.serve.metrics import RoundRecord, ServeMetrics
from repro.utils import tree as T


class ServeTimeout(TimeoutError):
    """A typed round timeout: WHY the wait failed, not just that it did.

    Attributes:
      round_id: the round being waited on.
      quorum: the effective quorum at raise time (degradation included).
      base_quorum: the configured quorum.
      buffer_count: accepted updates currently buffered.
      decisions: total ingest-classification counters at raise time.
      reason: ``"deadline"`` (the caller's wait expired) or
        ``"watchdog"`` (the liveness watchdog declared the round stalled).
    """

    def __init__(self, message: str, *, round_id: int, quorum: int,
                 base_quorum: int, buffer_count: int,
                 decisions: Dict[str, int], reason: str = "deadline"):
        super().__init__(message)
        self.round_id = round_id
        self.quorum = quorum
        self.base_quorum = base_quorum
        self.buffer_count = buffer_count
        self.decisions = dict(decisions)
        self.reason = reason


class FaultBudgetExceeded(RuntimeError):
    """Protocol-faulty + declared-Byzantine clients exceed ``f`` — the
    (f, kappa)-robust aggregation guarantee no longer holds, so the
    server fails loudly instead of silently serving unguaranteed rounds."""

    def __init__(self, message: str, *, faulty: Tuple[int, ...], f: int):
        super().__init__(message)
        self.faulty = faulty
        self.f = f


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (the algorithm itself lives in
    ``AlgorithmConfig``).

    Attributes:
      quorum: distinct clients required to fire a round; ``None`` = all
        ``n_workers``. Must be at least ``2f + 1`` (validated loudly).
      timeout_s: wall-clock round deadline; after it, a round fires with
        whatever partial participation arrived (at least one update).
        ``0`` disables the clock — rounds fire on quorum only.
      staleness_window: accept updates up to this many rounds late.
      stale_policy: ``discount`` (late updates weighted ``beta^k``) or
        ``drop``.
      checkpoint_every: persist server state every k fired rounds
        (0 = never).
      checkpoint_dir: where checkpoints go (required if checkpointing).
      degrade_after: after this many CONSECUTIVE wall-clock-fired rounds,
        step the effective quorum down one client toward the ``2f + 1``
        floor (0 = degradation off).
      recover_after: after this many consecutive quorum-fired rounds at a
        degraded level, step the effective quorum back up one client
        toward the configured quorum.
      watchdog_s: liveness watchdog — a round open this long without
        firing records a stall event and turns ``announce``/``wait_round``
        into fast loud :class:`ServeTimeout`(reason="watchdog") failures
        instead of hangs (0 = watchdog off).
      fault_tolerance: consecutive corrupt frames (with no valid update in
        between) after which a client is classified protocol-faulty and
        counted against the Byzantine budget ``f``.
    """

    quorum: Optional[int] = None
    timeout_s: float = 0.0
    staleness_window: int = 0
    stale_policy: str = "discount"
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    degrade_after: int = 0
    recover_after: int = 2
    watchdog_s: float = 0.0
    fault_tolerance: int = 3


@dataclasses.dataclass(frozen=True)
class RoundResult:
    """What the batcher reports back for one fired round."""

    round_id: int
    n_updates: int
    fired_by: str
    client_ids: Tuple[int, ...]
    staleness: Tuple[int, ...]
    latency_s: float


class ByzantineRobustServer:
    """Streaming parameter server for one serveable algorithm config."""

    def __init__(self, cfg: alg.AlgorithmConfig, params0,
                 serve: Optional[ServeConfig] = None, *, seed: int = 0):
        # same loud rejection make_wire_fn/make_serve_apply_fn give
        alg._check_serveable(cfg.name)
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        self.spec = T.make_flat_spec(params0)
        self.d = self.spec.size
        self.n = cfg.n_workers
        # host-side staleness discount rate: the momentum coefficient (a
        # geometric decay also applied to the bankless DGD rules)
        self._beta = np.float32(cfg.resolved_beta())
        self.params_flat = T.tree_ravel(params0, self.spec)
        # the serveable algorithms all run the pruned StateLayout (no
        # mirror/prev_grad leaves); the adversary's memory lives client-side
        # (the pool simulates the attack), so the server carries none
        self.server_state = alg.init_state(cfg, self.spec.padded_size
                                           )._replace(attack=None)
        self._key = jax.random.PRNGKey(seed)
        self.agg_backend = G.kernel_backend_label(cfg.aggregator.use_pallas)
        self._per_update_bytes = protocol.update_payload_bytes(cfg, self.d)

        # ONE jitted aggregate-and-apply step; participation (present) and
        # staleness (discount) are traced DATA over static [n, D] shapes,
        # so every participation level shares one compiled program.
        apply_fn = alg.make_serve_apply_fn(cfg, G.make_aggregator(
            cfg.aggregator))
        self.step_traces = 0

        def _step(params_flat, state, wire, present, discount):
            self.step_traces += 1  # trace-time (python) side effect only
            r, new_state = apply_fn(state, wire, present, discount)
            return alg.apply_direction(params_flat, r, cfg.gamma), new_state

        self._step = jax.jit(_step)

        self.metrics = ServeMetrics()
        self._buffer = RoundBuffer(
            n_clients=self.n, f=cfg.f, quorum=self.serve.quorum,
            timeout_s=self.serve.timeout_s,
            staleness_window=self.serve.staleness_window,
            stale_policy=self.serve.stale_policy)
        if self.serve.checkpoint_every and not self.serve.checkpoint_dir:
            raise ValueError("checkpoint_every > 0 needs a checkpoint_dir")

        self._queue: "queue.Queue[protocol.ClientUpdate]" = queue.Queue()
        self._cond = threading.Condition()
        self._results: Dict[int, RoundResult] = {}
        self._rounds_fired = 0
        self._round_id = 0
        self._ann: Optional[protocol.RoundAnnouncement] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # -- fault domain state -------------------------------------------
        # graceful quorum degradation counters
        self._consec_timeout = 0
        self._consec_quorum = 0
        # protocol-fault classification (transport-reported corruption)
        self._fault_counts: Dict[int, int] = {}
        self._protocol_faulty: set = set()
        self._fault_budget: Optional[FaultBudgetExceeded] = None
        # liveness watchdog: the round id whose stall is CURRENTLY declared
        # (cleared when updates start flowing again), and the last round an
        # event was recorded for (at most one event per round)
        self._watchdog_round: Optional[int] = None
        self._watchdog_fired_round = -1
        self._open_round(time.perf_counter())

    # -- round lifecycle (callers hold self._cond unless noted) ------------

    def _open_round(self, now: float, reopen_buffer: bool = True) -> None:
        """Open ``self._round_id``: advance the key chain exactly like the
        simulator (carry split, then mask/attack split) and broadcast the
        announcement. The batcher passes ``reopen_buffer=False`` — it
        already advanced the buffer at drain time, and re-opening here
        would wipe updates ingested while the apply ran."""
        self._key, round_key = jax.random.split(self._key)
        mask_key, atk_key = jax.random.split(round_key)
        self._ann = protocol.RoundAnnouncement(
            round_id=self._round_id,
            params=np.asarray(self.params_flat),
            mask_key=np.asarray(mask_key), atk_key=np.asarray(atk_key))
        if reopen_buffer:
            self._buffer.open(self._round_id, now,
                              mask_id=self._ann.mask_id)
        else:
            self._buffer.register_mask(self._round_id, self._ann.mask_id)
        # the liveness clock starts when the round is announced, not when
        # the buffer opened (the batcher opens the buffer BEFORE the apply,
        # which can include a multi-second first compile)
        self._ann_open_t = now

    # -- public API --------------------------------------------------------

    def start(self) -> "ByzantineRobustServer":
        if self._threads:
            return self
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._ingest_loop, name="serve-ingest",
                             daemon=True),
            threading.Thread(target=self._batcher_loop, name="serve-batcher",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)
        self._threads = []

    def submit(self, update: protocol.ClientUpdate) -> None:
        """Enqueue one client update (thread-safe, non-blocking)."""
        values = np.asarray(update.values)
        if values.shape != (self.spec.padded_size,):
            raise ValueError(
                f"update values shape {values.shape} != "
                f"[padded_D={self.spec.padded_size}]")
        self._queue.put(update)
        if self._watchdog_round is not None:
            # an enqueued update is imminent progress: lift the stall
            # declaration so waiters wait for the (now likely) fire
            # instead of failing fast on a recovering round
            with self._cond:
                self._watchdog_round = None
                self._cond.notify_all()

    def _serve_timeout(self, message: str, round_id: int,
                       reason: str) -> ServeTimeout:
        """Build a typed timeout from the current buffer/quorum state
        (caller holds ``self._cond``)."""
        return ServeTimeout(
            message, round_id=round_id, quorum=self._buffer.quorum,
            base_quorum=self._buffer.base_quorum,
            buffer_count=self._buffer.count,
            decisions=self.metrics.decisions, reason=reason)

    def announce(self, timeout: float = 60.0,
                 min_round: int = 0) -> protocol.RoundAnnouncement:
        """The current round's broadcast (blocks through an in-flight
        apply until a round ``>= min_round`` is open)."""
        deadline = time.perf_counter() + timeout
        with self._cond:
            while (self._ann is None
                   or self._ann.round_id != self._round_id
                   or self._round_id < min_round):
                if self._watchdog_round == self._round_id:
                    raise self._serve_timeout(
                        f"round {self._round_id} stalled (liveness "
                        f"watchdog): {self._buffer.count}/"
                        f"{self._buffer.quorum} updates after "
                        f"{self.serve.watchdog_s}s",
                        self._round_id, reason="watchdog")
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._cond.wait(timeout=rem):
                    raise self._serve_timeout(
                        f"no open round announcement >= {min_round} "
                        f"within {timeout}s (open round {self._round_id}, "
                        f"{self._buffer.count}/{self._buffer.quorum} "
                        "buffered)", self._round_id, reason="deadline")
            return self._ann

    def wait_round(self, round_id: int, timeout: float = 60.0) -> RoundResult:
        """Block until ``round_id`` has fired and been applied.

        Raises :class:`ServeTimeout` (typed: round id, quorum state,
        buffer counts, reason) when the wait expires or the liveness
        watchdog has declared the round stalled, and
        :class:`FaultBudgetExceeded` once protocol-faulty + declared-
        Byzantine clients exceed the budget ``f``."""
        deadline = time.perf_counter() + timeout
        with self._cond:
            while round_id not in self._results:
                if self._fault_budget is not None:
                    raise self._fault_budget
                if self._watchdog_round is not None and \
                        round_id >= self._watchdog_round:
                    raise self._serve_timeout(
                        f"round {self._watchdog_round} stalled (liveness "
                        f"watchdog): {self._buffer.count}/"
                        f"{self._buffer.quorum} updates buffered after "
                        f"{self.serve.watchdog_s}s open",
                        self._watchdog_round, reason="watchdog")
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._cond.wait(timeout=rem):
                    raise self._serve_timeout(
                        f"round {round_id} did not fire within {timeout}s "
                        f"(buffer has {self._buffer.count}/"
                        f"{self._buffer.quorum} updates; with timeout_s=0 a "
                        "round below quorum never fires)",
                        round_id, reason="deadline")
            if self._fault_budget is not None:
                raise self._fault_budget
            return self._results[round_id]

    @property
    def round_id(self) -> int:
        with self._cond:
            return self._round_id

    @property
    def effective_quorum(self) -> int:
        """The current (possibly degraded) firing quorum."""
        with self._cond:
            return self._buffer.quorum

    # -- protocol-fault budget (called by the transport binding) -----------

    def note_protocol_fault(self, client_id: int) -> None:
        """A corrupt/bad-checksum frame arrived attributable to
        ``client_id``. Counted, never crashing: past ``fault_tolerance``
        consecutive corrupt frames the client is classified
        protocol-faulty and charged against the Byzantine budget ``f``."""
        if not 0 <= client_id < self.n:
            return
        with self._cond:
            self.metrics.observe_decision("bad_checksum",
                                          round_id=self._buffer.round_id)
            c = self._fault_counts.get(client_id, 0) + 1
            self._fault_counts[client_id] = c
            if (c >= self.serve.fault_tolerance
                    and client_id not in self._protocol_faulty):
                self._protocol_faulty.add(client_id)
                self._check_fault_budget()
            self._cond.notify_all()

    def note_protocol_ok(self, client_id: int) -> None:
        """A well-formed frame from ``client_id`` — its transport path
        delivers valid payloads again, so clear its protocol-fault state
        (transient corruption repaired by retransmission is not
        Byzantine behaviour)."""
        with self._cond:
            self._fault_counts.pop(client_id, None)
            self._protocol_faulty.discard(client_id)

    @property
    def protocol_faulty(self) -> Tuple[int, ...]:
        with self._cond:
            return tuple(sorted(self._protocol_faulty))

    def _check_fault_budget(self) -> None:
        """Caller holds ``self._cond``. Declared-Byzantine rows are
        ``[0, f)`` (the pool convention); the budget breaks when the union
        with protocol-faulty clients exceeds ``f``."""
        declared = set(range(self.cfg.f))
        implicated = declared | self._protocol_faulty
        if len(implicated) > self.cfg.f and self._fault_budget is None:
            faulty = tuple(sorted(self._protocol_faulty))
            self.metrics.observe_fault_budget(
                self._buffer.round_id, faulty, self.cfg.f, self.cfg.f)
            print(f"[serve] FAULT BUDGET EXCEEDED at round "
                  f"{self._buffer.round_id}: protocol-faulty clients "
                  f"{faulty} + {self.cfg.f} declared byzantine > f="
                  f"{self.cfg.f} — robustness guarantee void")
            self._fault_budget = FaultBudgetExceeded(
                f"protocol-faulty clients {faulty} + {self.cfg.f} "
                f"declared byzantine exceed the budget f={self.cfg.f}: "
                "the (f, kappa)-robust aggregation guarantee no longer "
                "covers this service", faulty=faulty, f=self.cfg.f)

    # -- checkpointing -----------------------------------------------------

    def _checkpoint_tree(self):
        """The persisted state: params + ServerState + PRNG carry, PLUS
        the open round's announcement keys and the in-flight RoundBuffer
        rows — the mid-round recovery payload. The inflight slabs are
        statically shaped ``[n, D]``/``[n]`` so ``repro.checkpoint`` can
        restore into a fresh server's tree."""
        n, P = self.n, self.spec.padded_size
        inflight_values = np.zeros((n, P), np.float32)
        inflight_present = np.zeros((n,), bool)
        inflight_round = np.full((n,), -1, np.int64)
        inflight_mask = np.zeros((n,), np.uint64)
        for cid, row in self._buffer.rows().items():
            inflight_values[cid] = row.update.values
            inflight_present[cid] = True
            inflight_round[cid] = row.update.round_id
            inflight_mask[cid] = np.uint64(row.update.mask_id)
        ann = self._ann
        return {"params_flat": self.params_flat,
                "momentum": self.server_state.momentum,
                "step": self.server_state.step,
                "key": self._key,
                "ann_round": np.int64(-1 if ann is None else ann.round_id),
                "ann_mask_key": (np.zeros_like(np.asarray(self._key))
                                 if ann is None
                                 else np.asarray(ann.mask_key)),
                "ann_atk_key": (np.zeros_like(np.asarray(self._key))
                                if ann is None
                                else np.asarray(ann.atk_key)),
                "inflight_values": inflight_values,
                "inflight_present": inflight_present,
                "inflight_round": inflight_round,
                "inflight_mask": inflight_mask}

    def save_checkpoint(self, path: Optional[str] = None) -> str:
        """Persist ``{params, ServerState, key}`` + the open round's
        announcement keys + in-flight buffer rows via ``repro.checkpoint``
        (callable any time the server is paused; the batcher calls it
        between rounds when ``checkpoint_every`` is set)."""
        from repro.checkpoint import save
        with self._cond:
            # drain the ingest queue into the buffer first: those updates
            # were already ACKed "queued" to their clients, so a durable
            # snapshot must include them (otherwise a mid-round restore
            # silently loses acknowledged updates)
            now = time.perf_counter()
            while True:
                try:
                    u = self._queue.get_nowait()
                except queue.Empty:
                    break
                self.metrics.observe_decision(
                    self._buffer.add(u, now),
                    round_id=self._buffer.round_id)
            if path is None:
                path = os.path.join(self.serve.checkpoint_dir or ".",
                                    f"serve_round{self._round_id:06d}")
            return save(path, self._checkpoint_tree(),
                        metadata={"algo": self.cfg.name, "d": self.d,
                                  "n_workers": self.n},
                        step=self._round_id)

    def restore(self, path: str) -> int:
        """Load a checkpoint into this (not-yet-started) server and reopen
        its round. Returns the restored round id.

        Boundary checkpoints (the ``checkpoint_every`` path) restore the
        NEXT round by advancing the PRNG chain exactly like the live
        server. A checkpoint taken mid-round additionally carries the open
        round's announcement keys and the already-ingested buffer rows, so
        the restored server *resumes the interrupted round*: the identical
        announcement is re-broadcast (clients' in-flight updates still
        pass mask validation) and the saved rows are re-fed through the
        buffer's classification."""
        from repro.checkpoint import latest_step, restore
        if self._threads:
            raise RuntimeError("restore() before start()")
        tree = restore(path, self._checkpoint_tree())
        self.params_flat = jnp.asarray(tree["params_flat"])
        self.server_state = self.server_state._replace(
            momentum=jnp.asarray(tree["momentum"]),
            step=jnp.asarray(tree["step"]))
        self._key = jnp.asarray(tree["key"])
        step = latest_step(path)
        self._round_id = int(step) if step is not None else 0
        self._results = {}
        now = time.perf_counter()
        if int(tree["ann_round"]) == self._round_id:
            # mid-round checkpoint: the interrupted round's keys were
            # already split off the chain — rebroadcast the SAME
            # announcement instead of splitting again
            self._ann = protocol.RoundAnnouncement(
                round_id=self._round_id,
                params=np.asarray(self.params_flat),
                mask_key=np.asarray(tree["ann_mask_key"]),
                atk_key=np.asarray(tree["ann_atk_key"]))
            self._buffer.open(self._round_id, now,
                              mask_id=self._ann.mask_id)
            self._ann_open_t = now
        else:
            self._open_round(now)
        # re-feed the in-flight rows through classification (stale rows
        # re-register their stored mask ids; current-round rows must match
        # the regenerated mask — identical by PRNG determinism)
        present = np.asarray(tree["inflight_present"])
        for cid in np.nonzero(present)[0]:
            cid = int(cid)
            rid = int(tree["inflight_round"][cid])
            mid = int(tree["inflight_mask"][cid])
            if rid < self._round_id:
                self._buffer.register_mask(rid, mid)
            u = protocol.ClientUpdate(
                client_id=cid, round_id=rid, mask_id=mid,
                values=np.asarray(tree["inflight_values"][cid]),
                payload_bytes=self._per_update_bytes)
            self.metrics.observe_decision(self._buffer.add(u, now),
                                          round_id=self._round_id)
        return self._round_id

    # -- service loops -----------------------------------------------------

    def _ingest_loop(self) -> None:
        while not self._stop.is_set():
            try:
                u = self._queue.get(timeout=0.02)
            except queue.Empty:
                continue
            with self._cond:
                status = self._buffer.add(u, time.perf_counter())
                self.metrics.observe_decision(status,
                                              round_id=self._buffer.round_id)
                if (status in ("accepted", "replaced")
                        and self._watchdog_round == self._buffer.round_id):
                    # progress: updates are flowing again, so the round is
                    # no longer stalled — stop failing waiters fast (the
                    # recorded event resolves if/when the round fires)
                    self._watchdog_round = None
                self._cond.notify_all()

    def _watchdog_check(self, now: float) -> None:
        """Caller holds ``self._cond``: declare the open round stalled
        once it has been open past ``watchdog_s`` (at most once per
        round). Blocked waiters fail loudly instead of hanging."""
        wd = self.serve.watchdog_s
        if (wd > 0 and self._watchdog_round != self._round_id
                and self._watchdog_fired_round != self._round_id
                and now - self._ann_open_t >= wd):
            self._watchdog_round = self._round_id
            self._watchdog_fired_round = self._round_id
            open_s = now - self._ann_open_t
            self.metrics.observe_watchdog(
                self._round_id, open_s, self._buffer.count,
                self._buffer.quorum)
            print(f"[serve] WATCHDOG: round {self._round_id} stalled — "
                  f"{self._buffer.count}/{self._buffer.quorum} updates "
                  f"after {open_s:.2f}s open "
                  f"(timeout_s={self.serve.timeout_s})")
            self._cond.notify_all()

    def _adjust_quorum(self, fired_by: str, round_id: int) -> None:
        """Caller holds ``self._cond``. Graceful degradation: K
        consecutive wall-clock firings step the effective quorum down one
        client toward the 2f+1 floor; consecutive quorum firings at a
        degraded level step it back up toward the configured quorum."""
        if self.serve.degrade_after <= 0:
            return
        buf = self._buffer
        floor = max(2 * self.cfg.f + 1, 1)
        if fired_by == "timeout":
            self._consec_timeout += 1
            self._consec_quorum = 0
            if (self._consec_timeout >= self.serve.degrade_after
                    and buf.quorum > floor):
                old = buf.quorum
                buf.set_quorum(old - 1)
                self._consec_timeout = 0
                self.metrics.observe_quorum_transition(
                    round_id, old, buf.quorum, "degrade")
                print(f"[serve] quorum degraded {old} -> {buf.quorum} "
                      f"after {self.serve.degrade_after} consecutive "
                      f"timeout-fired rounds (floor 2f+1 = {floor})")
        else:
            self._consec_quorum += 1
            self._consec_timeout = 0
            if (self._consec_quorum >= self.serve.recover_after
                    and buf.quorum < buf.base_quorum):
                old = buf.quorum
                buf.set_quorum(old + 1)
                self._consec_quorum = 0
                self.metrics.observe_quorum_transition(
                    round_id, old, buf.quorum, "recover")
                print(f"[serve] quorum recovered {old} -> {buf.quorum} "
                      f"(configured {buf.base_quorum})")

    def _batcher_loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                now = time.perf_counter()
                if not self._buffer.ready(now):
                    self._watchdog_check(now)
                    if self._buffer.timeout_s > 0:
                        wait = max(1e-3, min(
                            0.02, self._buffer.opened_at
                            + self._buffer.timeout_s - now))
                    else:
                        wait = 0.05
                    if self.serve.watchdog_s > 0:
                        wait = min(wait, max(1e-3, self._ann_open_t
                                             + self.serve.watchdog_s - now))
                    self._cond.wait(timeout=wait)
                    continue
                fired_by = self._buffer.fired_by()
                fired_quorum = self._buffer.quorum
                if self._watchdog_fired_round == self._round_id:
                    # the stalled round is firing after all: resolve it
                    self.metrics.resolve_watchdog(self._round_id)
                if self._watchdog_round == self._round_id:
                    self._watchdog_round = None
                rows = self._buffer.drain()
                opened_at = self._buffer.opened_at
                round_id = self._round_id
                self._adjust_quorum(fired_by, round_id)
                # advance the round *now* so updates arriving during the
                # apply are classified against the next round (stale for
                # this one); the next announcement follows after the apply
                self._round_id = round_id + 1
                for _, status in self._buffer.open(self._round_id, now):
                    self.metrics.observe_decision(status,
                                                  round_id=self._round_id)

            # build the padded step inputs + run the jitted step OUTSIDE
            # the lock (ingest keeps draining while XLA runs)
            wire = np.zeros((self.n, self.spec.padded_size), np.float32)
            present = np.zeros((self.n,), bool)
            discount = np.ones((self.n,), np.float32)
            for cid, row in rows.items():
                wire[cid] = row.update.values
                present[cid] = True
                discount[cid] = self._beta ** row.staleness
            t0 = time.perf_counter()
            new_params, new_state = self._step(
                self.params_flat, self.server_state, jnp.asarray(wire),
                jnp.asarray(present), jnp.asarray(discount))
            jax.block_until_ready(new_params)
            t1 = time.perf_counter()

            with self._cond:
                self.params_flat = new_params
                self.server_state = new_state
                self._rounds_fired += 1
                cids = tuple(sorted(rows))
                stale = tuple(rows[c].staleness for c in cids)
                self._results[round_id] = RoundResult(
                    round_id=round_id, n_updates=len(rows),
                    fired_by=fired_by, client_ids=cids, staleness=stale,
                    latency_s=t1 - opened_at)
                self.metrics.observe_round(RoundRecord(
                    round_id=round_id, n_updates=len(rows),
                    fired_by=fired_by, staleness=stale,
                    latency_s=t1 - opened_at, step_s=t1 - t0,
                    payload_bytes=self._per_update_bytes * len(rows),
                    quorum=fired_quorum))
                if (self.serve.checkpoint_every
                        and self._rounds_fired
                        % self.serve.checkpoint_every == 0):
                    self.save_checkpoint()
                self._open_round(time.perf_counter(), reopen_buffer=False)
                self._cond.notify_all()


def run_service(server: ByzantineRobustServer, pool, rounds: int, *,
                round_timeout: float = 60.0,
                stop: bool = True) -> List[RoundResult]:
    """Drive ``rounds`` announce -> submit -> apply cycles with a simulated
    client pool (``repro.serve.client.ClientPool``).

    The pool may tag updates for late delivery (stragglers); those are held
    host-side and submitted at the start of their delivery round, where the
    buffer's staleness policy takes over. With ``stop=False`` the server
    keeps running (e.g. to continue with a different pool behaviour against
    the same compiled step).
    """
    server.start()
    t_start = time.perf_counter()
    pending: List[Tuple[int, protocol.ClientUpdate]] = []
    results: List[RoundResult] = []
    try:
        for _ in range(rounds):
            ann = server.announce(timeout=round_timeout)
            t = ann.round_id
            due = [u for dr, u in pending if dr <= t]
            pending = [(dr, u) for dr, u in pending if dr > t]
            for u in due:
                server.submit(u)
            for sched in pool.round_payloads(ann):
                if sched.drop:
                    continue
                if sched.deliver_round <= t:
                    server.submit(sched.update)
                else:
                    pending.append((sched.deliver_round, sched.update))
            results.append(server.wait_round(t, timeout=round_timeout))
    finally:
        server.metrics.span(t_start, time.perf_counter())
        if stop:
            server.stop()
    return results
