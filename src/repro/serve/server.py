"""The continuously-batching byzantine-robust parameter server.

Architecture (the offline-inference queue/thread/batcher idiom around one
jitted engine step):

* ``submit()`` enqueues :class:`~repro.serve.protocol.ClientUpdate`s onto a
  ``queue.Queue`` from any thread;
* the **ingest thread** drains the queue into the
  :class:`~repro.serve.buffer.RoundBuffer` (quorum / timeout / staleness
  classification) and wakes the batcher;
* the **batcher thread** watches the buffer and, on quorum-or-timeout,
  fires ONE jitted aggregate-and-apply step — the same ``make_aggregator``
  rule (Pallas kernels included via ``AggregatorConfig.use_pallas``) and
  rosdhb/robust_dgd/dgd apply halves the simulator runs
  (``algorithms.make_serve_apply_fn``) against the ``StateLayout``-pruned
  ``ServerState``. Absent clients are padded: participation enters the step
  as a traced ``present`` row mask and staleness as a traced ``discount``
  weight over a static ``[n, D]`` wire bank, so the step **never retraces
  across participation levels** (``step_traces`` counts XLA programs; the
  bench gates it at exactly 1).

The PRNG chain replicates the simulator's exactly — per round the carried
key splits into ``(carry, round_key)`` and the round key into
``(mask_key, atk_key)``, both broadcast in the round announcement — so with
full participation and zero timeout the served parameter trajectory is
bit-for-bit ``Simulator.rollout``'s (tests/test_serve.py).

``repro.checkpoint`` is wired in: with ``checkpoint_every > 0`` the server
periodically persists ``{params, ServerState, key}`` and a fresh server can
``restore()`` and continue with identical results under full participation.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as G
from repro.core import algorithms as alg
from repro.serve import protocol
from repro.serve.buffer import RoundBuffer
from repro.serve.metrics import RoundRecord, ServeMetrics
from repro.utils import tree as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (the algorithm itself lives in
    ``AlgorithmConfig``).

    Attributes:
      quorum: distinct clients required to fire a round; ``None`` = all
        ``n_workers``. Must be at least ``2f + 1`` (validated loudly).
      timeout_s: wall-clock round deadline; after it, a round fires with
        whatever partial participation arrived (at least one update).
        ``0`` disables the clock — rounds fire on quorum only.
      staleness_window: accept updates up to this many rounds late.
      stale_policy: ``discount`` (late updates weighted ``beta^k``) or
        ``drop``.
      checkpoint_every: persist server state every k fired rounds
        (0 = never).
      checkpoint_dir: where checkpoints go (required if checkpointing).
    """

    quorum: Optional[int] = None
    timeout_s: float = 0.0
    staleness_window: int = 0
    stale_policy: str = "discount"
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class RoundResult:
    """What the batcher reports back for one fired round."""

    round_id: int
    n_updates: int
    fired_by: str
    client_ids: Tuple[int, ...]
    staleness: Tuple[int, ...]
    latency_s: float


class ByzantineRobustServer:
    """Streaming parameter server for one serveable algorithm config."""

    def __init__(self, cfg: alg.AlgorithmConfig, params0,
                 serve: Optional[ServeConfig] = None, *, seed: int = 0):
        # same loud rejection make_wire_fn/make_serve_apply_fn give
        alg._check_serveable(cfg.name)
        self.cfg = cfg
        self.serve = serve or ServeConfig()
        self.spec = T.make_flat_spec(params0)
        self.d = self.spec.size
        self.n = cfg.n_workers
        # host-side staleness discount rate: the momentum coefficient (a
        # geometric decay also applied to the bankless DGD rules)
        self._beta = np.float32(cfg.resolved_beta())
        self.params_flat = T.tree_ravel(params0, self.spec)
        # the serveable algorithms all run the pruned StateLayout (no
        # mirror/prev_grad leaves); the adversary's memory lives client-side
        # (the pool simulates the attack), so the server carries none
        self.server_state = alg.init_state(cfg, self.spec.padded_size
                                           )._replace(attack=None)
        self._key = jax.random.PRNGKey(seed)
        self.agg_backend = G.kernel_backend_label(cfg.aggregator.use_pallas)
        self._per_update_bytes = protocol.update_payload_bytes(cfg, self.d)

        # ONE jitted aggregate-and-apply step; participation (present) and
        # staleness (discount) are traced DATA over static [n, D] shapes,
        # so every participation level shares one compiled program.
        apply_fn = alg.make_serve_apply_fn(cfg, G.make_aggregator(
            cfg.aggregator))
        self.step_traces = 0

        def _step(params_flat, state, wire, present, discount):
            self.step_traces += 1  # trace-time (python) side effect only
            r, new_state = apply_fn(state, wire, present, discount)
            return alg.apply_direction(params_flat, r, cfg.gamma), new_state

        self._step = jax.jit(_step)

        self.metrics = ServeMetrics()
        self._buffer = RoundBuffer(
            n_clients=self.n, f=cfg.f, quorum=self.serve.quorum,
            timeout_s=self.serve.timeout_s,
            staleness_window=self.serve.staleness_window,
            stale_policy=self.serve.stale_policy)
        if self.serve.checkpoint_every and not self.serve.checkpoint_dir:
            raise ValueError("checkpoint_every > 0 needs a checkpoint_dir")

        self._queue: "queue.Queue[protocol.ClientUpdate]" = queue.Queue()
        self._cond = threading.Condition()
        self._results: Dict[int, RoundResult] = {}
        self._rounds_fired = 0
        self._round_id = 0
        self._ann: Optional[protocol.RoundAnnouncement] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._open_round(time.perf_counter())

    # -- round lifecycle (callers hold self._cond unless noted) ------------

    def _open_round(self, now: float, reopen_buffer: bool = True) -> None:
        """Open ``self._round_id``: advance the key chain exactly like the
        simulator (carry split, then mask/attack split) and broadcast the
        announcement. The batcher passes ``reopen_buffer=False`` — it
        already advanced the buffer at drain time, and re-opening here
        would wipe updates ingested while the apply ran."""
        self._key, round_key = jax.random.split(self._key)
        mask_key, atk_key = jax.random.split(round_key)
        self._ann = protocol.RoundAnnouncement(
            round_id=self._round_id,
            params=np.asarray(self.params_flat),
            mask_key=np.asarray(mask_key), atk_key=np.asarray(atk_key))
        if reopen_buffer:
            self._buffer.open(self._round_id, now,
                              mask_id=self._ann.mask_id)
        else:
            self._buffer.register_mask(self._round_id, self._ann.mask_id)

    # -- public API --------------------------------------------------------

    def start(self) -> "ByzantineRobustServer":
        if self._threads:
            return self
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._ingest_loop, name="serve-ingest",
                             daemon=True),
            threading.Thread(target=self._batcher_loop, name="serve-batcher",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)
        self._threads = []

    def submit(self, update: protocol.ClientUpdate) -> None:
        """Enqueue one client update (thread-safe, non-blocking)."""
        values = np.asarray(update.values)
        if values.shape != (self.spec.padded_size,):
            raise ValueError(
                f"update values shape {values.shape} != "
                f"[padded_D={self.spec.padded_size}]")
        self._queue.put(update)

    def announce(self, timeout: float = 60.0) -> protocol.RoundAnnouncement:
        """The current round's broadcast (blocks through an in-flight
        apply until the next round is open)."""
        deadline = time.perf_counter() + timeout
        with self._cond:
            while (self._ann is None
                   or self._ann.round_id != self._round_id):
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._cond.wait(timeout=rem):
                    raise TimeoutError("no open round announcement")
            return self._ann

    def wait_round(self, round_id: int, timeout: float = 60.0) -> RoundResult:
        """Block until ``round_id`` has fired and been applied."""
        deadline = time.perf_counter() + timeout
        with self._cond:
            while round_id not in self._results:
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._cond.wait(timeout=rem):
                    raise TimeoutError(
                        f"round {round_id} did not fire within {timeout}s "
                        f"(buffer has {self._buffer.count}/"
                        f"{self._buffer.quorum} updates; with timeout_s=0 a "
                        "round below quorum never fires)")
            return self._results[round_id]

    @property
    def round_id(self) -> int:
        with self._cond:
            return self._round_id

    # -- checkpointing -----------------------------------------------------

    def _checkpoint_tree(self):
        return {"params_flat": self.params_flat,
                "momentum": self.server_state.momentum,
                "step": self.server_state.step,
                "key": self._key}

    def save_checkpoint(self, path: Optional[str] = None) -> str:
        """Persist ``{params, ServerState, key}`` + round metadata via
        ``repro.checkpoint`` (callable any time the server is paused; the
        batcher calls it between rounds when ``checkpoint_every`` is set)."""
        from repro.checkpoint import save
        if path is None:
            path = os.path.join(self.serve.checkpoint_dir or ".",
                                f"serve_round{self._round_id:06d}")
        return save(path, self._checkpoint_tree(),
                    metadata={"algo": self.cfg.name, "d": self.d,
                              "n_workers": self.n},
                    step=self._round_id)

    def restore(self, path: str) -> int:
        """Load a checkpoint into this (not-yet-started) server and reopen
        its round. Returns the restored round id."""
        from repro.checkpoint import latest_step, restore
        if self._threads:
            raise RuntimeError("restore() before start()")
        tree = restore(path, self._checkpoint_tree())
        self.params_flat = jnp.asarray(tree["params_flat"])
        self.server_state = self.server_state._replace(
            momentum=jnp.asarray(tree["momentum"]),
            step=jnp.asarray(tree["step"]))
        self._key = jnp.asarray(tree["key"])
        step = latest_step(path)
        self._round_id = int(step) if step is not None else 0
        self._results = {}
        self._open_round(time.perf_counter())
        return self._round_id

    # -- service loops -----------------------------------------------------

    def _ingest_loop(self) -> None:
        while not self._stop.is_set():
            try:
                u = self._queue.get(timeout=0.02)
            except queue.Empty:
                continue
            with self._cond:
                status = self._buffer.add(u, time.perf_counter())
                self.metrics.observe_decision(status)
                self._cond.notify_all()

    def _batcher_loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                now = time.perf_counter()
                if not self._buffer.ready(now):
                    if self._buffer.timeout_s > 0:
                        wait = max(1e-3, min(
                            0.02, self._buffer.opened_at
                            + self._buffer.timeout_s - now))
                    else:
                        wait = 0.05
                    self._cond.wait(timeout=wait)
                    continue
                fired_by = self._buffer.fired_by()
                rows = self._buffer.drain()
                opened_at = self._buffer.opened_at
                round_id = self._round_id
                # advance the round *now* so updates arriving during the
                # apply are classified against the next round (stale for
                # this one); the next announcement follows after the apply
                self._round_id = round_id + 1
                for _, status in self._buffer.open(self._round_id, now):
                    self.metrics.observe_decision(status)

            # build the padded step inputs + run the jitted step OUTSIDE
            # the lock (ingest keeps draining while XLA runs)
            wire = np.zeros((self.n, self.spec.padded_size), np.float32)
            present = np.zeros((self.n,), bool)
            discount = np.ones((self.n,), np.float32)
            for cid, row in rows.items():
                wire[cid] = row.update.values
                present[cid] = True
                discount[cid] = self._beta ** row.staleness
            t0 = time.perf_counter()
            new_params, new_state = self._step(
                self.params_flat, self.server_state, jnp.asarray(wire),
                jnp.asarray(present), jnp.asarray(discount))
            jax.block_until_ready(new_params)
            t1 = time.perf_counter()

            with self._cond:
                self.params_flat = new_params
                self.server_state = new_state
                self._rounds_fired += 1
                cids = tuple(sorted(rows))
                stale = tuple(rows[c].staleness for c in cids)
                self._results[round_id] = RoundResult(
                    round_id=round_id, n_updates=len(rows),
                    fired_by=fired_by, client_ids=cids, staleness=stale,
                    latency_s=t1 - opened_at)
                self.metrics.observe_round(RoundRecord(
                    round_id=round_id, n_updates=len(rows),
                    fired_by=fired_by, staleness=stale,
                    latency_s=t1 - opened_at, step_s=t1 - t0,
                    payload_bytes=self._per_update_bytes * len(rows)))
                if (self.serve.checkpoint_every
                        and self._rounds_fired
                        % self.serve.checkpoint_every == 0):
                    self.save_checkpoint()
                self._open_round(time.perf_counter(), reopen_buffer=False)
                self._cond.notify_all()


def run_service(server: ByzantineRobustServer, pool, rounds: int, *,
                round_timeout: float = 60.0,
                stop: bool = True) -> List[RoundResult]:
    """Drive ``rounds`` announce -> submit -> apply cycles with a simulated
    client pool (``repro.serve.client.ClientPool``).

    The pool may tag updates for late delivery (stragglers); those are held
    host-side and submitted at the start of their delivery round, where the
    buffer's staleness policy takes over. With ``stop=False`` the server
    keeps running (e.g. to continue with a different pool behaviour against
    the same compiled step).
    """
    server.start()
    t_start = time.perf_counter()
    pending: List[Tuple[int, protocol.ClientUpdate]] = []
    results: List[RoundResult] = []
    try:
        for _ in range(rounds):
            ann = server.announce(timeout=round_timeout)
            t = ann.round_id
            due = [u for dr, u in pending if dr <= t]
            pending = [(dr, u) for dr, u in pending if dr > t]
            for u in due:
                server.submit(u)
            for sched in pool.round_payloads(ann):
                if sched.drop:
                    continue
                if sched.deliver_round <= t:
                    server.submit(sched.update)
                else:
                    pending.append((sched.deliver_round, sched.update))
            results.append(server.wait_round(t, timeout=round_timeout))
    finally:
        server.metrics.span(t_start, time.perf_counter())
        if stop:
            server.stop()
    return results
