"""Deterministic transport fault injection for the serving stack.

A :class:`FaultPlan` is a *seeded, replayable* schedule of transport
faults: every ``(client, round, op, attempt)`` coordinate maps — through
its own ``numpy`` ``SeedSequence`` stream, independent of call order — to
one :class:`FaultDecision` drawing from the :class:`FaultSpec` rates.
Replaying the same plan against the same driver schedule reproduces the
same faults bit-for-bit (``tests/test_transport.py`` gates this), which
is what makes a chaos failure debuggable: re-run the scenario with the
same seed and the same frames drop, duplicate, and corrupt.

The fault taxonomy (all byte-level, applied by :class:`FaultyEndpoint`
around any transport endpoint):

``delay``      sleep ``delay_s`` before delivery (straggling network);
``drop``       the frame never arrives — the caller sees a
               :class:`~repro.serve.transport.TransportTimeout`;
``duplicate``  the frame is delivered twice (retransmission storm) — the
               server's freshest-wins dedup must absorb the second copy;
``reorder``    the frame is held and delivered *after* the client's next
               frame (out-of-order arrival);
``corrupt``    payload bytes are flipped (header left intact so the fault
               stays attributable) — the server must classify the CRC
               failure as a protocol fault, never crash;
``partition``  a scheduled ``(round_start, round_end, clients)`` window in
               which every frame from those clients is lost;
``reset``      the connection resets mid-exchange — drawn fairly between
               reset-before-delivery (frame lost) and reset-after-delivery
               (frame arrived but the ack didn't: the client's retry
               becomes a duplicate the server must dedup).

Faults apply to *requests* (client -> server). Decisions are drawn per
delivery attempt, so a retrying client eventually gets through unless the
plan partitions it outright.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve import protocol
from repro.serve.transport import TransportReset, TransportTimeout

#: Operations a fault decision is keyed on.
OPS = ("announce", "update")
_OP_IDX = {op: i for i, op in enumerate(OPS)}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-attempt fault rates + the deterministic partition schedule.

    Attributes:
      delay/drop/duplicate/reorder/corrupt/reset: per-delivery-attempt
        probabilities in [0, 1] (drawn independently; ``drop`` preempts
        the rest, then ``reset``, then the deliverable faults compose).
      delay_s: sleep applied when ``delay`` fires.
      partitions: ``((round_start, round_end, (client_ids...)), ...)`` —
        client ``c`` is partitioned for round ``t`` iff some window has
        ``round_start <= t < round_end`` and ``c`` in its ids. Scheduled,
        not random: partitions model correlated outages.
    """

    delay: float = 0.0
    drop: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    corrupt: float = 0.0
    reset: float = 0.0
    delay_s: float = 0.005
    partitions: Tuple[Tuple[int, int, Tuple[int, ...]], ...] = ()

    def __post_init__(self):
        for name in ("delay", "drop", "duplicate", "reorder", "corrupt",
                     "reset"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultSpec.{name}={v} outside [0, 1]")
        if self.delay_s < 0:
            raise ValueError(f"FaultSpec.delay_s={self.delay_s} < 0")

    def any_faults(self) -> bool:
        return bool(self.partitions) or any(
            getattr(self, n) > 0 for n in
            ("delay", "drop", "duplicate", "reorder", "corrupt", "reset"))


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    """The drawn fate of one delivery attempt."""

    partitioned: bool = False
    delay_s: float = 0.0
    drop: bool = False
    duplicate: bool = False
    reorder: bool = False
    corrupt: bool = False
    reset_before: bool = False   # reset, frame lost
    reset_after: bool = False    # reset, frame delivered but ack lost

    @property
    def clean(self) -> bool:
        return self == FaultDecision()


class FaultPlan:
    """Seeded deterministic fault schedule over (client, round, op,
    attempt) coordinates."""

    def __init__(self, spec: FaultSpec, seed: int = 0):
        self.spec = spec
        self.seed = int(seed)

    def _rng(self, client_id: int, round_id: int, op: str,
             attempt: int) -> np.random.Generator:
        # each coordinate gets its own independent stream — decisions do
        # not depend on the order the driver asks for them
        return np.random.default_rng(
            (self.seed, int(client_id), max(int(round_id), 0),
             _OP_IDX[op], int(attempt)))

    def partitioned(self, client_id: int, round_id: int) -> bool:
        return any(start <= round_id < end and client_id in cids
                   for start, end, cids in self.spec.partitions)

    def decide(self, client_id: int, round_id: int, op: str,
               attempt: int = 0) -> FaultDecision:
        """Draw one attempt's fate (pure: same coordinate -> same fate)."""
        s = self.spec
        if self.partitioned(client_id, round_id):
            return FaultDecision(partitioned=True)
        rng = self._rng(client_id, round_id, op, attempt)
        # fixed draw order => replayable bit-for-bit
        u = rng.random(7)
        if u[0] < s.drop:
            return FaultDecision(drop=True)
        reset_before = reset_after = False
        if u[1] < s.reset:
            reset_before = u[2] < 0.5
            reset_after = not reset_before
        if reset_before:
            return FaultDecision(reset_before=True)
        return FaultDecision(
            delay_s=s.delay_s if u[3] < s.delay else 0.0,
            duplicate=u[4] < s.duplicate,
            reorder=u[5] < s.reorder,
            corrupt=u[6] < s.corrupt,
            reset_after=reset_after)

    def corrupt_bytes(self, raw: bytes, client_id: int, round_id: int,
                      op: str, attempt: int = 0) -> bytes:
        """Flip deterministic payload bytes (header left intact, so the
        CRC fails but the fault stays attributable to the sender)."""
        body = len(raw) - protocol.HEADER_SIZE
        if body <= 0:
            return raw
        rng = self._rng(client_id, round_id, op, attempt)
        rng.random(7)                       # skip the decision draws
        n_flips = int(rng.integers(1, min(8, body) + 1))
        offsets = rng.integers(0, body, size=n_flips)
        buf = bytearray(raw)
        for off in offsets:
            buf[protocol.HEADER_SIZE + int(off)] ^= 0xFF
        return bytes(buf)


class FaultyEndpoint:
    """Wraps any transport endpoint with a :class:`FaultPlan`.

    ``request(raw, round_id=..., op=..., attempt=...)`` consults the plan
    for that coordinate and applies the drawn faults at the byte level.
    Reordered frames are held and delivered after the *next* frame from
    this endpoint (``flush()`` delivers a still-held frame at a round
    boundary); their caller gets a synthetic ``ACK("queued")`` — exactly
    what the real path returns for a queued update, since ingestion is
    asynchronous either way.
    """

    def __init__(self, inner, client_id: int, plan: FaultPlan,
                 sleep: Callable[[float], None] = time.sleep):
        self.inner = inner
        self.client_id = client_id
        self.plan = plan
        self._sleep = sleep
        self._held: Optional[bytes] = None
        #: injected-fault counters, keyed by fault kind (observability)
        self.injected: Dict[str, int] = {}

    def _count(self, kind: str) -> None:
        self.injected[kind] = self.injected.get(kind, 0) + 1

    def _deliver_held(self) -> None:
        if self._held is not None:
            held, self._held = self._held, None
            try:
                self.inner.request(held)
            except (TransportTimeout, TransportReset):
                pass                        # held frame lost: chaos is chaos

    def flush(self) -> None:
        """Deliver a still-held (reordered) frame — call at round end."""
        self._deliver_held()

    def request(self, raw: bytes, *, round_id: int = 0, op: str = "update",
                attempt: int = 0, **ctx) -> bytes:
        d = self.plan.decide(self.client_id, round_id, op, attempt)
        if d.partitioned:
            self._count("partitioned")
            raise TransportTimeout(
                f"client {self.client_id} partitioned at round {round_id}")
        if d.drop:
            self._count("drop")
            raise TransportTimeout(
                f"frame dropped (client {self.client_id}, round {round_id},"
                f" {op}, attempt {attempt})")
        if d.reset_before:
            self._count("reset")
            raise TransportReset(
                f"connection reset before delivery (client "
                f"{self.client_id}, round {round_id})")
        if d.delay_s > 0:
            self._count("delay")
            self._sleep(d.delay_s)
        if d.corrupt:
            self._count("corrupt")
            raw = self.plan.corrupt_bytes(raw, self.client_id, round_id,
                                          op, attempt)
        if d.reorder and op == "update":
            # hold this frame; it goes out after the NEXT one
            self._count("reorder")
            self._deliver_held()
            self._held = raw
            return protocol.encode_ack(round_id, "queued")
        resp = self.inner.request(raw)
        if d.duplicate:
            self._count("duplicate")
            try:
                self.inner.request(raw)
            except (TransportTimeout, TransportReset):
                pass
        self._deliver_held()
        if d.reset_after:
            self._count("reset")
            raise TransportReset(
                f"connection reset after delivery (client "
                f"{self.client_id}, round {round_id}) — the retry is a "
                "duplicate the server must dedup")
        return resp

    def close(self) -> None:
        self.flush()
        self.inner.close()


def faulty_endpoints(transport, n_clients: int, plan: FaultPlan
                     ) -> List[FaultyEndpoint]:
    """Connect ``n_clients`` endpoints through one shared plan."""
    return [FaultyEndpoint(transport.connect(cid), cid, plan)
            for cid in range(n_clients)]
