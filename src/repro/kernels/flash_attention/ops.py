"""Jitted wrapper for flash attention with backend selection."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "use_pallas", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, use_pallas: bool | None = None,
              interpret: bool = False):
    """Causal (optionally sliding-window) GQA attention.

    use_pallas=None -> Pallas kernel on TPU, XLA reference elsewhere.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, interpret=interpret)
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)
