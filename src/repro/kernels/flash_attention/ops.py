"""Jitted wrapper for flash attention with backend selection."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "use_pallas", "interpret"))
def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, use_pallas: bool | None = None,
              interpret: bool = False):
    """Causal (optionally sliding-window) GQA attention.

    use_pallas=None -> Pallas kernel on TPU, XLA reference elsewhere.

    Head dims that are not lane-aligned (``D % 128 != 0`` — e.g. the
    ``reduced()`` configs' D=64) are zero-padded to the next multiple of
    128 for the kernel: padded K coordinates contribute 0 to every logit
    and padded V coordinates produce 0 outputs (sliced back off), and q is
    pre-scaled by ``sqrt(D_pad / D)`` to cancel the kernel's
    ``1/sqrt(D_pad)`` softmax scale against the true ``1/sqrt(D)``.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    d = q.shape[-1]
    pad = (-d) % 128
    if pad:
        comp = jnp.asarray(math.sqrt((d + pad) / d), q.dtype)
        pad_last = lambda x: jnp.pad(  # noqa: E731
            x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
        q = pad_last(q * comp)
        k = pad_last(k)
        v = pad_last(v)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, interpret=interpret)
    return out[..., :d] if pad else out
