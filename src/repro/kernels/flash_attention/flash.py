"""Pallas TPU kernel: blocked causal flash attention (GQA + sliding window).

Grid ``(B, H, NQ, NK)`` with the K dimension innermost and "arbitrary"
(sequential) so the online-softmax accumulators live in VMEM scratch across
K steps. Per grid step the kernel sees:

    q   [block_q, d]   (VMEM, selected by the (b, h, iq) index map)
    k,v [block_k, d]   (VMEM, GQA: kv head = h // (H / KV))

and maintains f32 scratch ``acc [block_q, d]``, ``m/l [block_q, 128]``
(stat lanes). Causal/sliding-window masking is positional, computed from the
grid ids — no mask tensors are materialised. The matmuls hit the MXU at
(block_q x d) x (d x block_k) with d a multiple of 128 (callers pad).

``block_q/block_k`` default to 512: VMEM per step =
(512 + 2*512) * d * 2B + 512*d*4B ≈ 0.6 MiB at d=128 — well inside the
~16 MiB VMEM budget while large enough to amortise the DMA pipeline.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, n_k: int,
                  causal: bool, window: Optional[int], q_offset: int,
                  kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)           # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                          # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])               # [bq, bk]
    l_cur = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)

    v = v_ref[0, 0].astype(jnp.float32)           # [bk, d]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False
                    ) -> jnp.ndarray:
    """q: [B, Sq, H, D]; k/v: [B, Sk, KV, D] -> [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0
    rep = h // kv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qt = jnp.moveaxis(q, 2, 1)  # [B, H, Sq, D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_q, n_k = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window, q_offset=q_offset,
        kv_len=sk)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)[:, :sq]
    return out
