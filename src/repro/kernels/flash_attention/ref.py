"""Pure-jnp oracle for the flash-attention kernel: dense masked softmax
attention with GQA and optional sliding window."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: [B, Sq, H, D]; k/v: [B, Sk, KV, D] -> [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(d)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
