"""Pure-jnp oracle for the Block-RandK compress/decompress kernels."""

from __future__ import annotations

import jax.numpy as jnp


def block_compress_ref(g: jnp.ndarray, block_idx: jnp.ndarray,
                       block_size: int, alpha: float) -> jnp.ndarray:
    """Gather the selected blocks of ``g`` scaled by alpha.

    g: [d] with d % block_size == 0; block_idx: [kb] int32 block ids.
    Returns [kb * block_size] — the wire payload.
    """
    gb = g.reshape(-1, block_size)
    return (alpha * gb[block_idx]).reshape(-1).astype(g.dtype)


def block_decompress_ref(payload: jnp.ndarray, block_idx: jnp.ndarray,
                         block_size: int, d: int) -> jnp.ndarray:
    """Scatter the payload back to a dense [d] vector (zeros elsewhere)."""
    nb = d // block_size
    out = jnp.zeros((nb, block_size), payload.dtype)
    out = out.at[block_idx].set(payload.reshape(-1, block_size))
    return out.reshape(d)


def momentum_scatter_ref(bank_row: jnp.ndarray, payload: jnp.ndarray,
                         block_idx: jnp.ndarray, block_size: int,
                         beta: float) -> jnp.ndarray:
    """Fused RoSDHB momentum update (Algorithm 1, step 5):
       m <- beta * m              (all blocks)
       m[sel] += (1 - beta) * payload   (selected blocks)
    """
    nb = bank_row.shape[0] // block_size
    m = (beta * bank_row.astype(jnp.float32)).reshape(nb, block_size)
    upd = (1.0 - beta) * payload.astype(jnp.float32).reshape(-1, block_size)
    m = m.at[block_idx].add(upd)
    return m.reshape(-1).astype(bank_row.dtype)
