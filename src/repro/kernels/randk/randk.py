"""Pallas TPU kernels for Block-RandK compression (DESIGN §3).

Three kernels around the wire format ``payload = alpha * g[selected blocks]``:

  * ``block_compress``   — gather + scale: one grid step per selected block;
    the block id is prefetched (scalar prefetch) and drives the input
    BlockSpec index_map, so the gather is a pure DMA pattern — no VMEM
    shuffle, each selected block streams HBM->VMEM->HBM once.
  * ``block_decompress`` — inverse scatter into a zeroed dense vector.
  * ``momentum_scatter`` — the fused RoSDHB step-5 update: decay the whole
    momentum row by beta while adding (1-beta)*payload into the selected
    blocks; one pass over the bank row, which is the server's dominant
    HBM-bandwidth term (see EXPERIMENTS §Perf).

Block size is a multiple of the 128-lane register width; payloads are
2-D ``[kb, block_size]`` so every DMA is lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# compress: payload[j] = alpha * g_blocks[idx[j]]
# --------------------------------------------------------------------------


def _compress_kernel(idx_ref, g_ref, o_ref, *, alpha: float):
    # g_ref is the block selected by the index_map (scalar prefetch)
    o_ref[...] = (g_ref[...].astype(jnp.float32) * alpha).astype(o_ref.dtype)


def block_compress(g: jnp.ndarray, block_idx: jnp.ndarray, block_size: int,
                   alpha: float, *, interpret: bool = False) -> jnp.ndarray:
    """g: [d] (d % block_size == 0); block_idx: [kb] -> payload [kb*bs]."""
    d = g.shape[0]
    nb = d // block_size
    kb = block_idx.shape[0]
    gb = g.reshape(nb, block_size)
    grid = (kb,)
    out = pl.pallas_call(
        functools.partial(_compress_kernel, alpha=alpha),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, block_size),
                                   lambda j, idx: (idx[j], 0))],
            out_specs=pl.BlockSpec((1, block_size), lambda j, idx: (j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((kb, block_size), g.dtype),
        interpret=interpret,
    )(block_idx, gb)
    return out.reshape(kb * block_size)


# --------------------------------------------------------------------------
# decompress: dense[idx[j]] = payload[j]; zeros elsewhere
# --------------------------------------------------------------------------


def _decompress_kernel(sel_ref, p_ref, o_ref):
    # grid over ALL destination blocks i; sel_ref[i] holds the payload slot
    # for block i (or -1 if unselected).
    slot = sel_ref[pl.program_id(0)]

    @pl.when(slot >= 0)
    def _write():
        o_ref[...] = p_ref[...]

    @pl.when(slot < 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)


def block_decompress(payload: jnp.ndarray, block_idx: jnp.ndarray,
                     block_size: int, d: int, *,
                     interpret: bool = False) -> jnp.ndarray:
    """payload [kb*bs] + block ids -> dense [d]."""
    nb = d // block_size
    kb = block_idx.shape[0]
    pb = payload.reshape(kb, block_size)
    # slot map: destination block -> payload row (-1 = not selected)
    slot = jnp.full((nb,), -1, jnp.int32)
    slot = slot.at[block_idx].set(jnp.arange(kb, dtype=jnp.int32))
    out = pl.pallas_call(
        _decompress_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=[pl.BlockSpec((1, block_size),
                                   lambda i, sel: (jnp.maximum(sel[i], 0), 0))],
            out_specs=pl.BlockSpec((1, block_size), lambda i, sel: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), payload.dtype),
        interpret=interpret,
    )(slot, pb)
    return out.reshape(d)


# --------------------------------------------------------------------------
# fused momentum update: m = beta*m; m[sel] += (1-beta)*payload
# --------------------------------------------------------------------------


def _momentum_kernel(sel_ref, m_ref, p_ref, o_ref, *, beta: float):
    i = pl.program_id(0)
    slot = sel_ref[i]
    m = m_ref[...].astype(jnp.float32) * beta

    @pl.when(slot >= 0)
    def _upd():
        o_ref[...] = (m + (1.0 - beta) * p_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)

    @pl.when(slot < 0)
    def _decay():
        o_ref[...] = m.astype(o_ref.dtype)


def momentum_scatter(bank_row: jnp.ndarray, payload: jnp.ndarray,
                     block_idx: jnp.ndarray, block_size: int, beta: float,
                     *, interpret: bool = False) -> jnp.ndarray:
    """Fused Algorithm-1 step 5 over one worker's momentum row [d]."""
    d = bank_row.shape[0]
    nb = d // block_size
    kb = block_idx.shape[0]
    slot = jnp.full((nb,), -1, jnp.int32)
    slot = slot.at[block_idx].set(jnp.arange(kb, dtype=jnp.int32))
    out = pl.pallas_call(
        functools.partial(_momentum_kernel, beta=beta),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=[
                pl.BlockSpec((1, block_size), lambda i, sel: (i, 0)),
                pl.BlockSpec((1, block_size),
                             lambda i, sel: (jnp.maximum(sel[i], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, block_size), lambda i, sel: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nb, block_size), bank_row.dtype),
        interpret=interpret,
    )(slot, bank_row.reshape(nb, block_size), payload.reshape(kb, block_size))
    return out.reshape(d)
