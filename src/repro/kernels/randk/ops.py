"""Jitted wrappers for the Block-RandK kernels with backend selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.randk import randk as K
from repro.kernels.randk import ref as R


def _pallas(use_pallas):
    return jax.default_backend() == "tpu" if use_pallas is None else use_pallas


@functools.partial(jax.jit,
                   static_argnames=("block_size", "alpha", "use_pallas",
                                    "interpret"))
def compress(g, block_idx, *, block_size: int, alpha: float,
             use_pallas=None, interpret: bool = False):
    if _pallas(use_pallas):
        return K.block_compress(g, block_idx, block_size, alpha,
                                interpret=interpret)
    return R.block_compress_ref(g, block_idx, block_size, alpha)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "d", "use_pallas",
                                    "interpret"))
def decompress(payload, block_idx, *, block_size: int, d: int,
               use_pallas=None, interpret: bool = False):
    if _pallas(use_pallas):
        return K.block_decompress(payload, block_idx, block_size, d,
                                  interpret=interpret)
    return R.block_decompress_ref(payload, block_idx, block_size, d)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "beta", "use_pallas",
                                    "interpret"))
def momentum_update(bank_row, payload, block_idx, *, block_size: int,
                    beta: float, use_pallas=None, interpret: bool = False):
    if _pallas(use_pallas):
        return K.momentum_scatter(bank_row, payload, block_idx, block_size,
                                  beta, interpret=interpret)
    return R.momentum_scatter_ref(bank_row, payload, block_idx, block_size,
                                  beta)
