from repro.kernels.randk.ops import compress, decompress, momentum_update
from repro.kernels.randk.randk import (
    block_compress,
    block_decompress,
    momentum_scatter,
)
from repro.kernels.randk.ref import (
    block_compress_ref,
    block_decompress_ref,
    momentum_scatter_ref,
)

__all__ = [
    "compress", "decompress", "momentum_update",
    "block_compress", "block_decompress", "momentum_scatter",
    "block_compress_ref", "block_decompress_ref", "momentum_scatter_ref",
]
