"""Pallas TPU kernels for the perf-critical compute of the virtual server
(the robust-aggregation families cwtm / median / pairdist plus the randk
compressor) and the attention hot loop (flash_attention).

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with TPU/XLA backend selection) and ref.py (pure-jnp oracle used by
the interpret-mode test sweeps). The aggregation kernels additionally ship
explicitly *batched* entry points over the grid engine's fused
``[n_cells * n_seeds]`` leading axis; :func:`batchable` routes ``jax.vmap``
of the per-lane rule onto them.
"""

from __future__ import annotations

from typing import Callable

from jax.custom_batching import custom_vmap


def batchable(fn2d: Callable, fn3d: Callable) -> Callable:
    """Route ``jax.vmap`` of a per-lane ``[n, d]`` rule onto an explicitly
    batched ``[B, n, d]`` kernel.

    The grid engine runs aggregation per vmap lane of the fused
    ``[n_cells * n_seeds]`` axis; without this wrapper, ``vmap`` of a
    ``pallas_call`` falls back to Pallas's generic batching rule. With it,
    the engine's vmap lands on the hand-laid batched grid (one
    (B, d/block_d) launch, batch as the leading grid dimension). An
    unbatched call — or a vmap that does not map the stacked argument —
    just runs ``fn2d``.
    """
    op = custom_vmap(fn2d)

    @op.def_vmap
    def _batch_rule(axis_size, in_batched, x):  # noqa: ANN001
        if not in_batched[0]:
            return fn2d(x), False
        return fn3d(x), True

    return op
