"""Pallas TPU kernels for the perf-critical compute of the virtual server
(cwtm, randk) and the attention hot loop (flash_attention).

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with TPU/XLA backend selection) and ref.py (pure-jnp oracle used by
the interpret-mode test sweeps).
"""
