"""Pure-jnp oracle for the coordinate-wise median kernel."""

from __future__ import annotations

import jax.numpy as jnp


def median_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., n, d] -> [..., d]: per-coordinate median over the worker
    axis (axis -2), midpoint-averaged for even n — the same rule as
    ``repro.core.aggregators.coordinate_median``."""
    return jnp.median(x, axis=-2)
