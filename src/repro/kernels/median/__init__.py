from repro.kernels.median.median import (median_pallas, median_pallas_batched,
                                         median_weights)
from repro.kernels.median.ops import median
from repro.kernels.median.ref import median_ref

__all__ = ["median_pallas", "median_pallas_batched", "median_weights",
           "median", "median_ref"]
