"""Pallas TPU kernel: coordinate-wise median over the worker axis.

The sibling of the CWTM kernel (``repro.kernels.cwtm``): the median is a
rank-select inside the SAME bitonic sort network — only the static rank
weights change (the middle sorted row for odd n, the mean of the two middle
rows for even n), so this module reuses the CWTM tile plumbing
(``sorted_weighted_batched``: grid (B, d/block_d), one memory-bound
[n_pad, block_d] VMEM read per step) verbatim.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.cwtm.cwtm import sorted_weighted_batched


def median_weights(n: int) -> Tuple[float, ...]:
    """Rank weights of the coordinate-wise median: 1 at the middle sorted
    row (n odd), 1/2 at each of the two middle rows (n even) — matching
    ``jnp.median``'s midpoint convention."""
    assert n >= 1, n
    w = [0.0] * n
    if n % 2:
        w[n // 2] = 1.0
    else:
        w[n // 2 - 1] = 0.5
        w[n // 2] = 0.5
    return tuple(w)


def median_pallas_batched(x: jnp.ndarray, *, block_d: int = 2048,
                          interpret: bool = False) -> jnp.ndarray:
    """Batched coordinate-wise median: x [B, n, d] -> [B, d] — the grid
    engine's real shape (B = n_cells * n_seeds fusion lanes)."""
    return sorted_weighted_batched(x, median_weights(x.shape[1]),
                                   block_d=block_d, interpret=interpret)


def median_pallas(x: jnp.ndarray, *, block_d: int = 2048,
                  interpret: bool = False) -> jnp.ndarray:
    """Coordinate-wise median: x [n, d] -> [d]."""
    return median_pallas_batched(x[None], block_d=block_d,
                                 interpret=interpret)[0]
