"""Jitted wrapper for the median kernel with automatic backend selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.median.median import median_pallas, median_pallas_batched
from repro.kernels.median.ref import median_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret", "block_d"))
def median(x: jnp.ndarray, *, use_pallas: bool | None = None,
           interpret: bool = False, block_d: int = 2048) -> jnp.ndarray:
    """Coordinate-wise median over the worker axis.

    Accepts the per-lane ``[n, d]`` shape and the grid engine's batched
    ``[B, n, d]`` shape; use_pallas=None -> Pallas on TPU, XLA reference
    elsewhere (the pattern of ``repro.kernels.cwtm.ops``).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return median_ref(x)
    if x.ndim == 3:
        return median_pallas_batched(x, block_d=block_d, interpret=interpret)
    return median_pallas(x, block_d=block_d, interpret=interpret)
