"""Pallas TPU kernel: batched pairwise squared distances over the worker axis.

Serves BOTH NNM pre-aggregation (Allouah et al.'s Fixing-by-Mixing — each
worker vector replaced by the mean of its n-f nearest neighbours) and
(Multi-)Krum scoring: both start from the [n, n] squared-distance matrix
``||x_i - x_j||^2``. The pure-XLA rule materialises the Gram matrix from a
full f32 ``x @ x.T`` plus two more passes over ``x`` for the squared norms;
here one (B, d/block_d) grid makes a SINGLE memory-bound read of each
``[n, block_d]`` tile, accumulating the Gram block on the MXU in f32 into
the revisited [n_pad, n_pad] output block, and finalises
``d2 = sq_i + sq_j - 2 G`` (clamped at 0) in-register on the last
d-block — the tiny [n, n] output is the only other HBM traffic.

The worker axis is padded to a sublane multiple (8) with zero rows — zero
padding contributes nothing to inner products, and the pads are sliced off
the output. n <= 64 per the simulator contract, so the whole Gram tile
lives comfortably in VMEM next to the input tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pairdist_kernel(x_ref, o_ref, *, n_blocks: int, n_pad: int):
    """One (b, j) grid step: accumulate the Gram block of x_ref
    [1, n_pad, block_d] into the revisited o_ref [1, n_pad, n_pad]; on the
    last d-block, transform the Gram matrix into clamped squared
    distances in place."""
    j = pl.program_id(1)
    xt = x_ref[0].astype(jnp.float32)  # [n_pad, block_d]
    g = jax.lax.dot_general(xt, xt, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[0] = g

    @pl.when(j > 0)
    def _accumulate():
        o_ref[0] = o_ref[0] + g

    @pl.when(j == n_blocks - 1)
    def _finalise():
        gg = o_ref[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (n_pad, n_pad), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (n_pad, n_pad), 1)
        diag = jnp.where(row == col, gg, 0.0)
        sq_i = jnp.sum(diag, axis=1, keepdims=True)   # [n_pad, 1]
        sq_j = jnp.sum(diag, axis=0, keepdims=True)   # [1, n_pad]
        o_ref[0] = jnp.maximum(sq_i + sq_j - 2.0 * gg, 0.0)


def pairdist_pallas_batched(x: jnp.ndarray, *, block_d: int = 2048,
                            interpret: bool = False) -> jnp.ndarray:
    """Batched pairwise squared distances: x [B, n, d] -> [B, n, n] (f32)."""
    b, n, d = x.shape
    n_pad = max(8, -(-n // 8) * 8)
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n), (0, 0)))
    d_pad = (-d) % block_d
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad)))
    dp = d + d_pad
    n_blocks = dp // block_d

    kernel = functools.partial(pairdist_kernel, n_blocks=n_blocks,
                               n_pad=n_pad)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[pl.BlockSpec((1, n_pad, block_d), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, n_pad, n_pad), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, :n, :n]


def pairdist_pallas(x: jnp.ndarray, *, block_d: int = 2048,
                    interpret: bool = False) -> jnp.ndarray:
    """Pairwise squared distances: x [n, d] -> [n, n] (f32)."""
    return pairdist_pallas_batched(x[None], block_d=block_d,
                                   interpret=interpret)[0]
