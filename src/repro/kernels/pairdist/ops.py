"""Jitted wrapper for the pairwise-distance kernel with backend selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairdist.pairdist import (pairdist_pallas,
                                             pairdist_pallas_batched)
from repro.kernels.pairdist.ref import pairdist_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret", "block_d"))
def pairdist(x: jnp.ndarray, *, use_pallas: bool | None = None,
             interpret: bool = False, block_d: int = 2048) -> jnp.ndarray:
    """Pairwise squared distances over the worker axis (f32).

    Accepts the per-lane ``[n, d]`` shape and the grid engine's batched
    ``[B, n, d]`` shape; serves NNM pre-aggregation and (Multi-)Krum
    scoring in ``repro.core.aggregators``. use_pallas=None -> Pallas on
    TPU, XLA reference elsewhere.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return pairdist_ref(x)
    if x.ndim == 3:
        return pairdist_pallas_batched(x, block_d=block_d,
                                       interpret=interpret)
    return pairdist_pallas(x, block_d=block_d, interpret=interpret)
