"""Pure-jnp oracle for the pairwise squared-distance kernel."""

from __future__ import annotations

import jax.numpy as jnp


def pairdist_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: [..., n, d] -> [..., n, n] clamped squared distances in f32 —
    the ``sq_i + sq_j - 2 x x^T`` rule of
    ``repro.core.aggregators._pairwise_sq_dists``, batched over any
    leading axes."""
    xf = x.astype(jnp.float32)
    sq = jnp.sum(jnp.square(xf), axis=-1)
    g = jnp.einsum("...nd,...md->...nm", xf, xf)
    d2 = sq[..., :, None] + sq[..., None, :] - 2.0 * g
    return jnp.maximum(d2, 0.0)
