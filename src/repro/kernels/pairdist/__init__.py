from repro.kernels.pairdist.ops import pairdist
from repro.kernels.pairdist.pairdist import (pairdist_pallas,
                                             pairdist_pallas_batched)
from repro.kernels.pairdist.ref import pairdist_ref

__all__ = ["pairdist", "pairdist_pallas", "pairdist_pallas_batched",
           "pairdist_ref"]
