from repro.kernels.cwtm.cwtm import cwtm_pallas
from repro.kernels.cwtm.ops import cwtm
from repro.kernels.cwtm.ref import cwtm_ref

__all__ = ["cwtm_pallas", "cwtm", "cwtm_ref"]
