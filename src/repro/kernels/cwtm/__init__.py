from repro.kernels.cwtm.cwtm import (cwtm_pallas, cwtm_pallas_batched,
                                     cwtm_weights, sort_network_compares,
                                     sorted_weighted_batched)
from repro.kernels.cwtm.ops import cwtm
from repro.kernels.cwtm.ref import cwtm_ref

__all__ = ["cwtm_pallas", "cwtm_pallas_batched", "cwtm", "cwtm_ref",
           "cwtm_weights", "sort_network_compares", "sorted_weighted_batched"]
