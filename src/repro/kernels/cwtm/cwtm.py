"""Pallas TPU kernel: coordinate-wise trimmed mean over the worker axis.

This is the robust-aggregation hot loop of the virtual server: every training
round it processes all `D` coordinates of the momentum bank `[n_workers, D]`.

TPU mapping:
  * the coordinate axis is tiled into VMEM blocks of ``block_d`` lanes
    (a multiple of 128); each grid step loads an ``[n, block_d]`` tile;
  * the worker axis (n <= 64) lives across sublanes; we sort it with a
    Batcher bitonic network expressed as jnp.minimum/maximum over
    whole-lane vectors — fully vectorised on the VPU, no data-dependent
    control flow;
  * the middle ``n - 2f`` slice is accumulated in f32 and scaled.

Sorting cost is O(log^2 n) vector min/max passes per tile, so the kernel is
memory-bound by the single [n, block_d] read — exactly the roofline target
for an aggregation pass.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_pairs(n: int):
    """Index pairs of a bitonic sorting network for n inputs (n power of 2)."""
    pairs = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            stage = []
            for i in range(n):
                l = i ^ j
                if l > i:
                    ascending = (i & k) == 0
                    stage.append((i, l, ascending))
            pairs.append(stage)
            j //= 2
        k *= 2
    return pairs


def cwtm_kernel(x_ref, o_ref, *, n: int, n_pad: int, f: int, pad_value: float):
    """One VMEM tile: x_ref [n_pad, block_d] -> o_ref [block_d].

    Rows [n, n_pad) are padding preloaded with +inf so they sort to the top
    and never land in the trimmed window (guaranteed by n_pad - n <= f ...
    callers pad with +inf and enforce f' = f + (n_pad - n) on the high side).
    """
    rows = [x_ref[i, :].astype(jnp.float32) for i in range(n_pad)]
    for stage in _bitonic_pairs(n_pad):
        for i, l, asc in stage:
            lo = jnp.minimum(rows[i], rows[l])
            hi = jnp.maximum(rows[i], rows[l])
            rows[i], rows[l] = (lo, hi) if asc else (hi, lo)
    # after ascending sort: rows[f : n - f] is the trimmed window
    # (padding rows hold +inf and occupy the tail [n, n_pad))
    acc = rows[f]
    for i in range(f + 1, n - f):
        acc = acc + rows[i]
    o_ref[:] = (acc / float(n - 2 * f)).astype(o_ref.dtype)


def cwtm_pallas(x: jnp.ndarray, f: int, *, block_d: int = 2048,
                interpret: bool = False) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: x [n, d] -> [d]."""
    n, d = x.shape
    assert n > 2 * f, (n, f)
    n_pad = 1 << max(1, math.ceil(math.log2(n)))
    if n_pad != n:
        fill = jnp.full((n_pad - n, d), jnp.inf, x.dtype)
        x = jnp.concatenate([x, fill], axis=0)

    d_pad = (-d) % block_d
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
    dp = d + d_pad

    kernel = functools.partial(cwtm_kernel, n=n, n_pad=n_pad, f=f,
                               pad_value=float("inf"))
    out = pl.pallas_call(
        kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((n_pad, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), x.dtype),
        interpret=interpret,
    )(x)
    return out[:d]
