"""Pallas TPU kernel: coordinate-wise trimmed mean over the worker axis.

This is the robust-aggregation hot loop of the virtual server: every training
round it processes all `D` coordinates of the momentum bank `[n_workers, D]`
— and under the fused grid engine (repro.core.sweep) it does so for every
scenario cell at once, so the engine-real shape is ``[B, n, d]`` with
``B = n_cells * n_seeds`` flat fusion lanes.

TPU mapping:
  * the coordinate axis is tiled into VMEM blocks of ``block_d`` lanes
    (a multiple of 128); each grid step loads an ``[n, block_d]`` tile;
  * the batch axis is a leading grid dimension — one ``(b, j)`` grid step
    per (fusion lane, coordinate block), so the whole pass is a single
    memory-bound sweep over the stacked ``[B, n, d]`` read;
  * the worker axis (n <= 64) lives across sublanes; we sort it with a
    Batcher bitonic network expressed as jnp.minimum/maximum over
    whole-lane vectors — fully vectorised on the VPU, no data-dependent
    control flow;
  * the output is a static rank weighting of the sorted rows, accumulated
    in f32: the trimmed window for CWTM, the middle element(s) for the
    coordinate-wise median (see ``repro.kernels.median`` — the sibling
    kernel shares this sort network and tile plumbing, it only swaps the
    weight vector).

Sorting cost is O(log^2 n) vector min/max passes per tile, so the kernel is
memory-bound by the single [n, block_d] read — exactly the roofline target
for an aggregation pass (``repro.launch.roofline.aggregation_roofline``).
"""

from __future__ import annotations

import functools
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_pairs(n: int):
    """Index pairs of a bitonic sorting network for n inputs (n power of 2)."""
    pairs = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            stage = []
            for i in range(n):
                l = i ^ j
                if l > i:
                    ascending = (i & k) == 0
                    stage.append((i, l, ascending))
            pairs.append(stage)
            j //= 2
        k *= 2
    return pairs


def sort_network_compares(n_pad: int) -> int:
    """Total compare-exchange pairs of the bitonic network — the FLOP side
    of the aggregation roofline (2 vector ops — min + max — per pair)."""
    return sum(len(stage) for stage in _bitonic_pairs(n_pad))


def _sort_rows(rows):
    """Ascending bitonic sort of a list of same-shape lane vectors."""
    rows = list(rows)
    for stage in _bitonic_pairs(len(rows)):
        for i, l, asc in stage:
            lo = jnp.minimum(rows[i], rows[l])
            hi = jnp.maximum(rows[i], rows[l])
            rows[i], rows[l] = (lo, hi) if asc else (hi, lo)
    return rows


def sorted_weight_kernel(x_ref, o_ref, *, n_pad: int,
                         weights: Tuple[float, ...]):
    """One VMEM tile: x_ref [1, n_pad, block_d] -> o_ref [1, block_d].

    Rows [n, n_pad) are padding preloaded with +inf so they sort to the
    tail and ``weights`` (length n, indexed by sorted rank over the REAL
    rows) never touches them. The output is the static rank weighting
    sum_i weights[i] * sorted[i], accumulated in f32 — CWTM uses the
    trimmed-window weights, the coordinate-wise median the middle-rank
    weights (repro.kernels.median shares this kernel body).
    """
    rows = _sort_rows(x_ref[0, i, :].astype(jnp.float32)
                      for i in range(n_pad))
    acc = None
    for i, w in enumerate(weights):
        if w == 0.0:
            continue
        term = rows[i] * w if w != 1.0 else rows[i]
        acc = term if acc is None else acc + term
    o_ref[0, :] = acc.astype(o_ref.dtype)


def sorted_weighted_batched(x: jnp.ndarray, weights: Sequence[float], *,
                            block_d: int = 2048,
                            interpret: bool = False) -> jnp.ndarray:
    """Static rank weighting of the sorted worker axis: [B, n, d] -> [B, d].

    The shared tile plumbing of the CWTM / coordinate-wise-median kernels:
    grid (B, d/block_d), each step one memory-bound [n_pad, block_d] read.
    ``weights[i]`` scales the i-th smallest value per coordinate.
    """
    b, n, d = x.shape
    weights = tuple(float(w) for w in weights)
    assert len(weights) == n, (len(weights), n)
    n_pad = 1 << max(1, math.ceil(math.log2(n)))
    if n_pad != n:
        fill = jnp.full((b, n_pad - n, d), jnp.inf, x.dtype)
        x = jnp.concatenate([x, fill], axis=1)

    d_pad = (-d) % block_d
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad)))
    dp = d + d_pad

    kernel = functools.partial(sorted_weight_kernel, n_pad=n_pad,
                               weights=weights)
    out = pl.pallas_call(
        kernel,
        grid=(b, dp // block_d),
        in_specs=[pl.BlockSpec((1, n_pad, block_d), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, dp), x.dtype),
        interpret=interpret,
    )(x)
    return out[:, :d]


def cwtm_weights(n: int, f: int) -> Tuple[float, ...]:
    """Rank weights of the trimmed mean: 1/(n-2f) over ranks [f, n-f)."""
    assert n > 2 * f, (n, f)
    w = 1.0 / float(n - 2 * f)
    return tuple(w if f <= i < n - f else 0.0 for i in range(n))


def cwtm_pallas_batched(x: jnp.ndarray, f: int, *, block_d: int = 2048,
                        interpret: bool = False) -> jnp.ndarray:
    """Batched coordinate-wise trimmed mean: x [B, n, d] -> [B, d] — the
    grid engine's real shape (B = n_cells * n_seeds fusion lanes)."""
    return sorted_weighted_batched(x, cwtm_weights(x.shape[1], f),
                                   block_d=block_d, interpret=interpret)


def cwtm_pallas(x: jnp.ndarray, f: int, *, block_d: int = 2048,
                interpret: bool = False) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: x [n, d] -> [d]."""
    return cwtm_pallas_batched(x[None], f, block_d=block_d,
                               interpret=interpret)[0]
