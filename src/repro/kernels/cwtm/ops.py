"""Jitted wrapper for the CWTM kernel with automatic backend selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cwtm.cwtm import cwtm_pallas, cwtm_pallas_batched
from repro.kernels.cwtm.ref import cwtm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("f", "use_pallas", "interpret", "block_d"))
def cwtm(x: jnp.ndarray, f: int, *, use_pallas: bool | None = None,
         interpret: bool = False, block_d: int = 2048) -> jnp.ndarray:
    """Coordinate-wise trimmed mean over the worker axis.

    Accepts the per-lane ``[n, d]`` shape and the grid engine's batched
    ``[B, n, d]`` shape (B = n_cells * n_seeds fusion lanes) — the batched
    layout maps to ONE kernel launch with a (B, d/block_d) grid.

    use_pallas=None -> Pallas on TPU, XLA reference elsewhere (the dry-run
    and CPU tests take the XLA path; kernel correctness is covered by the
    interpret-mode sweeps in tests/test_kernels.py).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return cwtm_ref(x, f)
    if x.ndim == 3:
        return cwtm_pallas_batched(x, f, block_d=block_d, interpret=interpret)
    return cwtm_pallas(x, f, block_d=block_d, interpret=interpret)
