"""Jitted wrapper for the CWTM kernel with automatic backend selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cwtm.cwtm import cwtm_pallas
from repro.kernels.cwtm.ref import cwtm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("f", "use_pallas", "interpret"))
def cwtm(x: jnp.ndarray, f: int, *, use_pallas: bool | None = None,
         interpret: bool = False) -> jnp.ndarray:
    """Coordinate-wise trimmed mean over axis 0.

    use_pallas=None -> Pallas on TPU, XLA reference elsewhere (the dry-run
    and CPU tests take the XLA path; kernel correctness is covered by the
    interpret-mode sweeps in tests/test_kernels.py).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return cwtm_pallas(x, f, interpret=interpret)
    return cwtm_ref(x, f)
