"""Pure-jnp oracle for the coordinate-wise trimmed mean kernel."""

from __future__ import annotations

import jax.numpy as jnp


def cwtm_ref(x: jnp.ndarray, f: int) -> jnp.ndarray:
    """x: [..., n, d] -> [..., d]: drop the f largest / f smallest per
    coordinate, average the middle n - 2f (the worker axis is axis -2, so
    the same oracle covers the batched ``[B, n, d]`` grid-engine shape)."""
    n = x.shape[-2]
    assert n > 2 * f, (n, f)
    xs = jnp.sort(x, axis=-2)
    return jnp.mean(xs[..., f:n - f, :].astype(jnp.float32),
                    axis=-2).astype(x.dtype)
