"""Byzantine attack strategies.

Attacks see the honest workers' (compressed) momenta/gradients — the paper's
threat model is the worst case: colluding, omniscient Byzantine workers that
observe all honest messages. Every attack maps the stacked honest vectors
``honest: [h, d]`` to ``f`` Byzantine vectors ``[f, d]``.

``alie`` (A Little Is Enough, Baruch et al. [4]) is the attack used in the
paper's empirical evaluation (Fig. 1).
"""

from __future__ import annotations

import dataclasses
import math

import statistics

import jax
import jax.numpy as jnp


def _alie_z(n: int, f: int) -> float:
    """z-score threshold of ALIE: z = Phi^-1((n - f - s)/(n - f)) with
    s = floor(n/2 + 1) - f supporters needed to shift the median."""
    h = n - f
    s = math.floor(n / 2 + 1) - f
    frac = max(min((h - s) / h, 1.0 - 1e-6), 1e-6)
    return float(statistics.NormalDist().inv_cdf(frac))


def alie(honest: jnp.ndarray, f: int, z: float | None = None) -> jnp.ndarray:
    """A Little Is Enough: send mean - z * std, coordinate-wise."""
    h = honest.shape[0]
    n = h + f
    if z is None:
        z = _alie_z(n, f)
    mu = jnp.mean(honest, axis=0)
    sd = jnp.std(honest, axis=0)
    byz = mu - z * sd
    return jnp.broadcast_to(byz, (f,) + byz.shape)


def linear_attack(honest: jnp.ndarray, f: int,
                  coeffs: jnp.ndarray) -> jnp.ndarray:
    """The (a, b)-parameterised mean/std family: ``byz = a*mu + b*sd``.

    Expresses alie (a=1, b=-z), signflip (a=-scale), foe, ipm, and zero as
    *data* instead of code: ``coeffs`` is a traced ``[2]`` vector, so a grid
    of linear-family attacks compiles to ONE XLA program vmapped over the
    coefficient axis (see ``repro.core.sweep``) instead of one program per
    attack.
    """
    mu = jnp.mean(honest, axis=0)
    sd = jnp.std(honest, axis=0)
    byz = coeffs[0] * mu + coeffs[1] * sd
    return jnp.broadcast_to(byz, (f,) + byz.shape)


def linear_coeffs(cfg: "AttackConfig", n: int, f: int):
    """``(a, b)`` such that ``linear_attack`` reproduces ``cfg``, or ``None``
    when the attack is outside the mean/std family (mimic, gauss)."""
    if cfg.name == "alie":
        z = cfg.z if cfg.z is not None else _alie_z(n, f)
        return (1.0, -z)
    if cfg.name == "signflip":
        return (-(cfg.scale or 1.0), 0.0)
    if cfg.name == "ipm":
        return (-(cfg.scale or 0.5), 0.0)
    if cfg.name == "foe":
        return (-(cfg.scale or 10.0), 0.0)
    if cfg.name == "zero":
        return (0.0, 0.0)
    return None


def sign_flip(honest: jnp.ndarray, f: int, scale: float = 1.0) -> jnp.ndarray:
    """Send the negated honest mean (scaled)."""
    byz = -scale * jnp.mean(honest, axis=0)
    return jnp.broadcast_to(byz, (f,) + byz.shape)


def ipm(honest: jnp.ndarray, f: int, eps: float = 0.5) -> jnp.ndarray:
    """Inner-Product Manipulation (Xie et al.): -eps * honest mean; with small
    eps it keeps a negative inner product with the true gradient while staying
    inside typical filtering radii."""
    return sign_flip(honest, f, scale=eps)


def foe(honest: jnp.ndarray, f: int, scale: float = 10.0) -> jnp.ndarray:
    """Fall of Empires: large-magnitude negated mean."""
    return sign_flip(honest, f, scale=scale)


def mimic(honest: jnp.ndarray, f: int, target: int = 0) -> jnp.ndarray:
    """All Byzantine workers copy one honest worker, skewing the empirical
    distribution under heterogeneity."""
    byz = honest[target]
    return jnp.broadcast_to(byz, (f,) + byz.shape)


def gauss(honest: jnp.ndarray, f: int, key: jax.Array,
          std: float = 1.0) -> jnp.ndarray:
    """Random Gaussian noise (weak baseline attack)."""
    mu = jnp.mean(honest, axis=0)
    return mu + std * jax.random.normal(key, (f,) + mu.shape, honest.dtype)


def zero(honest: jnp.ndarray, f: int) -> jnp.ndarray:
    return jnp.zeros((f,) + honest.shape[1:], honest.dtype)


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """Named attack.

    Attributes:
      name: ``none`` | ``alie`` | ``signflip`` | ``ipm`` | ``foe`` |
        ``mimic`` | ``gauss`` | ``zero`` | ``spectral`` | ``ipm_greedy`` |
        ``linear`` (the traced mean/std family; coefficients arrive via
        ``apply_attack``'s ``params``) | ``bank`` (the switch-based attack
        bank of ``repro.adversary``; branch selected per grid cell by a
        traced ``ScenarioParams.attack_idx``). Stateful adversaries —
        the *tracked* mimic, ``spectral``, ``ipm_greedy`` — are executed by
        ``repro.adversary`` with memory carried in ``ServerState.attack``;
        :func:`apply_attack` below remains the stateless legacy dispatch
        (its ``mimic`` is the fixed-target variant).
      scale: magnitude parameter (signflip/foe/ipm/gauss/spectral/
        ipm_greedy).
      z: optional override of the ALIE z-score.
      bank: branch-name tuple when ``name='bank'`` (``None`` means the full
        ``repro.adversary.DEFAULT_ATTACK_BANK``).
    """

    name: str = "alie"
    scale: float | None = None
    z: float | None = None
    bank: tuple[str, ...] | None = None


def apply_attack(cfg: AttackConfig, honest: jnp.ndarray, f: int,
                 key: jax.Array | None = None,
                 params: jnp.ndarray | None = None) -> jnp.ndarray:
    """Produce the ``[f, d]`` Byzantine payload from honest ``[h, d]``.

    ``params`` carries traced attack parameters for ``name='linear'`` (the
    ``[2]`` coefficient vector of :func:`linear_attack`)."""
    if f == 0 or cfg.name == "none":
        return jnp.zeros((f,) + honest.shape[1:], honest.dtype)
    if cfg.name == "linear":
        assert params is not None, "linear attack needs a coeffs vector"
        return linear_attack(honest, f, params)
    if cfg.name == "alie":
        return alie(honest, f, z=cfg.z)
    if cfg.name == "signflip":
        return sign_flip(honest, f, scale=cfg.scale or 1.0)
    if cfg.name == "ipm":
        return ipm(honest, f, eps=cfg.scale or 0.5)
    if cfg.name == "foe":
        return foe(honest, f, scale=cfg.scale or 10.0)
    if cfg.name == "mimic":
        return mimic(honest, f)
    if cfg.name == "gauss":
        assert key is not None, "gauss attack needs a PRNG key"
        return gauss(honest, f, key, std=cfg.scale or 1.0)
    if cfg.name == "zero":
        return zero(honest, f)
    raise ValueError(
        f"unknown attack: {cfg.name!r} (apply_attack handles the stateless "
        "attacks none|linear|alie|signflip|ipm|foe|mimic|gauss|zero; "
        "stateful adversaries live in repro.adversary)")
