"""Coordinated and local gradient sparsification (the paper's Section 2/3).

Three unbiased sparsifiers are provided, all with the ``d/k`` unbiasedness
scaling of RandK:

* ``randk``      — exact RandK: ``k`` distinct uniformly-random coordinates
                   (permutation-based; intended for small ``d``, e.g. the
                   paper's 11.8k-parameter CNN).
* ``bernoulli``  — per-coordinate Bernoulli(k/d) mask. Unbiased with the same
                   scaling; the expected payload is ``k``. Cheap at any ``d``.
* ``block``      — Block-RandK (TPU adaptation, see DESIGN §3): sample
                   ``k/B`` of the ``d/B`` aligned blocks of size ``B``.
                   Contiguous payload, VMEM/lane-aligned; still a coordinated
                   unbiased sparsifier.

Masks come in two flavours matching the paper:
* **global** (Algorithm 1, step 1): one mask per round, shared by all
  workers — realised with a replicated PRNG key (0-byte broadcast).
* **local** (§3.3 RoSDHB-Local): each worker draws its own mask.

Compression is *simulated* densely: the wire format would carry only the
``k`` selected values; here ``compress`` returns the reconstructed estimate
``(d/k) * (g ⊙ mask)`` directly (what the server computes in step 4), while
``payload_bytes`` accounts for the real communication volume used by the
benchmark harness.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsifierConfig:
    """Configuration of the RandK-family sparsifier.

    Attributes:
      kind: ``randk`` | ``bernoulli`` | ``block`` | ``block_hash`` |
        ``natural`` | ``none``. ``natural`` is the paper's Appendix-C
        generalisation to arbitrary unbiased compressors: stochastic
        power-of-two rounding (Horvath et al. [20]), alpha = 9/8,
        ~9 bits/coordinate on the wire.
      ratio: compression ratio ``k/d`` in (0, 1]. ``alpha = 1/ratio``.
      block_size: block width for ``kind='block'``.
      local: if True, each worker samples its own mask (RoSDHB-Local);
        otherwise one global mask is shared (RoSDHB).
      use_pallas: Block-RandK compressor backend — ``None`` (default)
        auto-selects the ``repro.kernels.randk`` Pallas kernels on TPU and
        the jnp sparsifier elsewhere; ``True`` forces the kernel path
        (interpret mode off-TPU — parity testing); ``False`` forces jnp.
        Only ``kind='block'`` with a static ratio and
        ``d % block_size == 0`` has a kernel; everything else always runs
        the jnp path (same contract as ``AggregatorConfig.use_pallas``).
    """

    kind: str = "bernoulli"
    ratio: float = 1.0
    block_size: int = 512
    local: bool = False
    use_pallas: Optional[bool] = None

    @property
    def alpha(self) -> float:
        return 1.0 / self.ratio

    def k(self, d: int) -> int:
        return max(1, int(round(self.ratio * d)))


def _randk_mask(key: jax.Array, d: int, k: int, dtype) -> jnp.ndarray:
    """Exact RandK mask: k distinct coordinates set to 1."""
    idx = jax.random.permutation(key, d)[:k]
    return jnp.zeros((d,), dtype).at[idx].set(1)


def _bernoulli_mask(key: jax.Array, d: int, ratio: float, dtype) -> jnp.ndarray:
    return jax.random.bernoulli(key, ratio, (d,)).astype(dtype)


def _block_mask(key: jax.Array, d: int, ratio: float, block: int,
                dtype) -> jnp.ndarray:
    nb = -(-d // block)
    kb = max(1, int(round(ratio * nb)))
    bmask = jnp.zeros((nb,), dtype).at[jax.random.permutation(key, nb)[:kb]].set(1)
    full = jnp.repeat(bmask, block)[:d]
    return full


def _block_hash_mask(key: jax.Array, d: int, ratio: float, block: int,
                     dtype) -> jnp.ndarray:
    """Counter-based Bernoulli(ratio) block mask (§Perf iter 3).

    The permutation-based ``block`` mask materialises an UNSHARDED [d/B]
    vector (a 246M-element sort at 123B params) and a replicated repeat —
    at LLM scale GSPMD replicates ~[d] f32 per chip. This variant derives
    each block's keep/drop decision from a murmur-style integer hash of
    (block_id, per-round seed): pure elementwise ops over an iota, so GSPMD
    partitions it perfectly with zero communication and zero sort.

    Each block is kept independently with probability ``ratio`` — an
    unbiased coordinated sparsifier with the same (d/k) scaling (the exact-k
    guarantee of RandK is relaxed to E[k], as with ``bernoulli``).
    """
    seed = jax.random.bits(key, (), jnp.uint32)
    ids = jax.lax.iota(jnp.uint32, d) // jnp.uint32(block)
    h = ids * jnp.uint32(0x9E3779B1) + seed
    h ^= h >> 16
    h *= jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    h *= jnp.uint32(0xC2B2AE35)
    h ^= h >> 16
    u = h.astype(jnp.float32) * (1.0 / 4294967296.0)
    return (u < ratio).astype(dtype)


#: Sparsifier kinds whose keep-ratio may be a *traced* scalar — the mask
#: sampling and the unbiased rescale are pure elementwise functions of the
#: ratio, so a grid of ratios can join the vmapped fusion axis of
#: ``repro.core.sweep`` (the static-shape kinds randk/block cannot: their
#: ``k`` fixes index-array shapes at trace time).
TRACED_RATIO_KINDS = ("bernoulli", "block_hash")


def make_mask(key: jax.Array, d: int, cfg: SparsifierConfig,
              dtype=jnp.float32,
              ratio: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Sample one sparsification mask of shape ``[d]``.

    For ``kind='natural'`` the "mask" is the uniform rounding randomness
    u ~ U[0,1) consumed by :func:`compress`.

    ``ratio``, when given, is a traced scalar overriding ``cfg.ratio``
    (only for :data:`TRACED_RATIO_KINDS`)."""
    if ratio is not None:
        if cfg.kind == "bernoulli":
            return _bernoulli_mask(key, d, ratio, dtype)
        if cfg.kind == "block_hash":
            return _block_hash_mask(key, d, ratio, cfg.block_size, dtype)
        raise ValueError(
            f"sparsifier kind {cfg.kind!r} does not support a traced ratio "
            f"(supported: {TRACED_RATIO_KINDS})")
    if cfg.kind == "natural":
        return jax.random.uniform(key, (d,), dtype)
    if cfg.kind == "none" or cfg.ratio >= 1.0:
        return jnp.ones((d,), dtype)
    if cfg.kind == "randk":
        return _randk_mask(key, d, cfg.k(d), dtype)
    if cfg.kind == "bernoulli":
        return _bernoulli_mask(key, d, cfg.ratio, dtype)
    if cfg.kind == "block":
        return _block_mask(key, d, cfg.ratio, cfg.block_size, dtype)
    if cfg.kind == "block_hash":
        return _block_hash_mask(key, d, cfg.ratio, cfg.block_size, dtype)
    raise ValueError(f"unknown sparsifier kind: {cfg.kind!r}")


def make_masks(key: jax.Array, n_workers: int, d: int, cfg: SparsifierConfig,
               dtype=jnp.float32,
               ratio: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Sample masks ``[n_workers, d]``.

    With ``cfg.local=False`` (global sparsification, Algorithm 1) all rows are
    the *same* mask; with ``cfg.local=True`` (RoSDHB-Local, §3.3) each worker
    gets an independent mask. ``ratio`` optionally overrides ``cfg.ratio``
    with a traced scalar (see :func:`make_mask`).
    """
    if not cfg.local:
        m = make_mask(key, d, cfg, dtype, ratio=ratio)
        return jnp.broadcast_to(m, (n_workers, d))
    keys = jax.random.split(key, n_workers)
    return jax.vmap(lambda k: make_mask(k, d, cfg, dtype, ratio=ratio))(keys)


def compress(g: jnp.ndarray, mask: jnp.ndarray, cfg: SparsifierConfig,
             ratio: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Server-side unbiased reconstruction ``g̃ = (d/k)(g ⊙ mask)``.

    ``g`` may be ``[d]`` or ``[n, d]`` (with ``mask`` broadcastable).
    ``ratio`` optionally overrides ``cfg.ratio`` with a traced scalar; the
    unbiased rescale then uses the traced ``alpha = 1/ratio``.
    """
    if ratio is not None:
        return (g / ratio) * mask
    if cfg.kind == "natural":
        # stochastic power-of-two rounding: |x| in [2^e, 2^{e+1}) rounds up
        # with prob (|x|/2^e - 1); unbiased, E||C(x)||^2 <= (9/8)||x||^2.
        a = jnp.abs(g)
        safe = jnp.where(a > 0, a, 1.0)
        e = jnp.floor(jnp.log2(safe))
        lo = jnp.exp2(e)
        p = safe / lo - 1.0
        up = (mask < p).astype(g.dtype)
        out = jnp.sign(g) * lo * jnp.exp2(up)
        return jnp.where(a > 0, out, 0.0).astype(g.dtype)
    if cfg.kind == "none" or cfg.ratio >= 1.0:
        return g
    return (cfg.alpha * g) * mask


# --------------------------------------------------------------------------
# Pallas kernel backend (repro.kernels.randk) — Block-RandK round trip
# --------------------------------------------------------------------------


def resolve_kernel_backend(use_pallas: Optional[bool]
                           ) -> Optional[Dict[str, bool]]:
    """Resolve ``SparsifierConfig.use_pallas`` against the live backend —
    the same contract as ``aggregators.resolve_kernel_backend``: ``None``
    for the jnp sparsifier, else ``{"interpret": bool}`` (interpret mode
    whenever the backend is not a TPU, so forcing the kernels on CPU
    exercises the real kernel bodies instead of failing to lower)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return None
    return {"interpret": not on_tpu}


def kernel_backend_label(cfg: SparsifierConfig) -> str:
    """Resolved compressor backend: ``pallas`` | ``pallas-interpret`` |
    ``jnp``."""
    kb = resolve_kernel_backend(cfg.use_pallas)
    if kb is None:
        return "jnp"
    return "pallas-interpret" if kb["interpret"] else "pallas"


def _kernel_eligible(cfg: SparsifierConfig, d: int,
                     ratio: Optional[jnp.ndarray]) -> bool:
    """Only exact Block-RandK with a static keep-ratio and block-aligned
    ``d`` has a kernel; anything else stays on the jnp sparsifier."""
    return (cfg.kind == "block" and ratio is None and cfg.ratio < 1.0
            and d % cfg.block_size == 0)


def compressed_estimate(grads: jnp.ndarray, mask_key: jax.Array,
                        cfg: SparsifierConfig,
                        ratio: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Steps 1+4 in one call: sample the round's masks from ``mask_key`` and
    return the server-side unbiased reconstruction ``(d/k)(g ⊙ mask)`` for a
    ``[n, d]`` gradient bank.

    The jnp path is literally :func:`make_masks` + :func:`compress` — the
    trajectory graph is unchanged. When the resolved backend
    (:func:`resolve_kernel_backend`) selects the Pallas kernels and the
    config is kernel-eligible (:func:`_kernel_eligible`), the dense
    mask-multiply is replaced by the ``repro.kernels.randk``
    compress → decompress round trip over the REAL wire payload
    (``[k_blocks * block_size]`` values + block ids): block ids are sampled
    with exactly the ``_block_mask`` permutation (same key, same
    ``round(ratio * nb)`` count — global masks share one id vector, local
    masks split the key per worker), and the scatter of ``alpha * g`` is
    bitwise the f32 mask-multiply on finite gradients.
    """
    n, d = grads.shape
    kb = resolve_kernel_backend(cfg.use_pallas)
    if kb is None or not _kernel_eligible(cfg, d, ratio):
        masks = make_masks(mask_key, n, d, cfg, dtype=grads.dtype,
                           ratio=ratio)
        return compress(grads, masks, cfg, ratio=ratio)

    from repro.kernels.randk import ops as RK
    nb = d // cfg.block_size
    k_blocks = max(1, int(round(cfg.ratio * nb)))

    def block_ids(key: jax.Array) -> jnp.ndarray:
        # identical sampling to _block_mask: permutation prefix of the
        # block index set (order is irrelevant to the reconstruction)
        return jax.random.permutation(key, nb)[:k_blocks].astype(jnp.int32)

    if cfg.local:
        ids = jax.vmap(block_ids)(jax.random.split(mask_key, n))
    else:
        ids = jnp.broadcast_to(block_ids(mask_key), (n, k_blocks))

    def roundtrip(args):
        g_row, id_row = args
        payload = RK.compress(g_row, id_row, block_size=cfg.block_size,
                              alpha=cfg.alpha, use_pallas=True,
                              interpret=kb["interpret"])
        return RK.decompress(payload, id_row, block_size=cfg.block_size,
                             d=d, use_pallas=True,
                             interpret=kb["interpret"])

    return jax.lax.map(roundtrip, (grads, ids))


def payload_floats(d: int, cfg: SparsifierConfig) -> int:
    """Number of float values one worker sends per round (wire payload)."""
    if cfg.kind == "none" or cfg.ratio >= 1.0:
        return d
    return cfg.k(d)


def index_bytes(d: int) -> int:
    """Bytes needed to address one of ``d`` coordinates:
    ``ceil(log2(d) / 8)``, at least 1.

    A flat 4 bytes per index (the old accounting) overstates the index
    overhead by 4x for models under 2^8 coordinates and by 2x under 2^16 —
    at the paper's 11.8k-parameter CNN that error dominates the
    comm-to-threshold comparison for small keep-ratios.
    """
    if d < 2:
        return 1
    return max(1, math.ceil(math.log2(d) / 8.0))


def payload_bytes(d: int, cfg: SparsifierConfig, bytes_per_value: int = 4,
                  with_mask_indices: bool = False) -> int:
    """Per-worker uplink bytes per round.

    With global sparsification the mask is derived from a shared PRNG, so no
    index bits are sent. With local sparsification the worker must identify
    its coordinates; we charge :func:`index_bytes` — ``ceil(log2(d)/8)`` —
    bytes per index when requested (the minimal fixed-width index encoding,
    so comm-to-threshold curves stay honest for small models).
    """
    if cfg.kind == "natural":
        # sign + 8-bit exponent per coordinate
        return int(d * 9 / 8 / 4 * bytes_per_value)
    k = payload_floats(d, cfg)
    b = k * bytes_per_value
    if with_mask_indices and cfg.local and cfg.ratio < 1.0:
        b += k * index_bytes(d)
    return b
