"""Canonical uplink wire-format accounting, shared simulator <-> server.

The four algorithms transmit different quantities, so a shared formula
misprices the paper's communication comparison:

* ``rosdhb`` / ``dgd`` — the sparsified gradient: ``k`` values; index bytes
  only for *local* masks (the coordinated global mask is a shared PRNG draw
  — RoSDHB's headline communication trick — so it costs 0 wire bytes).
* ``robust_dgd`` — the raw uncompressed gradient: ``d`` values, no indices.
* ``dasha`` — the compressed per-worker momentum *difference*
  (Byz-DASHA-PAGE): each worker runs its own independent compressor (the
  analysis of [29] requires independent unbiasedness; there is no shared
  coordinated mask), so the wire always carries the ``k`` values PLUS their
  coordinate indices (``compression.index_bytes`` each).

Both ``Simulator.payload_bytes_per_round`` (via
``algorithms.algo_payload_bytes``) and the streaming parameter server's
``repro.serve.protocol`` price updates through this one module, so the
closed-world simulation and the service can never disagree on what a round
costs on the wire.
"""

from __future__ import annotations

import dataclasses

from repro.core import compression as C

#: Algorithms with a well-defined single-worker uplink format.
WIRE_ALGORITHMS = ("rosdhb", "dasha", "robust_dgd", "dgd")


def per_worker_payload_bytes(algo: str, d: int, sp: C.SparsifierConfig,
                             bytes_per_value: int = 4) -> int:
    """Uplink bytes ONE worker sends per round under ``algo``'s actual wire
    format (``d`` is the true model dimension, unpadded)."""
    if algo == "robust_dgd":
        return d * bytes_per_value
    if algo in ("rosdhb", "dgd"):
        return C.payload_bytes(d, sp, bytes_per_value=bytes_per_value,
                               with_mask_indices=True)
    if algo == "dasha":
        return C.payload_bytes(d, dataclasses.replace(sp, local=True),
                               bytes_per_value=bytes_per_value,
                               with_mask_indices=True)
    raise ValueError(
        f"no single wire format for algorithm {algo!r} (expected one of "
        f"{'|'.join(WIRE_ALGORITHMS)}) — a bank config mixes algorithms; "
        "account per cell with each cell's own config")


def round_payload_bytes(algo: str, d: int, sp: C.SparsifierConfig,
                        n_workers: int, bytes_per_value: int = 4) -> int:
    """Total uplink bytes per round across all ``n_workers`` (the paper
    counts every worker — the server cannot know who is honest)."""
    return per_worker_payload_bytes(algo, d, sp, bytes_per_value) * n_workers
