"""Core of the paper's contribution: RoSDHB and its competitors.

See DESIGN.md §1-3. The module split mirrors Algorithm 1:
  compression  - step 1-4 (masks + unbiased sparsified reconstruction)
  algorithms   - step 5-7 (momentum bank, robust aggregation, update) for
                 rosdhb / dasha / robust_dgd / dgd
  aggregators  - the (f, kappa)-robust rules F
  attacks      - the Byzantine adversary
  simulator    - paper-scale single-host training loop (lax.scan engine,
                 eval snapshots carried in-scan)
  sweep        - attack x aggregator x algorithm x seed grid runner
                 (plan/execute: maximal fusible banks, one device-sharded
                 XLA program per bank)
"""

from repro.core.compression import (
    SparsifierConfig, index_bytes, make_mask, make_masks, compress,
    payload_bytes, payload_floats,
)
from repro.core.aggregators import (
    AggregatorConfig, make_aggregator, make_aggregator_bank, bank_index,
    DEFAULT_BANK,
)
from repro.core.attacks import AttackConfig, apply_attack
from repro.core.algorithms import (
    ALGO_BANK,
    SERVE_ALGORITHMS,
    AlgorithmConfig,
    ScenarioParams,
    ServerState,
    StateLayout,
    algo_index,
    algo_payload_bytes,
    init_state,
    make_algorithm_bank,
    make_serve_apply_fn,
    make_wire_fn,
    server_round,
    server_state_bytes,
    apply_direction,
    theorem1_hparams,
)
from repro.core.wire import per_worker_payload_bytes, round_payload_bytes
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.simulator import Simulator, SimState, stack_batches
from repro.core.sweep import (
    Scenario, GridPlan, FusedBank, KNOWN_ALGORITHMS, grid_scenarios,
    plan_grid, execute_plan, rollout_over_seeds, fused_attack_rollout,
    fused_grid_rollout, fused_grid_eval, run_scenarios, bytes_to_threshold,
    quadratic_testbed,
)

__all__ = [
    "SparsifierConfig", "index_bytes", "make_mask", "make_masks", "compress",
    "payload_bytes", "payload_floats",
    "AggregatorConfig", "make_aggregator", "make_aggregator_bank",
    "bank_index", "DEFAULT_BANK",
    "AttackConfig", "apply_attack",
    "ALGO_BANK", "SERVE_ALGORITHMS", "AlgorithmConfig", "ScenarioParams",
    "ServerState", "StateLayout",
    "algo_index", "algo_payload_bytes", "init_state", "make_algorithm_bank",
    "make_serve_apply_fn", "make_wire_fn",
    "server_round", "server_state_bytes", "apply_direction",
    "theorem1_hparams",
    "per_worker_payload_bytes", "round_payload_bytes",
    "CostModel", "DEFAULT_COST_MODEL",
    "Simulator", "SimState", "stack_batches",
    "Scenario", "GridPlan", "FusedBank", "KNOWN_ALGORITHMS",
    "grid_scenarios", "plan_grid",
    "execute_plan", "rollout_over_seeds", "fused_attack_rollout",
    "fused_grid_rollout", "fused_grid_eval", "run_scenarios",
    "bytes_to_threshold", "quadratic_testbed",
]
