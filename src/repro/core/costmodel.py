"""Measured cost model for ``plan_grid``'s fusion-vs-partition decision.

Fusing a cross-algorithm grid into one ``lax.switch`` program saves
compiles but is not free at runtime: under ``vmap`` a switch computes every
branch for every lane, so a W-branch bank pays roughly W branches of work
per cell per round, where the per-algorithm partition pays one branch per
cell but W compiles. Which side wins depends on the grid (rows = cells x
seeds), the trajectory length (rounds), and two machine-dependent rates —
compile cost and warm per-cell-round cost. PR 4 shipped the fused default
unconditionally and the Table-1 grid regressed to 0.52x warm
(results/BENCH_sweep.json, cross_algo_grid); this module makes the choice
*measured* instead of assumed.

:class:`CostModel` is five calibrated scalars:

* ``compile_s`` + ``compile_s_per_branch``: compile cost of one bank
  program as an affine function of its algorithm-branch count.
* ``cell_round_us`` + ``cell_round_us_per_branch``: warm execution cost of
  one (cell x seed) row for one round, again affine in the branch count
  (the per-branch term is the switch-divergence price).
* ``sharded_compile_overhead_s``: extra compile seconds per program when it
  is laid out over a >1-device mesh, charged per program so it penalises
  the many-program partition (measured by bench_sweep's sharded probe).

``benchmarks/bench_sweep.py``'s calibration pass measures a 1-branch and a
W-branch probe bank cold+warm and persists the fit to
``results/COST_MODEL.json`` (:meth:`CostModel.fit` / :meth:`save`);
``plan_grid(cost_model=..., rounds=..., n_seeds=...)`` then compares
:meth:`fused_s` against :meth:`partitioned_s` per candidate bank and
partitions exactly when the model predicts the fused program is slower.
Decisions are pure arithmetic over the pinned JSON — deterministic, and
property-tested in tests/test_costmodel.py.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

#: Canonical on-disk location of the calibrated model (written by the
#: bench_sweep calibration pass, read by CLI/users via ``CostModel.load``).
DEFAULT_PATH = "results/COST_MODEL.json"


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated compile/warm cost rates for fused-bank programs.

    All rates are machine-specific; ``source`` records where they came from
    (the pinned default vs. a calibration run). The model is intentionally
    tiny — two affine laws — because its only job is a *binary* plan
    decision with a ~2x gap on the wrong side; see the module docstring.
    """

    compile_s: float               # base compile cost of one bank program
    compile_s_per_branch: float    # extra compile cost per algorithm branch
    cell_round_us: float           # warm us per (cell x seed) row per round
    cell_round_us_per_branch: float  # extra warm us per row-round per extra branch
    #: Extra compile seconds when the program is laid out over a >1-device
    #: mesh (SPMD partitioning + per-device codegen). Measured by
    #: bench_sweep's ``_sharded_grid`` probe (observed ~+1.4s on the 8-way
    #: CPU mesh) and folded back into the persisted model; 0.0 until a
    #: sharded calibration has run.
    sharded_compile_overhead_s: float = 0.0
    source: str = "pinned-default"

    def program_s(self, *, branches: int, rows: int, rounds: int,
                  sharded: bool = False) -> float:
        """Predicted total seconds (compile + warm execution) of ONE bank
        program with ``branches`` algorithm branches over ``rows`` =
        cells x seeds flat lanes for ``rounds`` scan steps. ``sharded``
        adds the mesh-compile overhead (each program pays it once, so the
        per-algorithm partition pays it once per algorithm)."""
        if branches < 1:
            raise ValueError(f"branches must be >= 1, got {branches}")
        if rows < 0 or rounds < 0:
            raise ValueError(f"rows/rounds must be >= 0, got {rows}/{rounds}")
        compile_cost = self.compile_s + self.compile_s_per_branch * branches
        if sharded:
            compile_cost += self.sharded_compile_overhead_s
        row_round_us = (self.cell_round_us
                        + self.cell_round_us_per_branch * (branches - 1))
        return compile_cost + row_round_us * 1e-6 * rows * rounds

    def fused_s(self, cells_per_algo: Dict[str, int], n_seeds: int,
                rounds: int, *, sharded: bool = False) -> float:
        """Predicted cost of running the whole group as ONE cross-algorithm
        bank (branch count = number of distinct algorithms)."""
        rows = sum(cells_per_algo.values()) * n_seeds
        return self.program_s(branches=len(cells_per_algo), rows=rows,
                              rounds=rounds, sharded=sharded)

    def partitioned_s(self, cells_per_algo: Dict[str, int], n_seeds: int,
                      rounds: int, *, sharded: bool = False) -> float:
        """Predicted cost of the per-algorithm partition: one single-branch
        bank program (its own compile — and its own mesh-compile overhead
        when ``sharded``) per algorithm."""
        return sum(
            self.program_s(branches=1, rows=c * n_seeds, rounds=rounds,
                           sharded=sharded)
            for c in cells_per_algo.values())

    def prefer_fused(self, cells_per_algo: Dict[str, int], n_seeds: int,
                     rounds: int, *, sharded: bool = False) -> bool:
        """The plan decision: fuse iff the fused program is predicted no
        slower than the per-algorithm partition (ties fuse — fewer
        programs). Sharded compiles tilt toward fusing: the overhead is
        per program, and the partition compiles more programs."""
        return (self.fused_s(cells_per_algo, n_seeds, rounds, sharded=sharded)
                <= self.partitioned_s(cells_per_algo, n_seeds, rounds,
                                      sharded=sharded))

    # -- calibration ------------------------------------------------------

    @classmethod
    def fit(cls, *, single_cold_s: float, single_warm_s: float,
            single_rows: int, fused_cold_s: float, fused_warm_s: float,
            fused_rows: int, branches: int, rounds: int,
            source: str = "calibration") -> "CostModel":
        """Fit the four rates from one 1-branch and one ``branches``-branch
        probe, each timed cold (first call, compile included) and warm
        (cached program). Pure arithmetic — same measurements, same model.

        Rates are clamped at zero: on a noisy host a warm probe can beat its
        own cold run, and a negative rate would make the decision grow
        *fonder* of the congested side as grids scale.
        """
        if branches < 2:
            raise ValueError("fit needs a multi-branch probe (branches >= 2)")
        if min(single_rows, fused_rows, rounds) <= 0:
            raise ValueError("probe rows/rounds must be positive")
        rate_1 = max(0.0, single_warm_s * 1e6 / (single_rows * rounds))
        rate_w = max(0.0, fused_warm_s * 1e6 / (fused_rows * rounds))
        per_branch_us = max(0.0, (rate_w - rate_1) / (branches - 1))
        compile_1 = max(0.0, single_cold_s - single_warm_s)
        compile_w = max(0.0, fused_cold_s - fused_warm_s)
        per_branch_s = max(0.0, (compile_w - compile_1) / (branches - 1))
        return cls(compile_s=max(0.0, compile_1 - per_branch_s),
                   compile_s_per_branch=per_branch_s,
                   cell_round_us=rate_1,
                   cell_round_us_per_branch=per_branch_us,
                   source=source)

    # -- persistence ------------------------------------------------------

    def save(self, path: str = DEFAULT_PATH) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump(dataclasses.asdict(self), fh, indent=2)
            fh.write("\n")
        return path

    @classmethod
    def load(cls, path: str = DEFAULT_PATH) -> "CostModel":
        """Load a pinned model; unknown keys are rejected loudly so a stale
        or hand-edited file cannot silently change plan decisions."""
        with open(path) as fh:
            raw = json.load(fh)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(raw) - known)
        if unknown:
            raise ValueError(
                f"unknown cost-model keys {unknown} in {path} "
                f"(expected a subset of {sorted(known)})")
        return cls(**raw)

    @classmethod
    def load_or_default(cls, path: Optional[str] = None) -> "CostModel":
        """The calibrated file if present, else the pinned
        :data:`DEFAULT_COST_MODEL` — so plan decisions exist (and are
        deterministic) before any calibration pass has run on this host."""
        p = path or DEFAULT_PATH
        if os.path.exists(p):
            return cls.load(p)
        return DEFAULT_COST_MODEL


#: Pinned fallback rates, measured on the 8-core CPU dev/CI host that also
#: produced results/BENCH_sweep.json (quadratic testbed, D=64, n=13). The
#: absolute numbers matter less than the ratio structure: a 4-branch switch
#: runs every branch per vmap lane (~4-5x the single-branch warm rate), and
#: one bank compile costs seconds — so small/short grids fuse, large/long
#: grids partition. Recalibrate with `python -m benchmarks.bench_sweep`.
DEFAULT_COST_MODEL = CostModel(
    compile_s=1.3,
    compile_s_per_branch=0.55,
    cell_round_us=120.0,
    cell_round_us_per_branch=100.0,
    source="pinned-default",
)
