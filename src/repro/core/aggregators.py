"""(f, kappa)-robust aggregation rules (Definition 2.2 of the paper).

All aggregators map a stacked array ``x: [n, d]`` of per-worker vectors to a
single ``[d]`` vector. The paper's experiments use coordinate-wise trimmed
mean (CWTM); we additionally provide coordinate-wise median, geometric median
(smoothed Weiszfeld), (Multi-)Krum, and the NNM pre-aggregation wrapper of
Allouah et al. [2], which upgrades any of these to the optimal
``kappa = O(f/n)`` regime.

Robustness coefficients (from Guerraoui-Gupta-Pinot, "Robust Machine
Learning", ch. 4; used by the benchmark harness to check Theorem 1's
``kappa * B^2 <= 1/25`` precondition):

  CWTM:    kappa <= 6 f/n (1 + f/(n-2f))     (with NNM: O(f/n))
  Median:  kappa <= (1 + f/(n-2f))^2 ... conservatively 4(1 + f/(n-2f))
  GeoMed:  kappa <= (1 + f/(n-2f))^2
  Krum:    kappa <= 6(1 + f/(n-2f))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp


Aggregator = Callable[[jnp.ndarray], jnp.ndarray]


def mean(x: jnp.ndarray) -> jnp.ndarray:
    """Plain averaging — NOT robust (kappa unbounded); the non-robust baseline."""
    return jnp.mean(x, axis=0)


def coordinate_median(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.median(x, axis=0)


def trimmed_mean(x: jnp.ndarray, f: int) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: drop the f largest and f smallest values
    per coordinate, average the middle ``n - 2f``."""
    n = x.shape[0]
    if f == 0:
        return jnp.mean(x, axis=0)
    if n - 2 * f <= 0:
        raise ValueError(f"trimmed_mean requires n > 2f, got n={n}, f={f}")
    xs = jnp.sort(x, axis=0)
    return jnp.mean(xs[f:n - f], axis=0)


def geometric_median(x: jnp.ndarray, iters: int = 8,
                     eps: float = 1e-8) -> jnp.ndarray:
    """Smoothed Weiszfeld iteration for the geometric median."""
    z = jnp.mean(x, axis=0)

    def body(_, z):
        dist = jnp.sqrt(jnp.sum(jnp.square(x - z[None, :]), axis=1) + eps)
        w = 1.0 / dist
        w = w / jnp.sum(w)
        return jnp.sum(w[:, None] * x, axis=0)

    return jax.lax.fori_loop(0, iters, body, z)


def _pairwise_sq_dists(x: jnp.ndarray) -> jnp.ndarray:
    sq = jnp.sum(jnp.square(x), axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def krum(x: jnp.ndarray, f: int, m: int = 1) -> jnp.ndarray:
    """(Multi-)Krum: average the ``m`` vectors with the smallest sum of
    squared distances to their ``n - f - 2`` nearest neighbours."""
    n = x.shape[0]
    q = max(1, n - f - 2)
    d2 = _pairwise_sq_dists(x)
    d2 = d2.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    nearest = jnp.sort(d2, axis=1)[:, :q]
    scores = jnp.sum(nearest, axis=1)
    sel = jnp.argsort(scores)[:m]
    return jnp.mean(x[sel], axis=0)


def nnm(x: jnp.ndarray, f: int) -> jnp.ndarray:
    """Nearest-Neighbour Mixing pre-aggregation [2]: replace each vector by
    the average of its ``n - f`` nearest neighbours (including itself)."""
    n = x.shape[0]
    q = n - f
    d2 = _pairwise_sq_dists(x)
    idx = jnp.argsort(d2, axis=1)[:, :q]  # self has distance 0 -> included
    return jnp.mean(x[idx], axis=1)


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    """Named robust-aggregation rule.

    Attributes:
      name: ``mean`` | ``cwtm`` | ``median`` | ``geomed`` | ``krum`` |
        ``multikrum``.
      f: number of tolerated Byzantine workers.
      pre_nnm: compose with NNM pre-aggregation (recommended; gives the
        optimal kappa = O(f/n) per [2]).
      geomed_iters: Weiszfeld iterations for ``geomed``.
    """

    name: str = "cwtm"
    f: int = 0
    pre_nnm: bool = False
    geomed_iters: int = 8

    def kappa_bound(self, n: int) -> float:
        """Conservative upper bound on the robustness coefficient kappa."""
        f = self.f
        if f == 0:
            return 0.0
        if n <= 2 * f:
            return float("inf")
        r = f / (n - 2 * f)
        base = {
            "mean": float("inf"),
            "cwtm": 6.0 * (f / n) * (1.0 + r),
            "median": 4.0 * (1.0 + r),
            "geomed": (1.0 + r) ** 2,
            "krum": 6.0 * (1.0 + r),
            "multikrum": 6.0 * (1.0 + r),
        }[self.name]
        if self.pre_nnm and self.name != "mean":
            # NNM composition: kappa <= 8 f/n (1 + kappa_base) per [2] Thm 2.
            return 8.0 * (f / n) * (1.0 + base)
        return base


def make_aggregator(cfg: AggregatorConfig) -> Aggregator:
    """Build an aggregator ``[n, d] -> [d]`` from a config."""
    f = cfg.f
    base: Aggregator
    if cfg.name == "mean":
        base = mean
    elif cfg.name == "cwtm":
        base = functools.partial(trimmed_mean, f=f)
    elif cfg.name == "median":
        base = coordinate_median
    elif cfg.name == "geomed":
        base = functools.partial(geometric_median, iters=cfg.geomed_iters)
    elif cfg.name == "krum":
        base = functools.partial(krum, f=f, m=1)
    elif cfg.name == "multikrum":
        base = lambda x: krum(x, f=f, m=max(1, x.shape[0] - f))  # noqa: E731
    else:
        raise ValueError(f"unknown aggregator: {cfg.name!r}")

    if cfg.pre_nnm and cfg.name != "mean":
        def agg(x: jnp.ndarray) -> jnp.ndarray:
            return base(nnm(x, f))
        return agg
    return base
