"""(f, kappa)-robust aggregation rules (Definition 2.2 of the paper).

All aggregators map a stacked array ``x: [n, d]`` of per-worker vectors to a
single ``[d]`` vector. The paper's experiments use coordinate-wise trimmed
mean (CWTM); we additionally provide coordinate-wise median, geometric median
(smoothed Weiszfeld), (Multi-)Krum, and the NNM pre-aggregation wrapper of
Allouah et al. [2], which upgrades any of these to the optimal
``kappa = O(f/n)`` regime.

Robustness coefficients (from Guerraoui-Gupta-Pinot, "Robust Machine
Learning", ch. 4; used by the benchmark harness to check Theorem 1's
``kappa * B^2 <= 1/25`` precondition):

  CWTM:    kappa <= 6 f/n (1 + f/(n-2f))     (with NNM: O(f/n))
  Median:  kappa <= (1 + f/(n-2f))^2 ... conservatively 4(1 + f/(n-2f))
  GeoMed:  kappa <= (1 + f/(n-2f))^2
  Krum:    kappa <= 6(1 + f/(n-2f))
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import batchable


Aggregator = Callable[[jnp.ndarray], jnp.ndarray]

#: Rules with a Pallas TPU kernel implementation (``repro.kernels.cwtm`` /
#: ``median`` / ``pairdist``); ``mean`` and ``geomed`` stay pure-jnp (a mean
#: is already one fused XLA pass; Weiszfeld is a data-dependent fixed-point
#: loop of matvecs). NNM pre-aggregation is kernel-backed through the
#: pairwise-distance kernel regardless of the base rule.
KERNEL_RULES: Tuple[str, ...] = ("cwtm", "median", "krum", "multikrum")

#: ``(name, pre_nnm)`` branch labels of the default aggregator bank, in
#: switch order. ``(mean, True)`` is intentionally absent — NNM composition
#: skips the non-robust mean (see :func:`make_aggregator`); ``bank_index``
#: maps it onto the plain-mean branch.
BANK_NAMES: Tuple[str, ...] = ("mean", "cwtm", "median", "geomed", "krum",
                               "multikrum")
DEFAULT_BANK: Tuple[Tuple[str, bool], ...] = (
    tuple((n, False) for n in BANK_NAMES)
    + tuple((n, True) for n in BANK_NAMES if n != "mean"))


def mean(x: jnp.ndarray) -> jnp.ndarray:
    """Plain averaging — NOT robust (kappa unbounded); the non-robust baseline."""
    return jnp.mean(x, axis=0)


def coordinate_median(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.median(x, axis=0)


def trimmed_mean(x: jnp.ndarray, f: int) -> jnp.ndarray:
    """Coordinate-wise trimmed mean: drop the f largest and f smallest values
    per coordinate, average the middle ``n - 2f``."""
    n = x.shape[0]
    if f == 0:
        return jnp.mean(x, axis=0)
    if n - 2 * f <= 0:
        raise ValueError(f"trimmed_mean requires n > 2f, got n={n}, f={f}")
    xs = jnp.sort(x, axis=0)
    return jnp.mean(xs[f:n - f], axis=0)


def geometric_median(x: jnp.ndarray, iters: int = 8,
                     eps: float = 1e-8) -> jnp.ndarray:
    """Smoothed Weiszfeld iteration for the geometric median."""
    z = jnp.mean(x, axis=0)

    def body(_, z):
        dist = jnp.sqrt(jnp.sum(jnp.square(x - z[None, :]), axis=1) + eps)
        w = 1.0 / dist
        w = w / jnp.sum(w)
        return jnp.sum(w[:, None] * x, axis=0)

    return jax.lax.fori_loop(0, iters, body, z)


def _pairwise_sq_dists(x: jnp.ndarray) -> jnp.ndarray:
    sq = jnp.sum(jnp.square(x), axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def krum(x: jnp.ndarray, f: int, m: int = 1) -> jnp.ndarray:
    """(Multi-)Krum: average the ``m`` vectors with the smallest sum of
    squared distances to their ``n - f - 2`` nearest neighbours."""
    n = x.shape[0]
    q = max(1, n - f - 2)
    d2 = _pairwise_sq_dists(x)
    d2 = d2.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    nearest = jnp.sort(d2, axis=1)[:, :q]
    scores = jnp.sum(nearest, axis=1)
    sel = jnp.argsort(scores)[:m]
    return jnp.mean(x[sel], axis=0)


def nnm(x: jnp.ndarray, f: int) -> jnp.ndarray:
    """Nearest-Neighbour Mixing pre-aggregation [2]: replace each vector by
    the average of its ``n - f`` nearest neighbours (including itself)."""
    n = x.shape[0]
    q = n - f
    d2 = _pairwise_sq_dists(x)
    idx = jnp.argsort(d2, axis=1)[:, :q]  # self has distance 0 -> included
    return jnp.mean(x[idx], axis=1)


@dataclasses.dataclass(frozen=True)
class AggregatorConfig:
    """Named robust-aggregation rule.

    Attributes:
      name: ``mean`` | ``cwtm`` | ``median`` | ``geomed`` | ``krum`` |
        ``multikrum``.
      f: number of tolerated Byzantine workers.
      pre_nnm: compose with NNM pre-aggregation (recommended; gives the
        optimal kappa = O(f/n) per [2]).
      geomed_iters: Weiszfeld iterations for ``geomed``.
      bank: branch set ``((name, pre_nnm), ...)`` when ``name='bank'`` — the
        switch-based aggregator bank whose branch is selected per grid cell
        by a traced index (see :func:`make_aggregator_bank`). ``None`` means
        :data:`DEFAULT_BANK`.
      use_pallas: kernel backend of the :data:`KERNEL_RULES` rules.
        ``None`` (default) auto-selects: Pallas TPU kernels on a TPU
        backend, the pure-jnp reference rules elsewhere. ``True`` forces
        the kernel path (interpret mode off-TPU — slow, for parity tests);
        ``False`` forces the jnp rules everywhere.
    """

    name: str = "cwtm"
    f: int = 0
    pre_nnm: bool = False
    geomed_iters: int = 8
    bank: Optional[Tuple[Tuple[str, bool], ...]] = None
    use_pallas: Optional[bool] = None

    def kappa_bound(self, n: int) -> float:
        """Conservative upper bound on the robustness coefficient kappa."""
        f = self.f
        if self.name not in BANK_NAMES:
            raise ValueError(
                f"unknown aggregator: {self.name!r} (expected one of "
                f"{'|'.join(BANK_NAMES)})")
        if f == 0:
            return 0.0
        if n <= 2 * f:
            return float("inf")
        r = f / (n - 2 * f)
        base = {
            "mean": float("inf"),
            "cwtm": 6.0 * (f / n) * (1.0 + r),
            "median": 4.0 * (1.0 + r),
            "geomed": (1.0 + r) ** 2,
            "krum": 6.0 * (1.0 + r),
            "multikrum": 6.0 * (1.0 + r),
        }[self.name]
        if self.pre_nnm and self.name != "mean":
            # NNM composition: kappa <= 8 f/n (1 + kappa_base) per [2] Thm 2.
            return 8.0 * (f / n) * (1.0 + base)
        return base


# --------------------------------------------------------------------------
# Pallas kernel backend (repro.kernels.{cwtm,median,pairdist})
# --------------------------------------------------------------------------


def resolve_kernel_backend(use_pallas: Optional[bool]
                           ) -> Optional[Dict[str, bool]]:
    """Resolve ``AggregatorConfig.use_pallas`` against the live backend.

    Returns ``None`` for the pure-jnp rules, else ``{"interpret": bool}``
    for the kernel path — interpret mode whenever the backend is not a TPU,
    so ``use_pallas=True`` on CPU exercises the real kernel bodies (the
    parity-test path) instead of failing to lower.
    """
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if not use_pallas:
        return None
    return {"interpret": not on_tpu}


def kernel_backend_label(use_pallas: Optional[bool]) -> str:
    """Human-readable resolved backend: ``pallas`` | ``pallas-interpret`` |
    ``jnp`` (surfaced by ``Simulator`` / the sweep CLI / bench_kernels)."""
    kb = resolve_kernel_backend(use_pallas)
    if kb is None:
        return "jnp"
    return "pallas-interpret" if kb["interpret"] else "pallas"


def _kernel_pairdist(interpret: bool) -> Aggregator:
    """The batched pairwise-squared-distance kernel as a per-lane op:
    ``vmap`` over the fused grid axis lands on the explicit [B, n, n]
    batched launch (see ``repro.kernels.batchable``)."""
    from repro.kernels.pairdist import pairdist
    fn = functools.partial(pairdist, use_pallas=True, interpret=interpret)
    return batchable(fn, fn)


def _kernel_nnm(f: int, interpret: bool) -> Aggregator:
    """Kernel-backed NNM pre-aggregation: distances from the pairdist
    kernel, then ONE [n, n] x [n, d] mixing matmul (a single memory-bound
    pass over ``x``) instead of the jnp rule's [n, q, d] gather."""
    pd = _kernel_pairdist(interpret)

    def pre(x: jnp.ndarray) -> jnp.ndarray:
        n = x.shape[0]
        q = n - f
        idx = jnp.argsort(pd(x), axis=-1)[..., :q]
        w = jnp.sum(jax.nn.one_hot(idx, n, dtype=jnp.float32), axis=-2) / q
        return (w @ x.astype(jnp.float32)).astype(x.dtype)

    return pre


def _kernel_base_rule(name: str, f: int,
                      interpret: bool) -> Optional[Aggregator]:
    """Kernel-backed version of a :data:`KERNEL_RULES` rule (``None`` for
    rules that stay pure-jnp). Each returned rule maps the per-lane
    ``[n, d]``; under the engine's vmap the stacked argument routes to the
    explicitly batched ``[B, n, d]`` kernels."""
    if name == "cwtm":
        from repro.kernels.cwtm import cwtm as cwtm_op
        fn = functools.partial(cwtm_op, f=f, use_pallas=True,
                               interpret=interpret)
        return batchable(fn, fn)
    if name == "median":
        from repro.kernels.median import median as median_op
        fn = functools.partial(median_op, use_pallas=True,
                               interpret=interpret)
        return batchable(fn, fn)
    if name in ("krum", "multikrum"):
        pd = _kernel_pairdist(interpret)

        def rule(x: jnp.ndarray) -> jnp.ndarray:
            n = x.shape[0]
            m = 1 if name == "krum" else max(1, n - f)
            q = max(1, n - f - 2)
            d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, pd(x))
            scores = jnp.sum(jnp.sort(d2, axis=-1)[..., :q], axis=-1)
            sel = jnp.argsort(scores)[:m]
            # selection as a weight vector: ONE [n] x [n, d] matvec instead
            # of gathering [m, d] rows and reducing
            w = jnp.zeros((n,), jnp.float32).at[sel].add(1.0 / m)
            return (w @ x.astype(jnp.float32)).astype(x.dtype)

        return rule
    return None


def _base_rule(name: str, f: int, geomed_iters: int = 8,
               kernel_backend: Optional[Dict[str, bool]] = None
               ) -> Aggregator:
    """The named rule without NNM composition. With ``kernel_backend``
    (see :func:`resolve_kernel_backend`), :data:`KERNEL_RULES` rules
    dispatch to the Pallas kernels; everything else keeps the jnp rule."""
    if kernel_backend is not None:
        rule = _kernel_base_rule(name, f, kernel_backend["interpret"])
        if rule is not None:
            return rule
    if name == "mean":
        return mean
    if name == "cwtm":
        return functools.partial(trimmed_mean, f=f)
    if name == "median":
        return coordinate_median
    if name == "geomed":
        return functools.partial(geometric_median, iters=geomed_iters)
    if name == "krum":
        return functools.partial(krum, f=f, m=1)
    if name == "multikrum":
        return lambda x: krum(x, f=f, m=max(1, x.shape[0] - f))
    raise ValueError(f"unknown aggregator: {name!r}")


def make_aggregator(cfg: AggregatorConfig) -> Aggregator:
    """Build an aggregator ``[n, d] -> [d]`` from a config.

    ``cfg.use_pallas`` selects the kernel backend (default: Pallas TPU
    kernels on TPU, jnp rules elsewhere — :func:`resolve_kernel_backend`).
    """
    f = cfg.f
    kb = resolve_kernel_backend(cfg.use_pallas)
    base = _base_rule(cfg.name, f, cfg.geomed_iters, kernel_backend=kb)
    if cfg.pre_nnm and cfg.name != "mean":
        pre = (_kernel_nnm(f, kb["interpret"]) if kb is not None
               else functools.partial(nnm, f=f))

        def agg(x: jnp.ndarray) -> jnp.ndarray:
            return base(pre(x))
        return agg
    return base


# --------------------------------------------------------------------------
# Switch-based aggregator bank (the one-program grid axis)
# --------------------------------------------------------------------------


BankAggregator = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def bank_index(cfg: AggregatorConfig,
               bank: Optional[Sequence[Tuple[str, bool]]] = None) -> int:
    """Branch index of ``cfg`` inside ``bank`` (default the full bank).

    ``(mean, pre_nnm=True)`` maps to the plain-mean branch, mirroring
    :func:`make_aggregator`'s NNM-skips-mean composition rule.
    """
    bank = tuple(bank) if bank is not None else DEFAULT_BANK
    entry = (cfg.name, bool(cfg.pre_nnm) and cfg.name != "mean")
    try:
        return bank.index(entry)
    except ValueError:
        raise ValueError(
            f"aggregator {entry} is not a branch of the bank {bank}") from None


def make_aggregator_bank(cfg: AggregatorConfig) -> BankAggregator:
    """Build the rank-preserving aggregator bank ``bank(x, idx) -> [d]``.

    The bank is a ``lax.switch`` over uniformly-shaped branches
    (``[n, d] -> [d]``), one per ``(rule, pre_nnm)`` combination in
    ``cfg.bank`` (default :data:`DEFAULT_BANK`), selected by the *traced*
    integer ``idx``. Because the branch choice is data, an entire
    attack x aggregator x seed grid shares ONE compiled XLA program —
    ``idx`` simply joins the vmapped fusion axis next to the linear-attack
    coefficients (see ``repro.core.sweep``).

    ``cfg.f`` and ``cfg.geomed_iters`` stay static across branches, which is
    why a fused bank requires every grid cell to share them. Note that under
    ``vmap`` a switch on per-lane indices lowers to a select over all
    branches: every lane computes every rule in the bank and keeps one. Keep
    ``cfg.bank`` restricted to the rules the grid actually uses.
    """
    entries = cfg.bank if cfg.bank is not None else DEFAULT_BANK
    f, iters = cfg.f, cfg.geomed_iters
    kb = resolve_kernel_backend(cfg.use_pallas)
    pre_nnm = (_kernel_nnm(f, kb["interpret"]) if kb is not None
               else functools.partial(nnm, f=f))

    def branch(name: str, pre: bool) -> Aggregator:
        base = _base_rule(name, f, iters, kernel_backend=kb)
        if pre and name != "mean":
            return lambda x: base(pre_nnm(x))
        return base

    branches = tuple(branch(n, p) for n, p in entries)

    def apply(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        if len(branches) == 1:
            return branches[0](x)
        return jax.lax.switch(idx, branches, x)

    return apply
