"""One-program experiment grids: plan/execute over a device-sharded fusion
axis.

The paper's empirical claims (Fig. 1, Table 1) are sweeps over attack x
aggregator x algorithm x seed grids. Dispatching ``Simulator.run`` once per
cell multiplies host-side overhead (and XLA compiles) by the grid size; here
the grid is collapsed into as few compiled programs as the scenario set
allows, in two stages:

* **plan** (:func:`plan_grid`): partition the scenarios into maximal fusible
  banks — every cell whose attack has an attack-bank branch
  (``repro.adversary.bank_entry``: the stateless mean/std family AND the
  stateful mimic/gauss/spectral/ipm_greedy adversaries) joins one bank; its
  attack-bank branch index + parameter vector, aggregator-bank branch index
  (``aggregators.make_aggregator_bank``), the *algorithm* as an
  algorithm-bank branch index + per-cell hyperparameters
  (``algorithms.make_algorithm_bank``: rosdhb/dasha/robust_dgd/dgd over a
  ``ServerState`` whose carry layout is specialised to the bank —
  ``algorithms.StateLayout`` prunes the mirror/prev_grad slots from
  dasha-free banks, beta / DASHA's ``a`` / the step size stay data) and,
  for ratio-traceable sparsifiers
  (``compression.TRACED_RATIO_KINDS``), its keep-ratio become *traced data*
  (``algorithms.ScenarioParams``). Stateful adversaries carry their memory
  (``repro.adversary.AttackState``) inside the scan like any other server
  state. What cannot fuse (``none`` attacks, singleton groups) stays a
  classic per-scenario vmapped scan. ``cross_algo=False`` restores the
  legacy one-bank-per-algorithm partition (the equivalence baseline for the
  cross-algorithm gate in benchmarks/bench_sweep.py). With a measured
  :class:`repro.core.costmodel.CostModel` the fuse-vs-partition choice per
  multi-algorithm bank is made by predicted runtime (a fused switch pays
  every branch per vmap lane; a partition pays extra compiles), so the
  chosen plan is never slower than the best static choice.
* **execute** (:func:`execute_plan` / :func:`fused_grid_rollout`): each bank
  runs as ONE compiled XLA program — ``lax.scan`` over rounds, one flat
  ``vmap`` axis of size ``n_cells * n_seeds`` — laid out over mesh devices
  with ``jax.sharding`` (``NamedSharding`` over the batch dim via
  ``repro.sharding.sweep_mesh``). The flat axis is padded to a multiple of
  the device count and pad rows are masked out of the results table. Eval
  is fused too (:func:`fused_grid_eval`): the bank's final states are
  evaluated in ONE vmapped ``eval_fn`` call over the same sharded flat
  axis, instead of one call per cell.

Early stopping is handled post-hoc from the stacked on-device metrics
(:func:`bytes_to_threshold`), matching the paper's comm-bytes-to-tau
protocol without breaking the scan.

CLI (the grid runner described in benchmarks/README.md):

    PYTHONPATH=src python -m repro.core.sweep \
        --algos rosdhb,dasha --attacks alie,foe,signflip --aggs cwtm,median \
        --seeds 4 --steps 300 --f 3 --ratio 0.1 [--no-fuse] [--no-shard]

or, via the adversarial-scenario registry (``repro.adversary.registry``:
named attack x heterogeneity x byzantine-fraction compositions):

    PYTHONPATH=src python -m repro.core.sweep --scenario mixed-attacks
    PYTHONPATH=src python -m repro.core.sweep --list-scenarios
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as S
from repro.core import aggregators as G
from repro.core import algorithms as alg
from repro.core import attacks as A
from repro.core import compression as C
from repro.core.costmodel import CostModel
from repro.core.simulator import SimState, Simulator, ensure_stacked
from repro.utils import tree as T


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One labelled grid cell: a full algorithm configuration."""

    label: str
    cfg: alg.AlgorithmConfig


#: Algorithms the grid runner knows how to build (= the algorithm bank's
#: branch set).
KNOWN_ALGORITHMS: Tuple[str, ...] = alg.ALGO_BANK


def _validate_grid_names(algos: Sequence[str], attacks: Sequence[str],
                         aggregators: Sequence[str]) -> None:
    """Fail fast on unknown names, listing everything known — mirrors the
    ``kappa_bound`` ValueError contract instead of erroring deep inside
    ``plan_grid``/tracing."""
    from repro.adversary import core as adv  # local: core <-> adversary cycle
    for a in algos:
        if a not in KNOWN_ALGORITHMS:
            raise ValueError(
                f"unknown algorithm: {a!r} (expected one of "
                f"{'|'.join(KNOWN_ALGORITHMS)})")
    for a in attacks:
        if a not in adv.KNOWN_ATTACKS:
            raise ValueError(
                f"unknown attack: {a!r} (expected one of "
                f"{'|'.join(adv.KNOWN_ATTACKS)})")
    for a in aggregators:
        if a not in G.BANK_NAMES:
            raise ValueError(
                f"unknown aggregator: {a!r} (expected one of "
                f"{'|'.join(G.BANK_NAMES)})")


def grid_scenarios(algos: Sequence[str] = ("rosdhb",),
                   attacks: Sequence[str] = ("alie",),
                   aggregators: Sequence[str] = ("cwtm",),
                   *, n_honest: int = 10, f: int = 3, ratio: float = 0.1,
                   gamma: float = 0.05, beta: float = 0.9,
                   pre_nnm: bool = True, local: bool = False,
                   alie_z: Optional[float] = 1.5,
                   use_pallas: Optional[bool] = None) -> List[Scenario]:
    """Enumerate the attack x aggregator x algorithm product into scenarios.

    ``f`` is fixed across the grid so every scenario shares the worker count
    (and therefore one stacked batch pytree). ``dgd`` pairs with plain mean
    (its defining non-robust corner) regardless of ``aggregators``. The
    sparsifier config is shared by every algorithm so the whole
    algo x attack x aggregator product fuses into ONE cross-algorithm bank
    (``robust_dgd``'s update rule ignores it — it transmits raw gradients,
    and :func:`repro.core.algorithms.algo_payload_bytes` accounts for that
    wire format). Unknown algorithm/attack/aggregator names raise
    ``ValueError`` listing the known names.

    ``use_pallas`` selects the aggregation backend for every cell (None:
    Pallas TPU kernels on TPU, jnp rules elsewhere — see
    :func:`repro.core.aggregators.resolve_kernel_backend`). It rides the
    shared aggregator config, so it is part of plan_grid's fusion key:
    grids with different backends never fuse into one program.
    """
    _validate_grid_names(algos, attacks, aggregators)
    out = []
    seen_labels = set()
    sparsifier = C.SparsifierConfig(kind="randk", ratio=ratio, local=local)
    for algo, attack, agg in itertools.product(algos, attacks, aggregators):
        # dgd's mean carries the grid's f so its (inert) aggregator config
        # stays key-compatible with the robust cells' bank branches
        aggregator = (G.AggregatorConfig(name="mean", f=max(f, 1),
                                         use_pallas=use_pallas)
                      if algo == "dgd"
                      else G.AggregatorConfig(name=agg, f=max(f, 1),
                                              pre_nnm=pre_nnm,
                                              use_pallas=use_pallas))
        cfg = alg.AlgorithmConfig(
            name=algo, n_workers=n_honest + f, f=f, gamma=gamma, beta=beta,
            sparsifier=sparsifier, aggregator=aggregator,
            attack=A.AttackConfig(name=attack,
                                  z=alie_z if attack == "alie" else None))
        label = f"{algo}/{attack}/{aggregator.name}"
        # dgd collapses every aggregator to mean, so multi-aggregator grids
        # would repeat the identical dgd cell once per rule — emit it once
        # (duplicate labels are a hard error in plan_grid: they key rows)
        if label in seen_labels:
            continue
        seen_labels.add(label)
        out.append(Scenario(label=label, cfg=cfg))
    return out


def init_states(sim: Simulator, seeds: Sequence[int]) -> SimState:
    """Stack per-seed initial states on a leading seed axis."""
    if not len(seeds):
        raise ValueError("seeds must be non-empty")
    states = [sim.init(int(s)) for s in seeds]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def rollout_over_seeds(sim: Simulator, seeds: Sequence[int], batches: Any,
                       steps: Optional[int] = None
                       ) -> Tuple[SimState, dict]:
    """Run all seeds of one scenario in a single vmapped scan.

    ``batches`` (a stacked pytree or a ``batch_fn``) is shared across seeds —
    seed variation enters through the per-seed PRNG state (mask sampling and
    stochastic attacks), matching sequential ``Simulator.rollout`` calls with
    ``sim.init(seed)``.

    Returns ``(final_states, metrics)`` with a leading seed axis on every
    leaf (metrics are ``[n_seeds, steps]``).
    """
    batches = ensure_stacked(batches, steps)
    if "seed_vmap" not in sim._sweep_cache:
        sim._sweep_cache["seed_vmap"] = jax.jit(
            jax.vmap(sim._scan, in_axes=(0, None)))
    return sim._sweep_cache["seed_vmap"](init_states(sim, seeds), batches)


def fused_grid_rollout(sim: Simulator, params: alg.ScenarioParams,
                       seeds: Sequence[int], batches: Any,
                       steps: Optional[int] = None, *,
                       shard: bool = True,
                       devices: Optional[Sequence[Any]] = None
                       ) -> Tuple[SimState, dict]:
    """Run a whole cells x seeds grid as ONE compiled, device-sharded program.

    ``params`` is a traced :class:`repro.core.algorithms.ScenarioParams`
    whose present components carry a leading ``[n_cells]`` axis (attack
    coefficients / aggregator-bank indices / keep-ratios). The grid is
    flattened to one ``[n_cells * n_seeds]`` vmap axis (a nested
    vmap-of-vmap compiles ~2.5x slower for the same program) and, when
    ``shard`` is set and >1 devices are visible, laid out over a 1-D
    ``grid`` mesh with ``NamedSharding`` — padded to a device-count multiple
    with repeated tail rows that are sliced off again before returning.

    Returns ``(final_states, metrics)`` with leading ``[n_cells, n_seeds]``
    axes on every leaf.
    """
    batches = ensure_stacked(batches, steps)
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("ScenarioParams has no traced components to fuse")
    if any(getattr(l, "ndim", 0) == 0 for l in leaves):
        raise ValueError("every ScenarioParams component needs a leading "
                         "[n_cells] axis (got a scalar)")
    lead = [l.shape[0] for l in leaves]
    if len(set(lead)) != 1:
        raise ValueError(f"inconsistent ScenarioParams cell axes: {lead}")
    n_c, n_s = lead[0], len(seeds)
    states = init_states(sim, seeds)
    # flat fusion axis, cell-major: row c * n_s + s = (cell c, seed s)
    states_flat = jax.tree_util.tree_map(
        lambda l: jnp.tile(l, (n_c,) + (1,) * (l.ndim - 1)), states)
    params_flat = jax.tree_util.tree_map(
        lambda l: jnp.repeat(l, n_s, axis=0), params)
    n_rows = n_c * n_s
    mesh = S.sweep_mesh(devices) if shard else None
    if mesh is not None and mesh.size > 1:
        pad = (-n_rows) % mesh.size
        if pad:
            pad_rows = lambda l: jnp.concatenate(  # noqa: E731
                [l, jnp.repeat(l[-1:], pad, axis=0)], axis=0)
            states_flat = jax.tree_util.tree_map(pad_rows, states_flat)
            params_flat = jax.tree_util.tree_map(pad_rows, params_flat)
        states_flat = jax.device_put(states_flat, S.grid_sharding(mesh))
        params_flat = jax.device_put(params_flat, S.grid_sharding(mesh))
        batches = jax.device_put(batches, S.replicated_sharding(mesh))
    if "grid_vmap" not in sim._sweep_cache:
        sim._sweep_cache["grid_vmap"] = jax.jit(
            jax.vmap(sim._scan, in_axes=(0, None, None, 0)))
    out_states, out_metrics = sim._sweep_cache["grid_vmap"](
        states_flat, batches, None, params_flat)
    # mask pad rows out, restore the [n_cells, n_seeds] grid axes
    unflatten = lambda l: l[:n_rows].reshape(  # noqa: E731
        (n_c, n_s) + l.shape[1:])
    return (jax.tree_util.tree_map(unflatten, out_states),
            jax.tree_util.tree_map(unflatten, out_metrics))


def _chunk_source(batches: Any, steps: Optional[int], chunk_size: int,
                  prefetch_depth: int, device: Optional[Any] = None):
    """Build the chunk source for a streaming sweep: a prefetch thread for
    ``batch_fn`` callables, a slice-and-device-put source for pre-stacked
    pytrees. Returns ``(source, steps)``."""
    from repro.data import stream as DS
    if callable(batches):
        if steps is None:
            raise ValueError("steps is required when batches is callable")
        return (DS.ChunkPrefetcher(batches, steps, chunk_size,
                                   prefetch_depth, device=device), steps)
    n_avail = jax.tree_util.tree_leaves(batches)[0].shape[0]
    steps = n_avail if steps is None else min(steps, n_avail)
    return (DS.StackedChunkSource(batches, steps, chunk_size,
                                  device=device), steps)


def _drive_stream_lanes(sim: Simulator, prog: Callable, states_flat: Any,
                        params_flat: Optional[Any], source: Any,
                        chunk_size: int, prefetch_depth: int
                        ) -> Tuple[Any, Dict[str, jnp.ndarray], Dict[str,
                                                                     Any]]:
    """Host loop of a streaming sweep: feed ``prefetch_depth``-deep device
    buffers through the vmapped while-loop program until the chunk source is
    exhausted. No early exit (sweep tables need full-length trajectories:
    ``bytes_to_threshold`` stays the post-hoc protocol), so every lane runs
    exactly ``n_valid`` chunks per dispatch."""
    n_rows = jax.tree_util.tree_leaves(states_flat)[0].shape[0]
    tau = jnp.float32(-jnp.inf)  # '<=' sentinel: never crossed
    eval_in = jnp.zeros((), jnp.float32)
    metrics_parts: List[Dict[str, np.ndarray]] = []
    dispatches = 0
    metrics0 = None
    state = states_flat
    try:
        while True:
            chunks = source.take(prefetch_depth)
            if not chunks:
                break
            n_valid = len(chunks)
            buf = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *chunks)
            if n_valid < prefetch_depth:
                buf = jax.tree_util.tree_map(
                    lambda l: jnp.concatenate(
                        [l] + [l[-1:]] * (prefetch_depth - n_valid), axis=0),
                    buf)
            if metrics0 is None:
                one_state = jax.tree_util.tree_map(lambda l: l[0],
                                                   states_flat)
                one_batch = jax.tree_util.tree_map(lambda l: l[0, 0], buf)
                one_scenario = (jax.tree_util.tree_map(lambda l: l[0],
                                                       params_flat)
                                if params_flat is not None else None)
                struct = sim._metric_struct(one_state, one_batch,
                                            one_scenario)
                metrics0 = {
                    k: jnp.zeros((n_rows, prefetch_depth * chunk_size),
                                 v.dtype) for k, v in struct.items()}
            args = (state, buf, n_valid, tau, eval_in, metrics0)
            if params_flat is not None:
                args = args + (params_flat,)
            state, bufs, i_done, done, last = prog(*args)
            dispatches += 1
            rounds = n_valid * chunk_size
            metrics_parts.append(
                {k: np.asarray(v[:, :rounds]) for k, v in bufs.items()})
    finally:
        if hasattr(source, "close"):
            source.close()
    metrics = ({k: jnp.asarray(np.concatenate([p[k] for p in metrics_parts],
                                              axis=1))
                for k in metrics_parts[0]} if metrics_parts else {})
    info = {
        "dispatches": dispatches,
        "chunk_size": chunk_size,
        "prefetch_depth": prefetch_depth,
        "chunk_bytes": getattr(source, "chunk_bytes", 0),
        "host_high_water_bytes": getattr(source, "high_water_bytes", 0),
    }
    return state, metrics, info


def rollout_over_seeds_streaming(sim: Simulator, seeds: Sequence[int],
                                 batches: Any, steps: Optional[int] = None,
                                 *, chunk_size: int = 32,
                                 prefetch_depth: int = 4
                                 ) -> Tuple[SimState, dict]:
    """Streaming counterpart of :func:`rollout_over_seeds`: the same
    vmap-over-seeds program, but fed from a prefetched ring buffer chunk by
    chunk instead of one O(steps) stacked array — bit-for-bit identical
    trajectories (the chunk scan embeds the identical round body).

    The ``steps % chunk_size`` tail runs through the fixed-length
    ``seed_vmap`` program (shared cache with :func:`rollout_over_seeds`).
    """
    source, steps = _chunk_source(batches, steps, chunk_size, prefetch_depth)
    n_chunks = steps // chunk_size
    remainder = steps % chunk_size
    states = init_states(sim, seeds)
    key = ("stream_seed_vmap", chunk_size)
    if key not in sim._sweep_cache:
        raw = sim._stream_raw(chunk_size, "loss", "<=", False)
        sim._sweep_cache[key] = jax.jit(
            jax.vmap(raw, in_axes=(0, None, None, None, None, 0)))
    state, metrics, _ = _drive_stream_lanes(
        sim, sim._sweep_cache[key], states, None, source, chunk_size,
        prefetch_depth)
    if remainder:
        from repro.core.simulator import stack_batches
        tail = (stack_batches(batches, remainder, start=n_chunks * chunk_size)
                if callable(batches) else
                jax.tree_util.tree_map(
                    lambda l: l[n_chunks * chunk_size:steps], batches))
        if "seed_vmap" not in sim._sweep_cache:
            sim._sweep_cache["seed_vmap"] = jax.jit(
                jax.vmap(sim._scan, in_axes=(0, None)))
        state, tail_ms = sim._sweep_cache["seed_vmap"](state, tail)
        metrics = {k: jnp.concatenate([metrics[k], tail_ms[k]], axis=1)
                   for k in metrics} if metrics else tail_ms
    return state, metrics


def fused_grid_rollout_streaming(sim: Simulator,
                                 params: alg.ScenarioParams,
                                 seeds: Sequence[int], batches: Any,
                                 steps: Optional[int] = None, *,
                                 chunk_size: int = 32,
                                 prefetch_depth: int = 4,
                                 shard: bool = True,
                                 devices: Optional[Sequence[Any]] = None
                                 ) -> Tuple[SimState, dict]:
    """Streaming counterpart of :func:`fused_grid_rollout`: the bank's flat
    ``[n_cells * n_seeds]`` fusion axis (same tiling / padding / mesh
    sharding) consumes chunks from a prefetched ring buffer inside the
    while-loop-of-scan-chunks program, so the host never materialises the
    ``[steps, ...]`` batch schedule. Trajectories are bit-for-bit the
    :func:`fused_grid_rollout` ones (identical round body, identical lane
    layout); only the input residency changes.

    Returns ``(final_states, metrics)`` with leading ``[n_cells, n_seeds]``
    axes, metrics ``[n_cells, n_seeds, steps]``.
    """
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        raise ValueError("ScenarioParams has no traced components to fuse")
    lead = [l.shape[0] for l in leaves]
    if len(set(lead)) != 1:
        raise ValueError(f"inconsistent ScenarioParams cell axes: {lead}")
    n_c, n_s = lead[0], len(seeds)
    states = init_states(sim, seeds)
    states_flat = jax.tree_util.tree_map(
        lambda l: jnp.tile(l, (n_c,) + (1,) * (l.ndim - 1)), states)
    params_flat = jax.tree_util.tree_map(
        lambda l: jnp.repeat(l, n_s, axis=0), params)
    n_rows = n_c * n_s
    mesh = S.sweep_mesh(devices) if shard else None
    chunk_device = None
    if mesh is not None and mesh.size > 1:
        pad = (-n_rows) % mesh.size
        if pad:
            pad_rows = lambda l: jnp.concatenate(  # noqa: E731
                [l, jnp.repeat(l[-1:], pad, axis=0)], axis=0)
            states_flat = jax.tree_util.tree_map(pad_rows, states_flat)
            params_flat = jax.tree_util.tree_map(pad_rows, params_flat)
        states_flat = jax.device_put(states_flat, S.grid_sharding(mesh))
        params_flat = jax.device_put(params_flat, S.grid_sharding(mesh))
        chunk_device = S.replicated_sharding(mesh)
    source, steps = _chunk_source(batches, steps, chunk_size, prefetch_depth,
                                  device=chunk_device)
    n_chunks = steps // chunk_size
    remainder = steps % chunk_size
    key = ("stream_grid_vmap", chunk_size)
    if key not in sim._sweep_cache:
        raw = sim._stream_raw(chunk_size, "loss", "<=", False)
        sim._sweep_cache[key] = jax.jit(
            jax.vmap(raw, in_axes=(0, None, None, None, None, 0, 0)))
    state, metrics, _ = _drive_stream_lanes(
        sim, sim._sweep_cache[key], states_flat, params_flat, source,
        chunk_size, prefetch_depth)
    if remainder:
        from repro.core.simulator import stack_batches
        tail = (stack_batches(batches, remainder, start=n_chunks * chunk_size)
                if callable(batches) else
                jax.tree_util.tree_map(
                    lambda l: l[n_chunks * chunk_size:steps], batches))
        if chunk_device is not None:
            tail = jax.device_put(tail, chunk_device)
        if "grid_vmap" not in sim._sweep_cache:
            sim._sweep_cache["grid_vmap"] = jax.jit(
                jax.vmap(sim._scan, in_axes=(0, None, None, 0)))
        state, tail_ms = sim._sweep_cache["grid_vmap"](
            state, tail, None, params_flat)
        metrics = {k: jnp.concatenate([metrics[k], tail_ms[k]], axis=1)
                   for k in metrics} if metrics else tail_ms
    unflatten = lambda l: l[:n_rows].reshape(  # noqa: E731
        (n_c, n_s) + l.shape[1:])
    return (jax.tree_util.tree_map(unflatten, state),
            jax.tree_util.tree_map(unflatten, metrics))


def fused_attack_rollout(sim: Simulator,
                         attack_cfgs: Sequence[A.AttackConfig],
                         seeds: Sequence[int], batches: Any,
                         steps: Optional[int] = None
                         ) -> Tuple[SimState, dict]:
    """Run a whole attacks x seeds grid as ONE compiled XLA program.

    Every attack must belong to the mean/std linear family
    (:func:`repro.core.attacks.linear_coeffs` — alie/signflip/ipm/foe/zero):
    their coefficients become a traced ``[n_attacks, 2]`` input vmapped over,
    so the grid pays a single compile instead of one per attack. ``sim`` must
    be built with ``attack=AttackConfig(name="linear")``. This is the
    attack-only corner of :func:`fused_grid_rollout` (unsharded, for
    backward compatibility).

    Returns ``(final_states, metrics)`` with leading ``[n_attacks, n_seeds]``
    axes on every leaf.
    """
    assert sim.cfg.attack.name == "linear", sim.cfg.attack
    n, f = sim.cfg.n_workers, sim.cfg.f
    coeffs = []
    for a in attack_cfgs:
        c = A.linear_coeffs(a, n, f)
        if c is None:
            raise ValueError(f"attack {a.name!r} is outside the linear "
                             "family; run it as its own scenario")
        coeffs.append(c)
    params = alg.ScenarioParams(
        attack_coeffs=jnp.asarray(coeffs, jnp.float32))
    return fused_grid_rollout(sim, params, seeds, batches, steps,
                              shard=False)


# --------------------------------------------------------------------------
# Plan: partition a scenario grid into maximal fusible banks
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedBank:
    """One maximal fusible group: ``n_cells`` scenarios sharing ONE compiled
    program, their differences carried as traced :class:`ScenarioParams`.

    ``cfg`` is the executable bank configuration: ``attack='bank'`` (the
    switch-based attack bank of ``repro.adversary`` — its branch set
    restricted to the adversaries the group actually uses, stateless linear
    family and stateful attacks alike) and ``aggregator.name='bank'`` with
    the rule set restricted likewise (under vmap a switch computes every
    branch per lane, so smaller banks are cheaper). Cross-algorithm banks
    additionally set ``cfg.name='bank'`` (``algorithms.make_algorithm_bank``
    restricted to the algorithms the group uses) and carry per-cell
    ``algo_idx`` / ``hparams`` (beta, DASHA's ``a``) / ``gammas`` as traced
    data; per-algorithm banks (``plan_grid(cross_algo=False)``) leave those
    ``None`` and keep the legacy static-config path.
    """

    cfg: alg.AlgorithmConfig
    scenarios: Tuple[Scenario, ...]
    coeffs: Tuple[Tuple[float, float], ...]
    attack_idx: Tuple[int, ...]
    agg_idx: Tuple[int, ...]
    ratios: Optional[Tuple[float, ...]]  # None -> ratio stays static config
    algo_idx: Optional[Tuple[int, ...]] = None
    #: per-cell (beta, mvr_a, 1-beta, 1-mvr_a) — see algorithms.static_hparams
    hparams: Optional[Tuple[Tuple[float, float, float, float], ...]] = None
    gammas: Optional[Tuple[float, ...]] = None

    @property
    def n_cells(self) -> int:
        return len(self.scenarios)

    def scenario_params(self) -> alg.ScenarioParams:
        """Stack the per-cell traced parameters on a leading cell axis."""
        return alg.ScenarioParams(
            attack_coeffs=jnp.asarray(self.coeffs, jnp.float32),
            attack_idx=jnp.asarray(self.attack_idx, jnp.int32),
            agg_idx=jnp.asarray(self.agg_idx, jnp.int32),
            ratio=(jnp.asarray(self.ratios, jnp.float32)
                   if self.ratios is not None else None),
            algo_idx=(jnp.asarray(self.algo_idx, jnp.int32)
                      if self.algo_idx is not None else None),
            hparams=(jnp.asarray(self.hparams, jnp.float32)
                     if self.hparams is not None else None),
            gamma=(jnp.asarray(self.gammas, jnp.float32)
                   if self.gammas is not None else None))


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Execution plan for a scenario grid: fusible banks + leftovers.

    ``banks`` each compile once for all their cells x seeds;
    ``singles`` (non-linear attacks, singleton groups) each pay one
    classic vmapped-scan compile over seeds.
    """

    banks: Tuple[FusedBank, ...]
    singles: Tuple[Scenario, ...]
    #: human-readable plan decisions (cost-model fuse/partition verdicts)
    notes: Tuple[str, ...] = ()

    @property
    def n_cells(self) -> int:
        return sum(b.n_cells for b in self.banks) + len(self.singles)

    @property
    def n_programs(self) -> int:
        return len(self.banks) + len(self.singles)

    def describe(self) -> str:
        parts = [f"{self.n_cells} scenarios -> {self.n_programs} programs"]
        for b in self.banks:
            name = ("+".join(b.cfg.bank or alg.ALGO_BANK)
                    if b.cfg.name == "bank" else b.cfg.name)
            layout = b.cfg.resolved_state_layout()
            parts.append(
                f"  bank[{name}] x{b.n_cells}"
                + ("" if layout.is_full else " [pruned carry]") + ": "
                + ", ".join(sc.label for sc in b.scenarios))
        for sc in self.singles:
            parts.append(f"  single: {sc.label}")
        for note in self.notes:
            parts.append(f"  note: {note}")
        return "\n".join(parts)


_GroupEntry = Tuple[Scenario, Tuple[str, Tuple[float, float]]]


def _build_bank(group: Sequence[_GroupEntry], *,
                cross_algo: bool) -> FusedBank:
    """Assemble one :class:`FusedBank` from grouped (scenario, attack-entry)
    pairs that already share a fusion key.

    The bank's carry layout is part of the plan: dasha-free groups get the
    pruned :class:`repro.core.algorithms.StateLayout` (no ``mirror`` /
    ``prev_grad`` slots in the scanned ``ServerState``), groups with a dasha
    branch keep the full width. An explicit per-scenario ``state_layout``
    (shared across the group — it is part of the fusion key) wins over the
    inferred one.
    """
    entries: List[Tuple[str, bool]] = []
    attack_entries: List[str] = []
    algos: List[str] = []
    for sc, (branch, _) in group:
        a = sc.cfg.aggregator
        e = (a.name, bool(a.pre_nnm) and a.name != "mean")
        if e not in entries:
            entries.append(e)
        if branch not in attack_entries:
            attack_entries.append(branch)
        if sc.cfg.name not in algos:
            algos.append(sc.cfg.name)
    bank_agg = dataclasses.replace(
        group[0][0].cfg.aggregator, name="bank", pre_nnm=False,
        bank=tuple(entries))
    bank_attack = A.AttackConfig(name="bank", bank=tuple(attack_entries))
    ratios = tuple(sc.cfg.sparsifier.ratio for sc, _ in group)
    trace_ratio = (group[0][0].cfg.sparsifier.kind
                   in C.TRACED_RATIO_KINDS and len(set(ratios)) > 1)
    exec_cfg = dataclasses.replace(
        group[0][0].cfg, attack=bank_attack, aggregator=bank_agg)
    if cross_algo:
        exec_cfg = dataclasses.replace(exec_cfg, name="bank",
                                       bank=tuple(algos))
    if exec_cfg.state_layout is None:
        exec_cfg = dataclasses.replace(
            exec_cfg,
            state_layout=alg.StateLayout.for_algorithms(
                exec_cfg.algorithms()))
    return FusedBank(
        cfg=exec_cfg,
        scenarios=tuple(sc for sc, _ in group),
        coeffs=tuple(c for _, (_, c) in group),
        attack_idx=tuple(attack_entries.index(b) for _, (b, _) in group),
        agg_idx=tuple(G.bank_index(sc.cfg.aggregator, tuple(entries))
                      for sc, _ in group),
        ratios=ratios if trace_ratio else None,
        algo_idx=(tuple(algos.index(sc.cfg.name) for sc, _ in group)
                  if cross_algo else None),
        hparams=(tuple(alg.static_hparams(sc.cfg) for sc, _ in group)
                 if cross_algo else None),
        gammas=(tuple(sc.cfg.gamma for sc, _ in group)
                if cross_algo else None))


def plan_grid(scenarios: Sequence[Scenario], *,
              fuse: bool = True, cross_algo: bool = True,
              cost_model: Optional[CostModel] = None,
              rounds: Optional[int] = None,
              n_seeds: int = 1, sharded: bool = False) -> GridPlan:
    """Partition ``scenarios`` into maximal fusible banks.

    Cells fuse when they share every static field of their config and
    differ only along traced axes: the attack — stateless mean/std family
    *and* stateful adversaries (mimic/gauss/spectral/ipm_greedy) alike, as
    an attack-bank branch index + parameter vector
    (``repro.adversary.bank_entry``) — the aggregator rule +/- NNM (bank
    branch index), the **algorithm** (algorithm-bank branch index with
    per-cell beta / DASHA ``a`` / step-size hyperparameters as traced
    data), and, for :data:`repro.core.compression.TRACED_RATIO_KINDS`
    sparsifiers, the keep-ratio. The aggregator's ``f``/``geomed_iters``,
    the worker counts, dtypes, and the sparsifier (up to a traceable ratio)
    must match — they are baked into the compiled branches. Groups of one
    and non-bankable attacks (``none``) fall back to per-scenario programs.

    Every bank carries its :class:`repro.core.algorithms.StateLayout` in
    ``cfg.state_layout``: dasha-free banks scan the pruned ``ServerState``
    (no mirror/prev_grad slots — the PR-4 fused path charged every cell
    DASHA's state width), mixed banks keep the full layout.

    With a :class:`repro.core.costmodel.CostModel` (plus the grid's
    ``rounds`` and ``n_seeds``), each multi-algorithm candidate bank is
    kept fused only when the model predicts the fused ``lax.switch``
    program (every branch computed per vmap lane) beats the per-algorithm
    partition's extra compiles; otherwise the group splits into
    single-algorithm banks (still attack/agg/ratio-fused). Decisions are
    recorded in ``GridPlan.notes``. ``sharded`` tells the model the grid
    will compile mesh-sharded (adds the measured
    ``sharded_compile_overhead_s`` to every compile term — see
    ``benchmarks/bench_sweep.py``'s ``_sharded_grid``).

    ``cross_algo=False`` keeps the algorithm (and its beta/``a``/gamma) a
    static config axis — the legacy one-bank-per-algorithm partition, kept
    as the equivalence baseline for the cross-algorithm compile-count gate.

    Duplicate scenario labels raise ``ValueError``: labels are the stable
    row key of :func:`execute_plan` / :func:`run_scenarios`.
    """
    from repro.adversary import core as adv  # local: core <-> adversary cycle
    label_counts = collections.Counter(sc.label for sc in scenarios)
    dupes = sorted(l for l, c in label_counts.items() if c > 1)
    if dupes:
        raise ValueError(
            f"duplicate scenario labels {dupes}: labels key the results "
            "table — give repeated cells distinct labels")
    if cost_model is not None and rounds is None:
        raise ValueError("plan_grid(cost_model=...) needs rounds= (the scan "
                         "length) to predict per-bank runtime")
    singles: List[Scenario] = []
    notes: List[str] = []
    if not fuse:
        return GridPlan(banks=(), singles=tuple(scenarios))
    groups: Dict[alg.AlgorithmConfig, List[_GroupEntry]] = {}
    for sc in scenarios:
        cfg = sc.cfg
        entry = adv.bank_entry(cfg.attack, cfg.n_workers, cfg.f)
        if entry is None:
            singles.append(sc)
            continue
        sp = cfg.sparsifier
        key = dataclasses.replace(
            cfg,
            attack=A.AttackConfig(name="bank"),
            aggregator=dataclasses.replace(cfg.aggregator, name="bank",
                                           pre_nnm=False, bank=None),
            sparsifier=(dataclasses.replace(sp, ratio=1.0)
                        if sp.kind in C.TRACED_RATIO_KINDS else sp))
        if cross_algo:
            # the algorithm and its per-cell hyperparameters become traced
            # data (algo_idx / hparams / gamma), so normalise them out of
            # the grouping key; resolved_beta() is evaluated per cell below
            key = dataclasses.replace(
                key, name="bank", bank=None, beta=0.0, smoothness_L=1.0,
                mvr_a=None, gamma=0.0)
        groups.setdefault(key, []).append((sc, entry))

    banks: List[FusedBank] = []
    for key, group in groups.items():
        if len(group) == 1:
            singles.append(group[0][0])
            continue
        cells = collections.Counter(sc.cfg.name for sc, _ in group)
        if cross_algo and cost_model is not None and len(cells) > 1:
            fused_s = cost_model.fused_s(dict(cells), n_seeds, rounds,
                                         sharded=sharded)
            part_s = cost_model.partitioned_s(dict(cells), n_seeds, rounds,
                                              sharded=sharded)
            verdict = "fused" if fused_s <= part_s else "partitioned"
            notes.append(
                f"cost-model[{cost_model.source}] {verdict} "
                f"{'+'.join(sorted(cells))} x{len(group)} cells x{n_seeds} "
                f"seeds x{rounds} rounds: fused {fused_s:.1f}s vs "
                f"partitioned {part_s:.1f}s")
            if fused_s > part_s:
                # split by algorithm; each part keeps its attack/agg/ratio
                # fusion (a 1-entry algorithm bank is pinned bit-for-bit
                # equal to the legacy static-config bank)
                for algo in cells:
                    sub = [g for g in group if g[0].cfg.name == algo]
                    if len(sub) == 1:
                        singles.append(sub[0][0])
                    else:
                        banks.append(_build_bank(sub, cross_algo=True))
                continue
        banks.append(_build_bank(group, cross_algo=cross_algo))
    return GridPlan(banks=tuple(banks), singles=tuple(singles),
                    notes=tuple(notes))


def eval_over_seeds(sim: Simulator, states: SimState,
                    eval_batch: Any) -> Dict[str, jnp.ndarray]:
    """vmap ``sim.eval_fn`` over the seed axis of stacked final states."""
    assert sim.eval_fn is not None, "Simulator has no eval_fn"
    if "eval_vmap" not in sim._sweep_cache:
        def one(flat, batch):
            return sim.eval_fn(T.tree_unravel(flat, sim.spec), batch)

        sim._sweep_cache["eval_vmap"] = jax.jit(
            jax.vmap(one, in_axes=(0, None)))
    return sim._sweep_cache["eval_vmap"](states.params_flat, eval_batch)


def fused_grid_eval(sim: Simulator, states: SimState, eval_batch: Any, *,
                    shard: bool = True,
                    devices: Optional[Sequence[Any]] = None
                    ) -> Dict[str, jnp.ndarray]:
    """Evaluate a whole bank's final states as ONE vmapped, sharded program.

    ``states`` is the :func:`fused_grid_rollout` output with leading
    ``[n_cells, n_seeds]`` axes; the eval is one ``vmap(eval_fn)`` call over
    the re-flattened ``[n_cells * n_seeds]`` axis, laid out over the same
    ``sweep_mesh`` device layout as the rollout (pad rows repeated and
    sliced back off). Replaces the legacy one-``eval_over_seeds``-per-cell
    loop, so eval of a 100-cell bank is also one compiled program.

    Returns a metrics dict with leading ``[n_cells, n_seeds]`` axes.
    """
    assert sim.eval_fn is not None, "Simulator has no eval_fn"
    flat = states.params_flat
    if flat.ndim < 3:
        raise ValueError(
            "fused_grid_eval expects fused_grid_rollout output with leading "
            f"[n_cells, n_seeds] axes, got params_flat shape {flat.shape}")
    n_c, n_s = flat.shape[:2]
    n_rows = n_c * n_s
    rows = flat.reshape((n_rows,) + flat.shape[2:])
    mesh = S.sweep_mesh(devices) if shard else None
    if mesh is not None and mesh.size > 1:
        pad = (-n_rows) % mesh.size
        if pad:
            rows = jnp.concatenate(
                [rows, jnp.repeat(rows[-1:], pad, axis=0)], axis=0)
        rows = jax.device_put(rows, S.grid_sharding(mesh))
        eval_batch = jax.device_put(eval_batch, S.replicated_sharding(mesh))
    if "grid_eval" not in sim._sweep_cache:
        def one(flat_p, batch):
            return sim.eval_fn(T.tree_unravel(flat_p, sim.spec), batch)

        sim._sweep_cache["grid_eval"] = jax.jit(
            jax.vmap(one, in_axes=(0, None)))
    out = sim._sweep_cache["grid_eval"](rows, eval_batch)
    unflatten = lambda l: l[:n_rows].reshape(  # noqa: E731
        (n_c, n_s) + l.shape[1:])
    return jax.tree_util.tree_map(unflatten, out)


def bytes_to_threshold(values: np.ndarray, per_round_bytes: int,
                       threshold: float, mode: str = "<=") -> np.ndarray:
    """Post-hoc early stopping: uplink bytes until ``values`` first crosses
    ``threshold`` (``inf`` where it never does).

    ``values`` is a per-round metric trajectory whose LAST axis is the round
    axis; any number of leading batch axes is preserved — ``[steps]``,
    ``[n_seeds, steps]``, the fused ``[n_attacks, n_seeds, steps]`` grid
    output, etc. Rounds are 1-indexed for byte accounting, matching the
    legacy ``stop_fn`` protocol.
    """
    if mode not in ("<=", ">="):
        raise ValueError(f"mode must be '<=' or '>=', got {mode!r}")
    v = np.asarray(values)
    if v.ndim == 0:
        raise ValueError("values must have a trailing round axis")
    flat = v.reshape((-1, v.shape[-1]))
    hit = (flat <= threshold) if mode == "<=" else (flat >= threshold)
    any_hit = hit.any(axis=1)
    first = np.where(any_hit, hit.argmax(axis=1), 0)
    out = np.where(any_hit, (first + 1.0) * per_round_bytes, np.inf)
    return out[0] if v.ndim == 1 else out.reshape(v.shape[:-1])


def _result_rows(sc: Scenario, sim: Simulator, seeds: Sequence[int],
                 loss: np.ndarray, emet: Dict[str, Any],
                 n_steps: int) -> List[Dict[str, Any]]:
    # byte accounting from the CELL's own config AND algorithm: inside a
    # bank the executing sim's static config is not this cell's, and each
    # algorithm has its own wire format (dasha's compressed differences
    # carry indices, robust_dgd sends raw gradients — algo_payload_bytes)
    per_round = alg.algo_payload_bytes(sc.cfg, sim.d) * sc.cfg.n_workers
    total_bytes = per_round * n_steps
    rows = []
    for i, seed in enumerate(seeds):
        row = {
            "scenario": sc.label,
            "algo": sc.cfg.name,
            "attack": sc.cfg.attack.name,
            "aggregator": sc.cfg.aggregator.name,
            # robust_dgd ignores the (grid-shared) sparsifier — report its
            # effective no-compression ratio, not the config's
            "ratio": (1.0 if sc.cfg.name == "robust_dgd"
                      else sc.cfg.sparsifier.ratio),
            "f": sc.cfg.f,
            "seed": int(seed),
            "final_loss": float(loss[i, -1]),
            "min_loss": float(loss[i].min()),
            "comm_bytes": total_bytes,
        }
        row.update({k: float(v[i]) for k, v in emet.items()})
        rows.append(row)
    return rows


def execute_plan(plan: GridPlan, *,
                 loss_fn: Callable[[Any, Any], jnp.ndarray],
                 params0: Any, batches: Any, seeds: Sequence[int],
                 steps: Optional[int] = None,
                 eval_fn: Optional[Callable[[Any, Any], Dict]] = None,
                 eval_batch: Any = None,
                 shard: bool = True,
                 devices: Optional[Sequence[Any]] = None,
                 sim_cache: Optional[Dict[alg.AlgorithmConfig,
                                          Simulator]] = None,
                 streaming: bool = False,
                 stream_chunk_size: int = 32,
                 prefetch_depth: int = 4
                 ) -> Dict[str, List[Dict[str, Any]]]:
    """Execute a :class:`GridPlan`; return rows keyed by scenario label.

    Each bank is one compiled program over its flat cells x seeds axis,
    sharded across ``devices`` when ``shard`` is set
    (:func:`fused_grid_rollout`), and its eval is one vmapped program over
    the same sharded axis (:func:`fused_grid_eval`); singles run as
    per-scenario vmapped scans.

    Simulators are shared across cells with identical static config —
    ``jax.jit`` caches hang off the wrapped function object, so a fresh
    ``Simulator`` per single used to mean a fresh ``_sweep_cache`` and one
    recompile per cell even for config-identical scenarios. Pass
    ``sim_cache`` (a mutable dict, reused across calls) to extend that
    sharing across ``execute_plan`` invocations — the caller must keep
    ``loss_fn`` / ``params0`` / ``eval_fn`` fixed for a given cache, since
    they are baked into each cached Simulator's compiled programs.

    Labels are the stable row key (``id(scenario)`` was reusable after GC
    and collided silently); duplicates raise ``ValueError``.

    With ``streaming=True`` the O(steps) host materialisation is skipped:
    each bank/single consumes ``stream_chunk_size``-round chunks from a
    ``prefetch_depth``-deep ring buffer
    (:func:`fused_grid_rollout_streaming` /
    :func:`rollout_over_seeds_streaming`) — bit-for-bit the same
    trajectories, O(prefetch_depth) host residency. A callable ``batches``
    is then re-streamed from round 0 for EVERY bank and single, so it must
    be a pure function of the round index (stateful ``data.BatchFn``
    instances would diverge across banks — pre-stack those, or pass a
    ``(seed, t)``-keyed pure fn as the transformer testbed does).
    """
    if streaming:
        if callable(batches):
            if steps is None:
                raise ValueError("steps is required when batches is callable")
            n_steps = steps
        else:
            n_avail = jax.tree_util.tree_leaves(batches)[0].shape[0]
            n_steps = n_avail if steps is None else min(steps, n_avail)
    else:
        batches = ensure_stacked(batches, steps)
        n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
    rows_by_label: Dict[str, List[Dict[str, Any]]] = {}
    if sim_cache is None:
        sim_cache = {}

    def get_sim(cfg: alg.AlgorithmConfig) -> Simulator:
        if cfg not in sim_cache:
            sim_cache[cfg] = Simulator(loss_fn=loss_fn, params0=params0,
                                       cfg=cfg, eval_fn=eval_fn)
        return sim_cache[cfg]

    def insert(sc: Scenario, rows: List[Dict[str, Any]]) -> None:
        if sc.label in rows_by_label:
            raise ValueError(
                f"duplicate scenario label {sc.label!r} in plan — labels "
                "key the results table")
        rows_by_label[sc.label] = rows

    for bank in plan.banks:
        sim = get_sim(bank.cfg)
        if streaming:
            states, metrics = fused_grid_rollout_streaming(
                sim, bank.scenario_params(), seeds, batches, n_steps,
                chunk_size=stream_chunk_size, prefetch_depth=prefetch_depth,
                shard=shard, devices=devices)
        else:
            states, metrics = fused_grid_rollout(
                sim, bank.scenario_params(), seeds, batches,
                shard=shard, devices=devices)
        loss = np.asarray(metrics["loss"])  # [n_cells, n_seeds, steps]
        emet_grid = (fused_grid_eval(sim, states, eval_batch, shard=shard,
                                     devices=devices)
                     if eval_fn is not None and eval_batch is not None
                     else {})
        emet_grid = {k: np.asarray(v) for k, v in emet_grid.items()}
        for c, sc in enumerate(bank.scenarios):
            emet = {k: v[c] for k, v in emet_grid.items()}
            insert(sc, _result_rows(sc, sim, seeds, loss[c], emet, n_steps))
    for sc in plan.singles:
        sim = get_sim(sc.cfg)
        if streaming:
            states, metrics = rollout_over_seeds_streaming(
                sim, seeds, batches, n_steps,
                chunk_size=stream_chunk_size, prefetch_depth=prefetch_depth)
        else:
            states, metrics = rollout_over_seeds(sim, seeds, batches)
        emet = (eval_over_seeds(sim, states, eval_batch)
                if eval_fn is not None and eval_batch is not None
                else {})
        insert(sc, _result_rows(sc, sim, seeds,
                                np.asarray(metrics["loss"]), emet, n_steps))
    return rows_by_label


def run_scenarios(scenarios: Sequence[Scenario], *,
                  loss_fn: Callable[[Any, Any], jnp.ndarray],
                  params0: Any, batches: Any, seeds: Sequence[int],
                  steps: Optional[int] = None,
                  eval_fn: Optional[Callable[[Any, Any], Dict]] = None,
                  eval_batch: Any = None,
                  fuse_attacks: bool = True,
                  cross_algo: bool = True,
                  shard: bool = True,
                  devices: Optional[Sequence[Any]] = None,
                  cost_model: Optional[CostModel] = None,
                  sim_cache: Optional[Dict[alg.AlgorithmConfig,
                                           Simulator]] = None,
                  streaming: bool = False,
                  stream_chunk_size: int = 32,
                  prefetch_depth: int = 4
                  ) -> List[Dict[str, Any]]:
    """Run every scenario x seed cell; return the flat results table.

    Plan/execute: the grid is partitioned into maximal fusible banks
    (:func:`plan_grid` — attack coefficients, aggregator-bank index,
    algorithm-bank index + hyperparameters, and traceable keep-ratios
    become vmapped data) and each bank executes as ONE compiled program
    laid out over mesh devices (:func:`fused_grid_rollout`), eval included
    (:func:`fused_grid_eval`). Everything else pays one vmapped-scan
    compile per scenario. Rows carry the scenario label/config fields, the
    seed, final/min loss, total uplink bytes under each algorithm's actual
    wire format (``algorithms.algo_payload_bytes``), and (when ``eval_fn``
    is given) final eval metrics.

    ``fuse_attacks=False`` disables fusion entirely; ``cross_algo=False``
    keeps one bank per algorithm (both are equivalence baselines);
    ``shard=False`` keeps every program on the default device. With
    ``cost_model`` the fuse-vs-partition choice per multi-algorithm bank is
    the model's (:func:`plan_grid`); ``sim_cache`` shares compiled
    Simulators across calls (see :func:`execute_plan`);
    ``streaming=True`` feeds every bank from the prefetched ring buffer
    instead of one O(steps) stacked array (see :func:`execute_plan`).
    """
    if streaming and callable(batches):
        if steps is None:
            raise ValueError("steps is required when batches is callable")
        rounds = steps
    else:
        batches = ensure_stacked(batches, steps)
        rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
    plan = plan_grid(scenarios, fuse=fuse_attacks, cross_algo=cross_algo,
                     cost_model=cost_model, rounds=rounds,
                     n_seeds=len(seeds),
                     sharded=shard and len(devices or jax.devices()) > 1)
    rows_by_label = execute_plan(
        plan, loss_fn=loss_fn, params0=params0, batches=batches, seeds=seeds,
        steps=rounds, eval_fn=eval_fn, eval_batch=eval_batch, shard=shard,
        devices=devices, sim_cache=sim_cache, streaming=streaming,
        stream_chunk_size=stream_chunk_size, prefetch_depth=prefetch_depth)
    # restore caller ordering regardless of fusion grouping
    return [row for sc in scenarios for row in rows_by_label[sc.label]]


# --------------------------------------------------------------------------
# Built-in testbeds + CLI
# --------------------------------------------------------------------------


def quadratic_testbed(n_workers: int, d: int = 64, spread: float = 0.1,
                      seed: int = 0):
    """The controlled quadratic testbed of benchmarks/bench_table1: worker i
    holds target ``t_i``, local loss ``0.5 ||w - t_i||^2``, so the honest
    optimum (mean of honest targets) is known exactly.

    Returns ``(loss_fn, params0, batch_fn, targets)``.
    """
    tg = jax.random.normal(jax.random.PRNGKey(seed),
                           (n_workers, d)) * spread + 1.0

    def loss_fn(params, batch):
        return 0.5 * jnp.sum(jnp.square(params["w"] - batch["target"]))

    return loss_fn, {"w": jnp.zeros(d)}, (lambda t: {"target": tg}), tg


def _mnist_testbed(n_workers: int, per_worker: int = 800, batch: int = 60,
                   seed: int = 0, alpha_het: Optional[float] = None):
    from repro.adversary.heterogeneity import dirichlet_mnist
    from repro.models import cnn_accuracy, cnn_init, cnn_loss

    ds = dirichlet_mnist(n_workers=n_workers, alpha=alpha_het,
                         per_worker=per_worker, seed=seed)
    eval_fn = lambda p, b: {"acc": cnn_accuracy(p, b)}  # noqa: E731
    return (cnn_loss, cnn_init(jax.random.PRNGKey(0)),
            ds.worker_batches(batch), eval_fn, ds.eval_batch)


def _transformer_testbed(n_workers: int, local_batch: int = 4,
                         seq_len: int = 32, seed: int = 0,
                         n_layers: int = 2, d_model: int = 256):
    """Reduced ``configs/stablelm_3b`` causal LM on synthetic token streams.

    The batch schedule is a PURE function of the round index
    (``np.random.default_rng((seed, t))``), so the streaming path can
    re-stream it per bank without divergence (unlike the stateful MNIST
    ``BatchFn``). Eval is held-out next-token accuracy.

    Returns ``(loss_fn, params0, batch_fn, eval_fn, eval_batch)``.
    """
    from repro.configs.base import get_arch
    from repro.data import synthetic_token_batch
    from repro.models import transformer as TR

    cfg = get_arch("stablelm_3b").model.reduced(n_layers=n_layers,
                                                d_model=d_model)
    params0 = TR.model_init(jax.random.PRNGKey(seed), cfg)
    loss_fn = lambda p, b: TR.lm_loss(p, cfg, b)  # noqa: E731

    def batch_fn(t: int):
        rng = np.random.default_rng((seed, int(t)))
        return synthetic_token_batch(rng, n_workers, local_batch, seq_len,
                                     cfg.vocab_size)

    def eval_fn(p, b):
        hidden, _, _ = TR.forward(p, cfg, b, mode="train")
        logits = TR.logits_fn(p, cfg, hidden[:, :-1]).astype(jnp.float32)
        pred = jnp.argmax(logits, axis=-1)
        tgt = b["tokens"][:, 1:]
        return {"acc": jnp.mean((pred == tgt).astype(jnp.float32))}

    # held-out eval stream: one "worker" with a bigger batch, keyed off the
    # training round-index range (t < 2**32 always)
    hold = np.random.default_rng((seed, 2 ** 32))
    eval_batch = {
        k: jnp.asarray(v[0]) for k, v in synthetic_token_batch(
            hold, 1, 8 * local_batch, seq_len, cfg.vocab_size).items()}
    return loss_fn, params0, batch_fn, eval_fn, eval_batch


def main(argv: Optional[Sequence[str]] = None) -> List[Dict[str, Any]]:
    import argparse

    p = argparse.ArgumentParser(description="attack x aggregator x algorithm "
                                "x seed grid runner (plan/execute: maximal "
                                "fusible banks, one device-sharded program "
                                "per bank)")
    p.add_argument("--algos", default="rosdhb")
    p.add_argument("--attacks", default="alie")
    p.add_argument("--aggs", default="cwtm")
    p.add_argument("--scenario", default=None,
                   help="named registry scenario (attack x heterogeneity x "
                        "byzantine-fraction composition, see "
                        "--list-scenarios); overrides --algos/--attacks/"
                        "--aggs/--f/--n-honest/--ratio/--testbed")
    p.add_argument("--list-scenarios", action="store_true",
                   help="print the scenario registry and exit")
    p.add_argument("--seeds", type=int, default=4, help="number of seeds")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--f", type=int, default=3)
    p.add_argument("--n-honest", type=int, default=10)
    p.add_argument("--ratio", type=float, default=0.1)
    p.add_argument("--gamma", type=float, default=0.05)
    p.add_argument("--testbed", default="quadratic",
                   choices=["quadratic", "mnist", "transformer"])
    p.add_argument("--stream", action=argparse.BooleanOptionalAction,
                   default=False,
                   help="feed rollouts from the prefetched ring buffer "
                        "(repro.data.stream) instead of materialising the "
                        "[steps, ...] batch schedule host-side — required "
                        "for LLM-scale step counts; implied default for "
                        "--testbed transformer")
    p.add_argument("--stream-chunk", type=int, default=32,
                   help="rounds per streamed chunk (scan length of one "
                        "chunk program)")
    p.add_argument("--prefetch-depth", type=int, default=4,
                   help="ring-buffer depth: peak host residency is "
                        "O(prefetch_depth * chunk_bytes)")
    p.add_argument("--fuse", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="fuse the attack / aggregator / algorithm / ratio "
                        "axes into banks (--no-fuse: one program per "
                        "scenario)")
    p.add_argument("--cross-algo", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="fuse the ALGORITHM axis too (lax.switch algorithm "
                        "bank over the unified server state — a Table-1 "
                        "algo x attack x agg grid = ONE program; "
                        "--no-cross-algo: one bank per algorithm)")
    p.add_argument("--shard", action=argparse.BooleanOptionalAction,
                   default=True,
                   help="lay each bank's flat cells x seeds axis over all "
                        "visible devices (--no-shard: single device); force "
                        "virtual CPU devices with "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    p.add_argument("--kernels", default="auto",
                   choices=["auto", "pallas", "jnp"],
                   help="aggregation backend: 'auto' picks the Pallas TPU "
                        "kernels on TPU and the jnp rules elsewhere; "
                        "'pallas' forces the kernel path (interpret mode "
                        "off-TPU — slow, parity testing only); 'jnp' forces "
                        "the XLA reference rules")
    p.add_argument("--cost-model", default=None, metavar="PATH|auto",
                   help="decide fusion vs per-algorithm partition with a "
                        "measured cost model: a COST_MODEL.json path, or "
                        "'auto' for results/COST_MODEL.json falling back to "
                        "the pinned default (calibrate with "
                        "benchmarks/bench_sweep.py)")
    p.add_argument("--plan", action="store_true",
                   help="print the grid plan (banks/singles/cost-model "
                        "notes) and exit")
    p.add_argument("--out", default=None, help="optional JSON output path")
    args = p.parse_args(argv)

    cost_model = None
    if args.cost_model == "auto":
        cost_model = CostModel.load_or_default()
    elif args.cost_model is not None:
        cost_model = CostModel.load(args.cost_model)

    if args.list_scenarios:
        from repro.adversary import registry as R
        print(R.describe())
        return []
    alpha_het = None
    if args.scenario is not None:
        from repro.adversary import registry as R
        spec = R.get_spec(args.scenario)  # ValueError lists known names
        scenarios = spec.expand()
        n = spec.n_workers
        testbed, alpha_het = spec.testbed, spec.alpha_het
    else:
        use_pallas = {"auto": None, "pallas": True, "jnp": False}[args.kernels]
        scenarios = grid_scenarios(
            args.algos.split(","), args.attacks.split(","),
            args.aggs.split(","), n_honest=args.n_honest, f=args.f,
            ratio=args.ratio, gamma=args.gamma, use_pallas=use_pallas)
        n = args.n_honest + args.f
        testbed = args.testbed
    if args.plan:
        print(plan_grid(scenarios, fuse=args.fuse,
                        cross_algo=args.cross_algo, cost_model=cost_model,
                        rounds=args.steps, n_seeds=args.seeds).describe())
        return []
    seeds = list(range(args.seeds))
    streaming = args.stream or testbed == "transformer"
    if testbed == "quadratic":
        loss_fn, params0, batch_fn, _ = quadratic_testbed(n)
        eval_fn = eval_batch = None
    elif testbed == "transformer":
        loss_fn, params0, batch_fn, eval_fn, eval_batch = \
            _transformer_testbed(n)
    else:
        loss_fn, params0, batch_fn, eval_fn, eval_batch = _mnist_testbed(
            n, alpha_het=alpha_het)
        if streaming:
            # the MNIST BatchFn is stateful (own RNG): pre-stack once so
            # every bank streams the identical schedule
            from repro.core.simulator import stack_batches
            batch_fn = stack_batches(batch_fn, args.steps)
    rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=seeds, steps=args.steps,
                         eval_fn=eval_fn, eval_batch=eval_batch,
                         fuse_attacks=args.fuse, cross_algo=args.cross_algo,
                         shard=args.shard, cost_model=cost_model,
                         streaming=streaming,
                         stream_chunk_size=args.stream_chunk,
                         prefetch_depth=args.prefetch_depth)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    if args.out:
        import json
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(rows, fh, indent=2)
    return rows


if __name__ == "__main__":
    main()
