"""Batched experiment grids: vmap the scan rollout over seeds, enumerate
scenarios.

The paper's empirical claims (Fig. 1, Table 1) are sweeps over attack x
aggregator x algorithm x seed grids. Dispatching ``Simulator.run`` once per
cell multiplies host-side overhead by the grid size; here every scenario is
ONE compiled XLA program — ``lax.scan`` over rounds (``Simulator.rollout``)
``vmap``-ed over the seed axis — and the enumerated scenarios land in a flat
results table. Early stopping is handled post-hoc from the stacked on-device
metrics (:func:`bytes_to_threshold`), matching the paper's
comm-bytes-to-tau protocol without breaking the scan.

CLI (the grid runner described in benchmarks/README.md):

    PYTHONPATH=src python -m repro.core.sweep \
        --algos rosdhb,dasha --attacks alie,foe,signflip --aggs cwtm \
        --seeds 4 --steps 300 --f 3 --ratio 0.1
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as G
from repro.core import algorithms as alg
from repro.core import attacks as A
from repro.core import compression as C
from repro.core.simulator import SimState, Simulator, ensure_stacked
from repro.utils import tree as T


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One labelled grid cell: a full algorithm configuration."""

    label: str
    cfg: alg.AlgorithmConfig


def grid_scenarios(algos: Sequence[str] = ("rosdhb",),
                   attacks: Sequence[str] = ("alie",),
                   aggregators: Sequence[str] = ("cwtm",),
                   *, n_honest: int = 10, f: int = 3, ratio: float = 0.1,
                   gamma: float = 0.05, beta: float = 0.9,
                   pre_nnm: bool = True, local: bool = False,
                   alie_z: Optional[float] = 1.5) -> List[Scenario]:
    """Enumerate the attack x aggregator x algorithm product into scenarios.

    ``f`` is fixed across the grid so every scenario shares the worker count
    (and therefore one stacked batch pytree). ``dgd`` pairs with plain mean
    (its defining non-robust corner) regardless of ``aggregators``.
    """
    out = []
    for algo, attack, agg in itertools.product(algos, attacks, aggregators):
        aggregator = (G.AggregatorConfig(name="mean") if algo == "dgd"
                      else G.AggregatorConfig(name=agg, f=max(f, 1),
                                              pre_nnm=pre_nnm))
        sparsifier = C.SparsifierConfig(
            kind="randk", ratio=1.0 if algo == "robust_dgd" else ratio,
            local=local)
        cfg = alg.AlgorithmConfig(
            name=algo, n_workers=n_honest + f, f=f, gamma=gamma, beta=beta,
            sparsifier=sparsifier, aggregator=aggregator,
            attack=A.AttackConfig(name=attack,
                                  z=alie_z if attack == "alie" else None))
        out.append(Scenario(label=f"{algo}/{attack}/{aggregator.name}", cfg=cfg))
    return out


def init_states(sim: Simulator, seeds: Sequence[int]) -> SimState:
    """Stack per-seed initial states on a leading seed axis."""
    if not len(seeds):
        raise ValueError("seeds must be non-empty")
    states = [sim.init(int(s)) for s in seeds]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def rollout_over_seeds(sim: Simulator, seeds: Sequence[int], batches: Any,
                       steps: Optional[int] = None
                       ) -> Tuple[SimState, dict]:
    """Run all seeds of one scenario in a single vmapped scan.

    ``batches`` (a stacked pytree or a ``batch_fn``) is shared across seeds —
    seed variation enters through the per-seed PRNG state (mask sampling and
    stochastic attacks), matching sequential ``Simulator.rollout`` calls with
    ``sim.init(seed)``.

    Returns ``(final_states, metrics)`` with a leading seed axis on every
    leaf (metrics are ``[n_seeds, steps]``).
    """
    batches = ensure_stacked(batches, steps)
    if "seed_vmap" not in sim._sweep_cache:
        sim._sweep_cache["seed_vmap"] = jax.jit(
            jax.vmap(sim._scan, in_axes=(0, None)))
    return sim._sweep_cache["seed_vmap"](init_states(sim, seeds), batches)


def fused_attack_rollout(sim: Simulator,
                         attack_cfgs: Sequence[A.AttackConfig],
                         seeds: Sequence[int], batches: Any,
                         steps: Optional[int] = None
                         ) -> Tuple[SimState, dict]:
    """Run a whole attacks x seeds grid as ONE compiled XLA program.

    Every attack must belong to the mean/std linear family
    (:func:`repro.core.attacks.linear_coeffs` — alie/signflip/ipm/foe/zero):
    their coefficients become a traced ``[n_attacks, 2]`` input vmapped over,
    so the grid pays a single compile instead of one per attack. ``sim`` must
    be built with ``attack=AttackConfig(name="linear")``.

    Returns ``(final_states, metrics)`` with leading ``[n_attacks, n_seeds]``
    axes on every leaf.
    """
    assert sim.cfg.attack.name == "linear", sim.cfg.attack
    n, f = sim.cfg.n_workers, sim.cfg.f
    coeffs = []
    for a in attack_cfgs:
        c = A.linear_coeffs(a, n, f)
        if c is None:
            raise ValueError(f"attack {a.name!r} is outside the linear "
                             "family; run it as its own scenario")
        coeffs.append(c)
    batches = ensure_stacked(batches, steps)
    if "attack_seed_vmap" not in sim._sweep_cache:
        # ONE flat vmap axis of size n_attacks * n_seeds (a nested
        # vmap-of-vmap compiles ~2.5x slower for the same program)
        sim._sweep_cache["attack_seed_vmap"] = jax.jit(
            jax.vmap(sim._scan, in_axes=(0, None, 0)))
    n_a, n_s = len(coeffs), len(seeds)
    states = init_states(sim, seeds)
    states_flat = jax.tree_util.tree_map(
        lambda l: jnp.tile(l, (n_a,) + (1,) * (l.ndim - 1)), states)
    coeffs_flat = jnp.repeat(jnp.asarray(coeffs, jnp.float32), n_s, axis=0)
    out_states, out_metrics = sim._sweep_cache["attack_seed_vmap"](
        states_flat, batches, coeffs_flat)
    unflatten = lambda l: l.reshape((n_a, n_s) + l.shape[1:])  # noqa: E731
    return (jax.tree_util.tree_map(unflatten, out_states),
            jax.tree_util.tree_map(unflatten, out_metrics))


def eval_over_seeds(sim: Simulator, states: SimState,
                    eval_batch: Any) -> Dict[str, jnp.ndarray]:
    """vmap ``sim.eval_fn`` over the seed axis of stacked final states."""
    assert sim.eval_fn is not None, "Simulator has no eval_fn"
    if "eval_vmap" not in sim._sweep_cache:
        def one(flat, batch):
            return sim.eval_fn(T.tree_unravel(flat, sim.spec), batch)

        sim._sweep_cache["eval_vmap"] = jax.jit(
            jax.vmap(one, in_axes=(0, None)))
    return sim._sweep_cache["eval_vmap"](states.params_flat, eval_batch)


def bytes_to_threshold(values: np.ndarray, per_round_bytes: int,
                       threshold: float, mode: str = "<=") -> np.ndarray:
    """Post-hoc early stopping: uplink bytes until ``values`` first crosses
    ``threshold`` (``inf`` where it never does).

    ``values`` is a per-round metric trajectory ``[steps]`` or a stacked
    ``[n_seeds, steps]``; rounds are 1-indexed for byte accounting, matching
    the legacy ``stop_fn`` protocol.
    """
    if mode not in ("<=", ">="):
        raise ValueError(f"mode must be '<=' or '>=', got {mode!r}")
    v = np.atleast_2d(np.asarray(values))
    hit = (v <= threshold) if mode == "<=" else (v >= threshold)
    any_hit = hit.any(axis=1)
    first = np.where(any_hit, hit.argmax(axis=1), 0)
    out = np.where(any_hit, (first + 1.0) * per_round_bytes, np.inf)
    return out[0] if np.ndim(values) == 1 else out


def _result_rows(sc: Scenario, sim: Simulator, seeds: Sequence[int],
                 loss: np.ndarray, emet: Dict[str, Any],
                 n_steps: int) -> List[Dict[str, Any]]:
    total_bytes = sim.payload_bytes_per_round() * n_steps
    rows = []
    for i, seed in enumerate(seeds):
        row = {
            "scenario": sc.label,
            "algo": sc.cfg.name,
            "attack": sc.cfg.attack.name,
            "aggregator": sc.cfg.aggregator.name,
            "ratio": sc.cfg.sparsifier.ratio,
            "f": sc.cfg.f,
            "seed": int(seed),
            "final_loss": float(loss[i, -1]),
            "min_loss": float(loss[i].min()),
            "comm_bytes": total_bytes,
        }
        row.update({k: float(v[i]) for k, v in emet.items()})
        rows.append(row)
    return rows


def run_scenarios(scenarios: Sequence[Scenario], *,
                  loss_fn: Callable[[Any, Any], jnp.ndarray],
                  params0: Any, batches: Any, seeds: Sequence[int],
                  steps: Optional[int] = None,
                  eval_fn: Optional[Callable[[Any, Any], Dict]] = None,
                  eval_batch: Any = None,
                  fuse_attacks: bool = True) -> List[Dict[str, Any]]:
    """Run every scenario x seed cell; return the flat results table.

    Scenarios that differ only in a mean/std-family attack are fused into a
    single compiled program (:func:`fused_attack_rollout`) — the attack axis
    becomes vmapped data. Everything else pays one vmapped-scan compile per
    scenario. Rows carry the scenario label/config fields, the seed,
    final/min loss, total honest uplink bytes, and (when ``eval_fn`` is
    given) final eval metrics.
    """
    batches = ensure_stacked(batches, steps)
    n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]

    # group scenarios that differ only in their (linear-family) attack
    groups: Dict[alg.AlgorithmConfig, List[Scenario]] = {}
    for sc in scenarios:
        base = dataclasses.replace(sc.cfg, attack=A.AttackConfig(name="none"))
        groups.setdefault(base, []).append(sc)

    rows_by_scenario: Dict[int, List[Dict[str, Any]]] = {}
    for base, group in groups.items():
        fusible = (fuse_attacks and len(group) > 1 and all(
            A.linear_coeffs(sc.cfg.attack, base.n_workers, base.f) is not None
            for sc in group))
        if fusible:
            lin = dataclasses.replace(base,
                                      attack=A.AttackConfig(name="linear"))
            sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=lin,
                            eval_fn=eval_fn)
            states, metrics = fused_attack_rollout(
                sim, [sc.cfg.attack for sc in group], seeds, batches)
            loss = np.asarray(metrics["loss"])  # [n_attacks, n_seeds, steps]
            for a, sc in enumerate(group):
                st_a = jax.tree_util.tree_map(lambda l: l[a], states)
                emet = (eval_over_seeds(sim, st_a, eval_batch)
                        if eval_fn is not None and eval_batch is not None
                        else {})
                rows_by_scenario[id(sc)] = _result_rows(
                    sc, sim, seeds, loss[a], emet, n_steps)
        else:
            for sc in group:
                sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg,
                                eval_fn=eval_fn)
                states, metrics = rollout_over_seeds(sim, seeds, batches)
                emet = (eval_over_seeds(sim, states, eval_batch)
                        if eval_fn is not None and eval_batch is not None
                        else {})
                rows_by_scenario[id(sc)] = _result_rows(
                    sc, sim, seeds, np.asarray(metrics["loss"]), emet,
                    n_steps)
    # restore caller ordering regardless of fusion grouping
    return [row for sc in scenarios for row in rows_by_scenario[id(sc)]]


# --------------------------------------------------------------------------
# Built-in testbeds + CLI
# --------------------------------------------------------------------------


def quadratic_testbed(n_workers: int, d: int = 64, spread: float = 0.1,
                      seed: int = 0):
    """The controlled quadratic testbed of benchmarks/bench_table1: worker i
    holds target ``t_i``, local loss ``0.5 ||w - t_i||^2``, so the honest
    optimum (mean of honest targets) is known exactly.

    Returns ``(loss_fn, params0, batch_fn, targets)``.
    """
    tg = jax.random.normal(jax.random.PRNGKey(seed),
                           (n_workers, d)) * spread + 1.0

    def loss_fn(params, batch):
        return 0.5 * jnp.sum(jnp.square(params["w"] - batch["target"]))

    return loss_fn, {"w": jnp.zeros(d)}, (lambda t: {"target": tg}), tg


def _mnist_testbed(n_workers: int, per_worker: int = 800, batch: int = 60,
                   seed: int = 0):
    from repro.data import SyntheticMNIST
    from repro.models import cnn_accuracy, cnn_init, cnn_loss

    ds = SyntheticMNIST(n_workers=n_workers, per_worker=per_worker, seed=seed)
    eval_fn = lambda p, b: {"acc": cnn_accuracy(p, b)}  # noqa: E731
    return (cnn_loss, cnn_init(jax.random.PRNGKey(0)),
            ds.worker_batches(batch), eval_fn, ds.eval_batch)


def main(argv: Optional[Sequence[str]] = None) -> List[Dict[str, Any]]:
    import argparse

    p = argparse.ArgumentParser(description="attack x aggregator x algorithm "
                                "x seed grid runner (one vmapped scan per "
                                "scenario)")
    p.add_argument("--algos", default="rosdhb")
    p.add_argument("--attacks", default="alie")
    p.add_argument("--aggs", default="cwtm")
    p.add_argument("--seeds", type=int, default=4, help="number of seeds")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--f", type=int, default=3)
    p.add_argument("--n-honest", type=int, default=10)
    p.add_argument("--ratio", type=float, default=0.1)
    p.add_argument("--gamma", type=float, default=0.05)
    p.add_argument("--testbed", default="quadratic",
                   choices=["quadratic", "mnist"])
    p.add_argument("--out", default=None, help="optional JSON output path")
    args = p.parse_args(argv)

    scenarios = grid_scenarios(
        args.algos.split(","), args.attacks.split(","), args.aggs.split(","),
        n_honest=args.n_honest, f=args.f, ratio=args.ratio, gamma=args.gamma)
    seeds = list(range(args.seeds))
    n = args.n_honest + args.f
    if args.testbed == "quadratic":
        loss_fn, params0, batch_fn, _ = quadratic_testbed(n)
        eval_fn = eval_batch = None
    else:
        loss_fn, params0, batch_fn, eval_fn, eval_batch = _mnist_testbed(n)
    rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=seeds, steps=args.steps,
                         eval_fn=eval_fn, eval_batch=eval_batch)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.6g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))
    if args.out:
        import json
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as fh:
            json.dump(rows, fh, indent=2)
    return rows


if __name__ == "__main__":
    main()
