"""Paper-scale distributed-learning simulator.

Simulates a server + n workers on a single host (the paper's own evaluation
setup, §4): every round, honest workers compute (mini-batch) gradients on
their local shard, the chosen algorithm compresses/attacks/aggregates, and
the server updates the model.

The engine is a single ``lax.scan`` over rounds (:meth:`Simulator.rollout`):
the whole trajectory runs inside one jitted XLA program with metrics stacked
on device, so sweeping the paper's attack x aggregator x algorithm x seed
grids (``repro.core.sweep``) pays host-side dispatch once per scenario
instead of once per round. :meth:`Simulator.run` is kept as a thin
compatibility wrapper that chunks the scan at eval rounds to preserve the
legacy eval/early-stop protocol, and :meth:`Simulator.run_per_round` retains
the original one-dispatch-per-round loop as the equivalence/benchmark
reference.

This is the engine behind the MNIST-like reproduction (benchmarks/bench_fig1)
and the convergence-comparison benchmarks; the LLM-scale path lives in
``repro/launch`` and shares the same ``core.algorithms`` math.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import compression as C
from repro.utils import tree as T


class SimState(NamedTuple):
    params_flat: jnp.ndarray
    server: alg.ServerState
    key: jax.Array


def stack_batches(batch_fn: Callable[[int], Any], steps: int,
                  start: int = 0) -> Any:
    """Materialise ``batch_fn(start) .. batch_fn(start+steps-1)`` stacked on a
    leading step axis, ready for :meth:`Simulator.rollout`'s scan.

    Stateful ``batch_fn`` implementations (e.g. ``data.BatchFn``) are called
    in step order, so chunked stacking reproduces the same stream as the
    legacy per-round loop.
    """
    per_step = [batch_fn(t) for t in range(start, start + steps)]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *per_step)


def ensure_stacked(batches: Any, steps: Optional[int]) -> Any:
    """Normalise a rollout's ``batches`` argument: materialise a ``batch_fn``
    callable into a step-stacked pytree, pass stacked pytrees through."""
    if callable(batches):
        if steps is None:
            raise ValueError("steps is required when batches is callable")
        return stack_batches(batches, steps)
    return batches


@dataclasses.dataclass
class Simulator:
    """Single-host simulator of Byzantine-robust compressed training.

    Args:
      loss_fn: ``loss_fn(params, batch) -> scalar`` — per-worker local loss.
      params0: initial parameter pytree.
      cfg: algorithm configuration (n_workers, f, attack, compression, ...).
      eval_fn: optional ``eval_fn(params, eval_batch) -> metrics dict``.
    """

    loss_fn: Callable[[Any, Any], jnp.ndarray]
    params0: Any
    cfg: alg.AlgorithmConfig
    eval_fn: Optional[Callable[[Any, Any], Dict[str, jnp.ndarray]]] = None

    def __post_init__(self):
        self.spec = T.make_flat_spec(self.params0)
        self.d = self.spec.size

        def _round(state: SimState, worker_batches,
                   attack_params=None) -> Tuple[SimState, dict]:
            key, mask_key = jax.random.split(state.key)
            params = T.tree_unravel(state.params_flat, self.spec)

            def worker_grad(batch):
                l, g = jax.value_and_grad(self.loss_fn)(params, batch)
                return l, T.tree_ravel(g, self.spec)

            losses, grads = jax.vmap(worker_grad)(worker_batches)
            r, server, aux = alg.server_round(self.cfg, state.server, grads,
                                              mask_key,
                                              attack_params=attack_params)
            new_flat = alg.apply_direction(state.params_flat, r,
                                           self.cfg.gamma)
            metrics = {
                "loss": jnp.mean(losses[self.cfg.f:]),  # honest mean loss
                "grad_norm": jnp.linalg.norm(jnp.mean(grads[self.cfg.f:],
                                                      axis=0)),
                "dir_norm": jnp.linalg.norm(r),
            }
            return SimState(new_flat, server, key), metrics

        def _scan(state: SimState, batches,
                  attack_params=None) -> Tuple[SimState, dict]:
            return jax.lax.scan(
                lambda s, b: _round(s, b, attack_params), state, batches)

        self._round = jax.jit(_round)
        # un-jitted scan kept separate so repro.core.sweep can vmap it over
        # the seed (and linear-attack coefficient) axes before compiling
        self._scan = _scan
        self._rollout = jax.jit(_scan)
        # jitted sweep entry points, cached per vmap structure so repeated
        # grid calls don't re-trace
        self._sweep_cache: dict = {}

    def init(self, seed: int = 0) -> SimState:
        return SimState(
            params_flat=T.tree_ravel(self.params0, self.spec),
            server=alg.init_state(self.cfg, self.spec.padded_size),
            key=jax.random.PRNGKey(seed),
        )

    def params(self, state: SimState) -> Any:
        return T.tree_unravel(state.params_flat, self.spec)

    def payload_bytes_per_round(self) -> int:
        """Total honest uplink bytes per round (the paper's comm-cost metric).

        The paper counts communication of all n workers (the server cannot
        know who is honest); we follow that convention."""
        per = C.payload_bytes(self.d, self.cfg.sparsifier, bytes_per_value=4,
                              with_mask_indices=True)
        return per * self.cfg.n_workers

    def rollout(self, state: SimState, batches: Any,
                steps: Optional[int] = None) -> Tuple[SimState, dict]:
        """Run a whole trajectory inside one jitted ``lax.scan``.

        ``batches`` is either a pytree whose leaves carry a leading step axis
        (``[steps, n_workers, ...]``, see :func:`stack_batches`) or a
        ``batch_fn(t)`` callable (then ``steps`` is required and the batches
        are materialised host-side first).

        Returns ``(final_state, metrics)`` where ``metrics`` is a dict of
        ``[steps]`` arrays stacked on device. There is no early stopping —
        the scan always runs every round; threshold crossings (the paper's
        comm-bytes-to-tau protocol) are computed post-hoc, e.g. with
        :func:`repro.core.sweep.bytes_to_threshold`.
        """
        return self._rollout(state, ensure_stacked(batches, steps))

    def _record(self, history: Dict[str, list], rec: Dict[str, float],
                t: int) -> None:
        history["step"].append(t)
        history["loss"].append(rec["loss"])
        history["comm_bytes"].append(rec["comm_bytes"])
        for k, v in rec.items():
            if k not in ("loss", "comm_bytes"):
                history.setdefault(k, []).append(v)

    def _eval_record(self, state: SimState, m: Dict[str, Any], t: int,
                     per_round: int, eval_batch: Any) -> Dict[str, float]:
        rec = {k: float(v) for k, v in m.items()}
        rec["comm_bytes"] = per_round * (t + 1)
        if self.eval_fn is not None and eval_batch is not None:
            emet = self.eval_fn(self.params(state), eval_batch)
            rec.update({k: float(v) for k, v in emet.items()})
        return rec

    def run(self, state: SimState, batch_fn: Callable[[int], Any],
            steps: int, eval_every: int = 0, eval_batch: Any = None,
            stop_fn: Optional[Callable[[Dict[str, float]], bool]] = None,
            ) -> Tuple[SimState, Dict[str, list]]:
        """Run ``steps`` rounds (thin compatibility wrapper over the scan
        engine).

        ``batch_fn(t)`` must return stacked per-worker batches with leading
        dim ``n_workers``. ``stop_fn(metrics)`` can end training early (used
        by the communication-cost-to-threshold benchmark).

        The trajectory is executed as ``lax.scan`` chunks whose boundaries
        are exactly the legacy eval rounds (``t % eval_every == 0`` or the
        final step), so the eval schedule, history contents, and early-stop
        behaviour match :meth:`run_per_round` while paying host dispatch per
        eval chunk instead of per round.
        """
        history: Dict[str, list] = {"step": [], "loss": [], "comm_bytes": []}
        per_round = self.payload_bytes_per_round()
        if steps <= 0:
            return state, history
        if not eval_every:
            state, _ = self.rollout(state, batch_fn, steps)
            return state, history
        eval_rounds = [t for t in range(steps)
                       if t % eval_every == 0 or t == steps - 1]
        prev = -1
        for t in eval_rounds:
            chunk = stack_batches(batch_fn, t - prev, start=prev + 1)
            state, ms = self._rollout(state, chunk)
            prev = t
            m_last = {k: v[-1] for k, v in ms.items()}
            rec = self._eval_record(state, m_last, t, per_round, eval_batch)
            self._record(history, rec, t)
            if stop_fn is not None and stop_fn(rec):
                break
        return state, history

    def run_per_round(self, state: SimState, batch_fn: Callable[[int], Any],
                      steps: int, eval_every: int = 0, eval_batch: Any = None,
                      stop_fn: Optional[Callable[[Dict[str, float]], bool]]
                      = None) -> Tuple[SimState, Dict[str, list]]:
        """Legacy engine: one jitted dispatch per round.

        Kept as the numerical-equivalence reference for the scan engine
        (tests/test_engine.py) and as the sequential baseline for
        benchmarks/bench_sweep.py.
        """
        history: Dict[str, list] = {"step": [], "loss": [], "comm_bytes": []}
        per_round = self.payload_bytes_per_round()
        for t in range(steps):
            state, m = self._round(state, batch_fn(t))
            if eval_every and (t % eval_every == 0 or t == steps - 1):
                rec = self._eval_record(state, m, t, per_round, eval_batch)
                self._record(history, rec, t)
                if stop_fn is not None and stop_fn(rec):
                    break
        return state, history
