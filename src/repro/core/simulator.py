"""Paper-scale distributed-learning simulator.

Simulates a server + n workers on a single host (the paper's own evaluation
setup, §4): every round, honest workers compute (mini-batch) gradients on
their local shard, the chosen algorithm compresses/attacks/aggregates, and
the server updates the model. One jitted function per round.

This is the engine behind the MNIST-like reproduction (benchmarks/bench_fig1)
and the convergence-comparison benchmarks; the LLM-scale path lives in
``repro/launch`` and shares the same ``core.algorithms`` math.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import algorithms as alg
from repro.core import compression as C
from repro.utils import tree as T


class SimState(NamedTuple):
    params_flat: jnp.ndarray
    server: alg.ServerState
    key: jax.Array


@dataclasses.dataclass
class Simulator:
    """Single-host simulator of Byzantine-robust compressed training.

    Args:
      loss_fn: ``loss_fn(params, batch) -> scalar`` — per-worker local loss.
      params0: initial parameter pytree.
      cfg: algorithm configuration (n_workers, f, attack, compression, ...).
      eval_fn: optional ``eval_fn(params, eval_batch) -> metrics dict``.
    """

    loss_fn: Callable[[Any, Any], jnp.ndarray]
    params0: Any
    cfg: alg.AlgorithmConfig
    eval_fn: Optional[Callable[[Any, Any], Dict[str, jnp.ndarray]]] = None

    def __post_init__(self):
        self.spec = T.make_flat_spec(self.params0)
        self.d = self.spec.size

        def _round(state: SimState, worker_batches) -> Tuple[SimState, dict]:
            key, mask_key = jax.random.split(state.key)
            params = T.tree_unravel(state.params_flat, self.spec)

            def worker_grad(batch):
                l, g = jax.value_and_grad(self.loss_fn)(params, batch)
                return l, T.tree_ravel(g, self.spec)

            losses, grads = jax.vmap(worker_grad)(worker_batches)
            r, server, aux = alg.server_round(self.cfg, state.server, grads,
                                              mask_key)
            new_flat = alg.apply_direction(state.params_flat, r,
                                           self.cfg.gamma)
            metrics = {
                "loss": jnp.mean(losses[self.cfg.f:]),  # honest mean loss
                "grad_norm": jnp.linalg.norm(jnp.mean(grads[self.cfg.f:],
                                                      axis=0)),
                "dir_norm": jnp.linalg.norm(r),
            }
            return SimState(new_flat, server, key), metrics

        self._round = jax.jit(_round)

    def init(self, seed: int = 0) -> SimState:
        return SimState(
            params_flat=T.tree_ravel(self.params0, self.spec),
            server=alg.init_state(self.cfg, self.spec.padded_size),
            key=jax.random.PRNGKey(seed),
        )

    def params(self, state: SimState) -> Any:
        return T.tree_unravel(state.params_flat, self.spec)

    def payload_bytes_per_round(self) -> int:
        """Total honest uplink bytes per round (the paper's comm-cost metric).

        The paper counts communication of all n workers (the server cannot
        know who is honest); we follow that convention."""
        per = C.payload_bytes(self.d, self.cfg.sparsifier, bytes_per_value=4,
                              with_mask_indices=True)
        return per * self.cfg.n_workers

    def run(self, state: SimState, batch_fn: Callable[[int], Any],
            steps: int, eval_every: int = 0, eval_batch: Any = None,
            stop_fn: Optional[Callable[[Dict[str, float]], bool]] = None,
            ) -> Tuple[SimState, Dict[str, list]]:
        """Run ``steps`` rounds.

        ``batch_fn(t)`` must return stacked per-worker batches with leading
        dim ``n_workers``. ``stop_fn(metrics)`` can end training early (used
        by the communication-cost-to-threshold benchmark).
        """
        history: Dict[str, list] = {"step": [], "loss": [], "comm_bytes": []}
        per_round = self.payload_bytes_per_round()
        for t in range(steps):
            state, m = self._round(state, batch_fn(t))
            if eval_every and (t % eval_every == 0 or t == steps - 1):
                rec = {k: float(v) for k, v in m.items()}
                rec["comm_bytes"] = per_round * (t + 1)
                if self.eval_fn is not None and eval_batch is not None:
                    emet = self.eval_fn(self.params(state), eval_batch)
                    rec.update({k: float(v) for k, v in emet.items()})
                history["step"].append(t)
                history["loss"].append(rec["loss"])
                history["comm_bytes"].append(rec["comm_bytes"])
                for k, v in rec.items():
                    if k not in ("loss", "comm_bytes"):
                        history.setdefault(k, []).append(v)
                if stop_fn is not None and stop_fn(rec):
                    break
        return state, history
