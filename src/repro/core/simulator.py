"""Paper-scale distributed-learning simulator.

Simulates a server + n workers on a single host (the paper's own evaluation
setup, §4): every round, honest workers compute (mini-batch) gradients on
their local shard, the chosen algorithm compresses/attacks/aggregates, and
the server updates the model.

The engine is a single ``lax.scan`` over rounds (:meth:`Simulator.rollout`):
the whole trajectory runs inside one jitted XLA program with metrics stacked
on device, so sweeping the paper's attack x aggregator x algorithm x seed
grids (``repro.core.sweep``) pays host-side dispatch once per *grid*
instead of once per round. Eval lives inside the scan too: parameter
snapshots are written into a carried ``[n_evals, D]`` buffer at eval rounds
(:meth:`Simulator.rollout_with_snapshots`) and all eval rounds are evaluated
afterwards in ONE vmapped call, so :meth:`Simulator.run` is a single compiled
program regardless of the eval schedule (the old chunked wrapper paid one
compile per distinct chunk length — ``{1, eval_every, remainder}``).
:meth:`Simulator.run_per_round` retains the original one-dispatch-per-round
loop as the equivalence/benchmark reference.

This is the engine behind the MNIST-like reproduction (benchmarks/bench_fig1)
and the convergence-comparison benchmarks; the LLM-scale path lives in
``repro/launch`` and shares the same ``core.algorithms`` math.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators as G
from repro.core import algorithms as alg
from repro.data import stream as DS
from repro.utils import tree as T


class SimState(NamedTuple):
    params_flat: jnp.ndarray
    server: alg.ServerState
    key: jax.Array


#: Sanity ceiling on the host-side footprint :func:`stack_batches` will
#: materialise before refusing (2 GiB). Past MNIST-CNN scale the right tool
#: is the O(prefetch_depth) streaming path — see
#: :meth:`Simulator.rollout_streaming` / ``repro.data.stream``. Override
#: per-call with ``max_bytes=`` or globally with the
#: ``REPRO_STACK_BYTES_LIMIT`` env var (``0`` disables the check).
STACK_BYTES_LIMIT = 2 * 1024 ** 3


def _stack_limit(max_bytes: Optional[int]) -> int:
    if max_bytes is not None:
        return max_bytes
    env = os.environ.get("REPRO_STACK_BYTES_LIMIT")
    return int(env) if env is not None else STACK_BYTES_LIMIT


def stack_batches(batch_fn: Callable[[int], Any], steps: int,
                  start: int = 0, max_bytes: Optional[int] = None) -> Any:
    """Materialise ``batch_fn(start) .. batch_fn(start+steps-1)`` stacked on a
    leading step axis, ready for :meth:`Simulator.rollout`'s scan.

    Stateful ``batch_fn`` implementations (e.g. ``data.BatchFn``) are called
    in step order, so chunked stacking reproduces the same stream as the
    legacy per-round loop.

    Raises ``ValueError`` (instead of silently OOM-ing the host) when the
    estimated footprint ``steps * batch_bytes`` exceeds the sanity limit
    (``max_bytes`` if given, else ``REPRO_STACK_BYTES_LIMIT``, else
    :data:`STACK_BYTES_LIMIT`); the message points at the O(prefetch_depth)
    streaming path (:meth:`Simulator.rollout_streaming`).
    """
    limit = _stack_limit(max_bytes)
    per_step: List[Any] = []
    for i, t in enumerate(range(start, start + steps)):
        b = batch_fn(t)
        if i == 0 and limit:
            per = DS.batch_bytes(b)
            est = per * steps
            if est > limit:
                raise ValueError(
                    f"stack_batches would materialise ~{est / 1e9:.2f} GB "
                    f"host-side ({steps} steps x {per} bytes/step), over the "
                    f"{limit / 1e9:.2f} GB sanity limit. Stream the batches "
                    "instead — Simulator.rollout_streaming / "
                    "repro.data.stream.ChunkPrefetcher hold only "
                    "O(prefetch_depth) chunks — or raise the limit via "
                    "max_bytes= / REPRO_STACK_BYTES_LIMIT (0 disables).")
        per_step.append(b)
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *per_step)


def ensure_stacked(batches: Any, steps: Optional[int]) -> Any:
    """Normalise a rollout's ``batches`` argument: materialise a ``batch_fn``
    callable into a step-stacked pytree, pass stacked pytrees through."""
    if callable(batches):
        if steps is None:
            raise ValueError("steps is required when batches is callable")
        return stack_batches(batches, steps)
    return batches


@dataclasses.dataclass
class Simulator:
    """Single-host simulator of Byzantine-robust compressed training.

    Args:
      loss_fn: ``loss_fn(params, batch) -> scalar`` — per-worker local loss.
      params0: initial parameter pytree.
      cfg: algorithm configuration (n_workers, f, attack, compression, ...).
      eval_fn: optional ``eval_fn(params, eval_batch) -> metrics dict``.
    """

    loss_fn: Callable[[Any, Any], jnp.ndarray]
    params0: Any
    cfg: alg.AlgorithmConfig
    eval_fn: Optional[Callable[[Any, Any], Dict[str, jnp.ndarray]]] = None

    def __post_init__(self):
        self.spec = T.make_flat_spec(self.params0)
        self.d = self.spec.size
        # resolved aggregation backend ("jnp" | "pallas" |
        # "pallas-interpret") — which implementation the round body's
        # aggregator dispatches to, surfaced for logs/benches
        self.agg_backend = G.kernel_backend_label(
            self.cfg.aggregator.use_pallas)
        # Number of times the round body has been traced: jit compiles trace
        # exactly once, so this counts distinct XLA programs built through
        # this Simulator (the one-program-per-grid acceptance check in
        # benchmarks/bench_sweep.py reads it).
        self.round_traces = 0

        def _round(state: SimState, worker_batches, attack_params=None,
                   scenario=None) -> Tuple[SimState, dict]:
            self.round_traces += 1  # trace-time (python) side effect only
            key, mask_key = jax.random.split(state.key)
            params = T.tree_unravel(state.params_flat, self.spec)

            def worker_grad(batch):
                l, g = jax.value_and_grad(self.loss_fn)(params, batch)
                return l, T.tree_ravel(g, self.spec)

            losses, grads = jax.vmap(worker_grad)(worker_batches)
            r, server, aux = alg.server_round(self.cfg, state.server, grads,
                                              mask_key,
                                              attack_params=attack_params,
                                              scenario=scenario)
            # per-cell step size: a fused bank carries gamma as traced data
            gamma = self.cfg.gamma
            if scenario is not None and scenario.gamma is not None:
                gamma = scenario.gamma
            new_flat = alg.apply_direction(state.params_flat, r, gamma)
            metrics = {
                "loss": jnp.mean(losses[self.cfg.f:]),  # honest mean loss
                "grad_norm": jnp.linalg.norm(jnp.mean(grads[self.cfg.f:],
                                                      axis=0)),
                "dir_norm": jnp.linalg.norm(r),
            }
            return SimState(new_flat, server, key), metrics

        def _scan(state: SimState, batches, attack_params=None,
                  scenario=None) -> Tuple[SimState, dict]:
            return jax.lax.scan(
                lambda s, b: _round(s, b, attack_params, scenario),
                state, batches)

        def _snap_scan(state: SimState, batches, eval_mask, snaps0,
                       attack_params=None, scenario=None
                       ) -> Tuple[SimState, dict, jnp.ndarray]:
            """Scan with an in-scan eval-snapshot carry.

            ``eval_mask`` is a ``[steps]`` bool vector; at rounds where it is
            set, the post-update ``params_flat`` is written into the next
            free row of the carried ``snaps0`` buffer (``[n_evals, D]``).
            All eval rounds are then evaluated post-hoc in one vmapped call
            — no scan breaks, no chunk-boundary recompiles.
            """
            def step(carry, inp):
                st, buf, slot = carry
                batch, is_eval = inp
                new_st, m = _round(st, batch, attack_params, scenario)
                buf = jax.lax.cond(
                    is_eval,
                    lambda b: jax.lax.dynamic_update_slice_in_dim(
                        b, new_st.params_flat[None].astype(b.dtype), slot,
                        axis=0),
                    lambda b: b, buf)
                return (new_st, buf, slot + is_eval.astype(jnp.int32)), m

            (st, buf, _), ms = jax.lax.scan(
                step, (state, snaps0, jnp.zeros((), jnp.int32)),
                (batches, eval_mask))
            return st, ms, buf

        self._round = jax.jit(_round)
        # un-jitted round/scan kept separate so repro.core.sweep can vmap
        # them over the grid fusion axes (seed / attack-coefficient /
        # aggregator index / ratio) before compiling, and so the streaming
        # while-loop-of-scan-chunks program can embed the same round body
        self._round_unjit = _round
        self._scan = _scan
        self._rollout = jax.jit(_scan)
        self._snap_rollout = jax.jit(_snap_scan)
        # jitted sweep entry points, cached per vmap structure so repeated
        # grid calls don't re-trace
        self._sweep_cache: dict = {}

    def init(self, seed: int = 0) -> SimState:
        """Fresh :class:`SimState`; the server carry takes the shape of
        ``cfg.resolved_state_layout()`` — dasha-free configs scan a
        momentum-only ``ServerState`` (no mirror/prev_grad leaves), so the
        rollout never pays DASHA's state width for algorithms that don't
        use it (:func:`repro.core.algorithms.server_state_bytes`)."""
        return SimState(
            params_flat=T.tree_ravel(self.params0, self.spec),
            server=alg.init_state(self.cfg, self.spec.padded_size),
            key=jax.random.PRNGKey(seed),
        )

    def params(self, state: SimState) -> Any:
        return T.tree_unravel(state.params_flat, self.spec)

    def state_layout(self) -> alg.StateLayout:
        """The carry layout this simulator scans (see :meth:`init`)."""
        return self.cfg.resolved_state_layout()

    def server_state_bytes(self) -> int:
        """On-device bytes of the scanned ``ServerState`` banks under the
        resolved layout — the per-algorithm memory accounting behind the
        paper's RoSDHB-vs-Byz-DASHA-PAGE claim."""
        return alg.server_state_bytes(self.cfg, self.spec.padded_size)

    def payload_bytes_per_round(self) -> int:
        """Total uplink bytes per round (the paper's comm-cost metric) under
        this algorithm's ACTUAL wire format
        (:func:`repro.core.algorithms.algo_payload_bytes`: rosdhb/dgd send
        sparsified gradients, dasha compressed differences with indices,
        robust_dgd raw gradients).

        The paper counts communication of all n workers (the server cannot
        know who is honest); we follow that convention. Raises ``ValueError``
        for bank configs — a bank mixes wire formats; account per cell."""
        per = alg.algo_payload_bytes(self.cfg, self.d, bytes_per_value=4)
        return per * self.cfg.n_workers

    def rollout(self, state: SimState, batches: Any,
                steps: Optional[int] = None) -> Tuple[SimState, dict]:
        """Run a whole trajectory inside one jitted ``lax.scan``.

        ``batches`` is either a pytree whose leaves carry a leading step axis
        (``[steps, n_workers, ...]``, see :func:`stack_batches`) or a
        ``batch_fn(t)`` callable (then ``steps`` is required and the batches
        are materialised host-side first).

        Returns ``(final_state, metrics)`` where ``metrics`` is a dict of
        ``[steps]`` arrays stacked on device. There is no early stopping —
        the scan always runs every round; threshold crossings (the paper's
        comm-bytes-to-tau protocol) are computed post-hoc, e.g. with
        :func:`repro.core.sweep.bytes_to_threshold`.
        """
        return self._rollout(state, ensure_stacked(batches, steps))

    def rollout_with_snapshots(self, state: SimState, batches: Any,
                               eval_rounds: Any,
                               steps: Optional[int] = None
                               ) -> Tuple[SimState, dict, jnp.ndarray]:
        """One-scan trajectory that also returns parameter snapshots.

        ``eval_rounds`` is a sequence of round indices; the returned
        ``snaps`` array is ``[len(eval_rounds), D]`` holding ``params_flat``
        *after* each listed round (the legacy eval protocol). The snapshot
        write is a masked in-scan ``dynamic_update_slice`` — the scan never
        breaks, so the whole trajectory (eval included) is ONE compiled
        program.
        """
        batches = ensure_stacked(batches, steps)
        n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
        eval_rounds = np.asarray(eval_rounds, np.int64)
        if (eval_rounds.ndim != 1 or np.any(np.diff(eval_rounds) <= 0)
                or (eval_rounds.size
                    and (eval_rounds[0] < 0 or eval_rounds[-1] >= n_steps))):
            # rows are written chronologically by a slot counter, so an
            # unsorted/duplicated schedule (or a wrapping negative index)
            # would silently misalign snaps[i]
            raise ValueError(
                "eval_rounds must be strictly increasing round indices in "
                f"[0, {n_steps}), got {eval_rounds}")
        mask = np.zeros((n_steps,), bool)
        mask[eval_rounds] = True
        snaps0 = jnp.zeros((len(eval_rounds), self.spec.padded_size),
                           jnp.float32)
        return self._snap_rollout(state, batches, jnp.asarray(mask), snaps0)

    # ------------------------------------------------------------------ #
    # streaming rollout: while-loop over scan chunks from a ring buffer
    # ------------------------------------------------------------------ #

    def _metric_struct(self, state: SimState, one_batch: Any,
                       scenario=None) -> Dict[str, Any]:
        """Abstract shapes of the per-round metrics dict (cached — the
        ``eval_shape`` trace counts once in ``round_traces``)."""
        key = ("stream_metric_struct", scenario is not None)
        if key not in self._sweep_cache:
            # scenario (one lane's traced ScenarioParams, or None) is closed
            # over: bank configs need it to trace the round body at all
            self._sweep_cache[key] = jax.eval_shape(
                lambda s, b: self._round_unjit(s, b, None, scenario)[1],
                state, one_batch)
        return self._sweep_cache[key]

    def _stream_raw(self, chunk_size: int, metric: str, mode: str,
                    use_eval: bool) -> Callable:
        """Build (and cache) the un-jitted while-loop-of-scan-chunks body.

        The returned ``run_buffer(state, buf, n_valid, tau, eval_batch,
        metrics0, scenario)`` consumes a device ring buffer ``buf`` whose
        leaves are ``[depth, chunk_size, n_workers, ...]``: a
        ``lax.while_loop`` scans one chunk per iteration (the identical
        round body as :meth:`rollout` — bit-for-bit the reference path),
        writes the chunk's per-round metrics into the carried
        ``[depth * chunk_size]`` buffers, then evaluates the early-exit
        metric and stops once it crosses ``tau`` (``mode`` ``'>='`` or
        ``'<='``). Left un-jitted so the sweep engine can vmap it over the
        flat grid axis before compiling (``lax.while_loop``'s batching rule
        freezes finished lanes, so per-lane early exit is preserved).
        """
        key_ = ("stream_raw", chunk_size, metric, mode, use_eval)
        if key_ in self._sweep_cache:
            return self._sweep_cache[key_]
        if mode not in (">=", "<="):
            raise ValueError(f"tau_mode must be '>=' or '<=', got {mode!r}")

        def run_buffer(state, buf, n_valid, tau, eval_batch, metrics0,
                       scenario=None):
            def chunk_metric(st, ms):
                if use_eval:
                    em = self.eval_fn(T.tree_unravel(st.params_flat,
                                                     self.spec), eval_batch)
                    return jnp.asarray(em[metric], jnp.float32)
                return jnp.asarray(ms[metric][-1], jnp.float32)

            def cond(carry):
                st, i, done, bufs, last = carry
                return (i < n_valid) & jnp.logical_not(done)

            def body(carry):
                st, i, done, bufs, last = carry
                cb = jax.tree_util.tree_map(
                    lambda l: jax.lax.dynamic_index_in_dim(
                        l, i, 0, keepdims=False), buf)
                st2, ms = jax.lax.scan(
                    lambda s, b: self._round_unjit(s, b, None, scenario),
                    st, cb)
                bufs = {k: jax.lax.dynamic_update_slice_in_dim(
                    bufs[k], ms[k].astype(bufs[k].dtype), i * chunk_size,
                    axis=0) for k in bufs}
                ev = chunk_metric(st2, ms)
                hit = (ev >= tau) if mode == ">=" else (ev <= tau)
                return (st2, i + 1, hit, bufs, ev)

            init = (state, jnp.zeros((), jnp.int32), jnp.zeros((), bool),
                    metrics0, jnp.full((), jnp.nan, jnp.float32))
            st, i, done, bufs, last = jax.lax.while_loop(cond, body, init)
            return st, bufs, i, done, last

        self._sweep_cache[key_] = run_buffer
        return run_buffer

    def rollout_streaming(self, state: SimState, batches: Any,
                          steps: Optional[int] = None, *,
                          chunk_size: int = 32, prefetch_depth: int = 4,
                          tau: Optional[float] = None,
                          tau_metric: Optional[str] = None,
                          tau_mode: Optional[str] = None,
                          eval_batch: Any = None
                          ) -> Tuple[SimState, Dict[str, np.ndarray],
                                     Dict[str, Any]]:
        """Streaming trajectory: prefetched ring buffer + chunked early exit.

        The O(steps) host materialisation of :meth:`rollout` is replaced by
        a host prefetch thread (``repro.data.stream.ChunkPrefetcher``) that
        device-puts ``chunk_size``-round chunks into a fixed-depth ring
        buffer; the rollout consumes up to ``prefetch_depth`` chunks per
        dispatch inside ONE jitted ``lax.while_loop``-over-scan-chunks
        program (the scan body is the identical round body — with ``tau``
        unset the trajectory is bit-for-bit :meth:`rollout`'s). Host-side
        residency is O(prefetch_depth * chunk_bytes) regardless of
        trajectory length.

        Early exit: after each chunk the carried eval metric is compared
        against ``tau`` — ``eval_fn(params, eval_batch)[tau_metric]`` when
        ``eval_batch`` is given (default metric ``'acc'``, mode ``'>='``),
        else the chunk's last per-round ``tau_metric`` (default ``'loss'``,
        mode ``'<='``). The loop stops at the first chunk boundary past the
        crossing, so unlike the post-hoc :func:`sweep.bytes_to_threshold`
        protocol the remaining rounds are never computed.

        ``batches`` is a ``batch_fn(t)`` callable (streamed; ``steps``
        required) or a pre-stacked ``[steps, ...]`` pytree (chunked and
        device-put chunk-by-chunk — useful for parity tests). A tail of
        ``steps % chunk_size`` rounds runs through the fixed-length
        :meth:`rollout` program on the final state.

        Returns ``(final_state, metrics, info)``: ``metrics`` holds
        ``[rounds_run]`` host arrays (truncated at early exit), ``info``
        reports ``rounds_run`` / ``early_exit`` / ``last_metric`` /
        ``dispatches`` / ``chunk_bytes`` / ``host_high_water_bytes`` /
        ``device_buffer_bytes``.
        """
        if chunk_size <= 0 or prefetch_depth <= 0:
            raise ValueError("chunk_size and prefetch_depth must be positive")
        if callable(batches):
            if steps is None:
                raise ValueError("steps is required when batches is callable")
            source: Any = DS.ChunkPrefetcher(batches, steps, chunk_size,
                                             prefetch_depth)
            tail_fn = batches
            stacked = None
        else:
            n_avail = jax.tree_util.tree_leaves(batches)[0].shape[0]
            steps = n_avail if steps is None else min(steps, n_avail)
            stacked = batches
            source = DS.StackedChunkSource(batches, steps, chunk_size)
            tail_fn = None
        n_chunks = steps // chunk_size
        remainder = steps % chunk_size

        use_eval = (tau is not None and eval_batch is not None
                    and self.eval_fn is not None)
        metric = tau_metric or ("acc" if use_eval else "loss")
        mode = tau_mode or (">=" if use_eval else "<=")
        # a never-crossed sentinel: '>=' can't reach +inf, '<=' can't reach
        # -inf, so tau=None runs the full fixed length
        disabled = jnp.inf if mode == ">=" else -jnp.inf
        tau_arr = jnp.float32(tau if tau is not None else disabled)
        eval_in = eval_batch if use_eval else jnp.zeros((), jnp.float32)

        metrics_parts: List[Dict[str, np.ndarray]] = []
        early = False
        last_metric = float("nan")
        dispatches = 0
        chunks_done = 0
        metrics0 = None
        prog_key = ("stream_jit", chunk_size, metric, mode, use_eval)
        try:
            while chunks_done < n_chunks and not early:
                chunks = source.take(prefetch_depth)
                if not chunks:
                    break
                n_valid = len(chunks)
                buf = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                             *chunks)
                if n_valid < prefetch_depth:
                    # pad the buffer to the fixed depth (never consumed:
                    # the while-loop stops at n_valid)
                    buf = jax.tree_util.tree_map(
                        lambda l: jnp.concatenate(
                            [l] + [l[-1:]] * (prefetch_depth - n_valid),
                            axis=0), buf)
                if metrics0 is None:
                    one = jax.tree_util.tree_map(lambda l: l[0, 0], buf)
                    struct = self._metric_struct(state, one)
                    metrics0 = {k: jnp.zeros((prefetch_depth * chunk_size,),
                                             v.dtype)
                                for k, v in struct.items()}
                if prog_key not in self._sweep_cache:
                    self._sweep_cache[prog_key] = jax.jit(
                        self._stream_raw(chunk_size, metric, mode, use_eval))
                state, bufs, i_done, done, last = self._sweep_cache[prog_key](
                    state, buf, n_valid, tau_arr, eval_in, metrics0)
                dispatches += 1
                i_done = int(i_done)
                early = bool(done)
                last_metric = float(last)
                rounds = i_done * chunk_size
                metrics_parts.append(
                    {k: np.asarray(v[:rounds]) for k, v in bufs.items()})
                chunks_done += i_done
        finally:
            if hasattr(source, "close"):
                source.close()

        if remainder and not early:
            if tail_fn is not None:
                tail = stack_batches(tail_fn, remainder,
                                     start=n_chunks * chunk_size)
            else:
                tail = jax.tree_util.tree_map(
                    lambda l: l[n_chunks * chunk_size:steps], stacked)
            state, ms = self._rollout(state, tail)
            metrics_parts.append({k: np.asarray(v) for k, v in ms.items()})

        if metrics_parts:
            metrics = {k: np.concatenate([p[k] for p in metrics_parts])
                       for k in metrics_parts[0]}
        else:
            metrics = {}
        rounds_run = int(next(iter(metrics.values())).shape[0]) \
            if metrics else 0
        chunk_bytes = getattr(source, "chunk_bytes", 0)
        info = {
            "rounds_run": rounds_run,
            "early_exit": early,
            "last_metric": last_metric,
            "tau": tau,
            "tau_metric": metric,
            "tau_mode": mode,
            "dispatches": dispatches,
            "chunk_size": chunk_size,
            "prefetch_depth": prefetch_depth,
            "chunk_bytes": chunk_bytes,
            "host_high_water_bytes": getattr(source, "high_water_bytes", 0),
            "device_buffer_bytes": prefetch_depth * chunk_bytes,
        }
        return state, metrics, info

    def _record(self, history: Dict[str, list], rec: Dict[str, float],
                t: int) -> None:
        history["step"].append(t)
        history["loss"].append(rec["loss"])
        history["comm_bytes"].append(rec["comm_bytes"])
        for k, v in rec.items():
            if k not in ("loss", "comm_bytes"):
                history.setdefault(k, []).append(v)

    def _eval_record(self, state: SimState, m: Dict[str, Any], t: int,
                     per_round: int, eval_batch: Any) -> Dict[str, float]:
        rec = {k: float(v) for k, v in m.items()}
        rec["comm_bytes"] = per_round * (t + 1)
        if self.eval_fn is not None and eval_batch is not None:
            emet = self.eval_fn(self.params(state), eval_batch)
            rec.update({k: float(v) for k, v in emet.items()})
        return rec

    def run(self, state: SimState, batch_fn: Callable[[int], Any],
            steps: int, eval_every: int = 0, eval_batch: Any = None,
            stop_fn: Optional[Callable[[Dict[str, float]], bool]] = None,
            ) -> Tuple[SimState, Dict[str, list]]:
        """Run ``steps`` rounds as ONE compiled scan, eval included.

        ``batch_fn(t)`` must return stacked per-worker batches with leading
        dim ``n_workers`` (a pre-stacked pytree is accepted too).

        Eval rounds (``t % eval_every == 0`` or the final step) no longer
        break the scan: parameter snapshots are carried through the scan
        (:meth:`rollout_with_snapshots`) and every eval round is evaluated
        in a single vmapped ``eval_fn`` call afterwards, so the eval
        schedule and history contents match :meth:`run_per_round` while the
        whole trajectory pays exactly one compile (the old chunked wrapper
        paid one per distinct chunk length: ``{1, eval_every, remainder}``).

        ``stop_fn(metrics)`` is honoured post-hoc: the history is truncated
        at the first eval record where it fires, matching the legacy early
        stop, but the scan itself always runs every round and the returned
        state is the final-round state. Threshold protocols should read the
        crossing from the history (or ``sweep.bytes_to_threshold``), not
        from the returned state.
        """
        history: Dict[str, list] = {"step": [], "loss": [], "comm_bytes": []}
        per_round = self.payload_bytes_per_round()
        if steps <= 0:
            return state, history
        if not eval_every:
            state, _ = self.rollout(state, batch_fn, steps)
            return state, history
        eval_rounds = [t for t in range(steps)
                       if t % eval_every == 0 or t == steps - 1]
        batches = ensure_stacked(batch_fn, steps)
        emets: Dict[str, np.ndarray] = {}
        if self.eval_fn is not None and eval_batch is not None:
            state, ms, snaps = self.rollout_with_snapshots(state, batches,
                                                           eval_rounds)
            if "snap_eval" not in self._sweep_cache:
                def eval_snap(flat, batch):
                    return self.eval_fn(T.tree_unravel(flat, self.spec),
                                        batch)

                self._sweep_cache["snap_eval"] = jax.jit(
                    jax.vmap(eval_snap, in_axes=(0, None)))
            emets = {k: np.asarray(v) for k, v in
                     self._sweep_cache["snap_eval"](snaps, eval_batch).items()}
        else:
            # nothing to evaluate: skip the snapshot carry entirely (the
            # per-round metrics already hold everything the history needs)
            state, ms = self._rollout(state, batches)
        ms = {k: np.asarray(v) for k, v in ms.items()}
        for i, t in enumerate(eval_rounds):
            rec = {k: float(v[t]) for k, v in ms.items()}
            rec["comm_bytes"] = per_round * (t + 1)
            rec.update({k: float(v[i]) for k, v in emets.items()})
            self._record(history, rec, t)
            if stop_fn is not None and stop_fn(rec):
                break
        return state, history

    def run_per_round(self, state: SimState, batch_fn: Callable[[int], Any],
                      steps: int, eval_every: int = 0, eval_batch: Any = None,
                      stop_fn: Optional[Callable[[Dict[str, float]], bool]]
                      = None) -> Tuple[SimState, Dict[str, list]]:
        """Legacy engine: one jitted dispatch per round.

        Kept as the numerical-equivalence reference for the scan engine
        (tests/test_engine.py) and as the sequential baseline for
        benchmarks/bench_sweep.py.
        """
        history: Dict[str, list] = {"step": [], "loss": [], "comm_bytes": []}
        per_round = self.payload_bytes_per_round()
        for t in range(steps):
            state, m = self._round(state, batch_fn(t))
            if eval_every and (t % eval_every == 0 or t == steps - 1):
                rec = self._eval_record(state, m, t, per_round, eval_batch)
                self._record(history, rec, t)
                if stop_fn is not None and stop_fn(rec):
                    break
        return state, history
