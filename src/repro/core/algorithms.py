"""Server-side distributed learning algorithms on flat gradient banks.

Everything here operates on flat stacked vectors ``[n_workers, D]`` — the
launcher (``repro/launch``) is responsible for producing per-worker gradients
from the sharded model and for resharding; these functions are pure math and
are shared between the paper-scale simulator and the LLM-scale pjit path.

Algorithms:
  * ``rosdhb``       — the paper's Algorithm 1 (global or local sparsification
                       chosen by the sparsifier config).
  * ``dasha``        — Byz-DASHA-PAGE [29] with p=1 (full-gradient PAGE
                       branch): per-worker MVR momentum + compressed-difference
                       server mirrors + robust aggregation.
  * ``robust_dgd``   — robust DGD, no compression (SOTA-without-compression
                       corner, [3]).
  * ``dgd``          — plain compressed DGD, non-robust (SOTA-without-
                       robustness corner, [1]).
  * ``bank``         — the switch-based **algorithm bank**
                       (:func:`make_algorithm_bank`): a ``lax.switch`` over
                       the four update rules above, selected per grid cell by
                       the traced ``ScenarioParams.algo_idx`` — the paper's
                       whole Table-1 cross-algorithm comparison as ONE
                       compiled XLA program (see ``repro.core.sweep``).

The Byzantine adversary is simulated *on the wire quantity* each algorithm
actually transmits: compressed gradients for rosdhb/dgd, raw gradients for
robust_dgd, compressed differences (applied at the mirror level) for dasha.
:func:`algo_payload_bytes` accounts for those wire formats individually.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as A
from repro.core import aggregators as G
from repro.core import compression as C
from repro.core import wire as W


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StateLayout:
    """Which optional ``ServerState`` slots a program's carry materialises.

    The ``mirror``/``prev_grad`` banks exist only for DASHA's
    variance-reduction state (Byz-DASHA-PAGE carries a server-side gradient
    mirror h_i and the previous-round gradients for its MVR correction);
    RoSDHB and the DGD variants never read them. Carrying them anyway costs
    ``n*D`` momentum-dtype + ``n*D`` f32 floats per trajectory — exactly the
    per-client memory overhead the paper's comparison charges DASHA and NOT
    RoSDHB — so the plan layer prunes the slots whenever a program provably
    contains no dasha cell (:meth:`for_algorithms`), and keeps the full
    width for mixed banks. Pruned slots are ``None`` in the state pytree
    (no leaves), which is bit-for-bit neutral: the non-dasha update rules
    pass the slots through untouched either way (property-tested in
    tests/test_state_layout.py).
    """

    mirror: bool = True
    prev_grad: bool = True

    @classmethod
    def full(cls) -> "StateLayout":
        """Every slot materialised (the pre-specialisation padded layout)."""
        return cls(mirror=True, prev_grad=True)

    @classmethod
    def pruned(cls) -> "StateLayout":
        """The dasha-free layout: mirror/prev_grad dropped from the carry."""
        return cls(mirror=False, prev_grad=False)

    @classmethod
    def for_algorithms(cls, names: Sequence[str]) -> "StateLayout":
        """The minimal layout for a program running exactly ``names``:
        full width iff any branch is dasha."""
        needs = "dasha" in tuple(names)
        return cls(mirror=needs, prev_grad=needs)

    @property
    def is_full(self) -> bool:
        return self.mirror and self.prev_grad


@dataclasses.dataclass(frozen=True)
class AlgorithmConfig:
    """Full specification of a Byzantine-robust compressed training run.

    Attributes:
      name: ``rosdhb`` | ``dasha`` | ``robust_dgd`` | ``dgd`` | ``bank``
        (the switch-based algorithm bank; branch selected per grid cell by a
        traced ``ScenarioParams.algo_idx``, see :func:`make_algorithm_bank`).
      n_workers: total workers n.
      f: number of Byzantine workers (the first ``f`` indices).
      gamma: learning rate.
      beta: momentum coefficient; ``None`` -> Theorem 1 schedule
        ``sqrt(1 - 24 gamma L)`` using ``smoothness_L``.
      smoothness_L: Lipschitz constant estimate used by the beta schedule.
      mvr_a: DASHA's MVR coefficient ``a`` (only for ``dasha``).
      sparsifier: compression config.
      aggregator: robust-aggregation config.
      attack: Byzantine strategy.
      momentum_dtype: dtype of the server momentum bank (f32 default;
        bf16/fp8 are beyond-paper memory optimizations, see DESIGN §3).
      server_compute_dtype: dtype the server does its momentum/aggregation
        math in (f32 default; bf16 halves the per-round transient at LLM
        scale — a beyond-paper optimization ablated in EXPERIMENTS §Perf).
      bank: algorithm-branch tuple when ``name='bank'`` (``None`` means the
        full :data:`ALGO_BANK`). Per-cell hyperparameters (momentum beta,
        DASHA's ``a``, the step size) then arrive as traced
        ``ScenarioParams`` data, not from this config.
      state_layout: explicit :class:`StateLayout` override, or ``None``
        (default) for the plan-time automatic layout — pruned
        mirror/prev_grad slots whenever this config provably runs no dasha
        branch (:meth:`resolved_state_layout`). Forcing
        ``StateLayout.full()`` reproduces the legacy padded carry exactly
        (the parity baseline for the specialisation property tests).
    """

    name: str = "rosdhb"
    n_workers: int = 10
    f: int = 0
    gamma: float = 0.05
    beta: Optional[float] = 0.9
    smoothness_L: float = 1.0
    mvr_a: Optional[float] = None
    sparsifier: C.SparsifierConfig = dataclasses.field(
        default_factory=C.SparsifierConfig)
    aggregator: G.AggregatorConfig = dataclasses.field(
        default_factory=G.AggregatorConfig)
    attack: A.AttackConfig = dataclasses.field(
        default_factory=lambda: A.AttackConfig(name="none"))
    momentum_dtype: str = "float32"
    server_compute_dtype: str = "float32"
    clip_norm: Optional[float] = None  # per-worker L2 clip before compression
    bank: Optional[Tuple[str, ...]] = None
    state_layout: Optional[StateLayout] = None

    @property
    def honest(self) -> int:
        return self.n_workers - self.f

    def algorithms(self) -> Tuple[str, ...]:
        """The algorithm branches this config can execute: the bank's entry
        set for ``name='bank'``, else the single static algorithm."""
        if self.name == "bank":
            return tuple(self.bank) if self.bank else ALGO_BANK
        return (self.name,)

    def resolved_state_layout(self) -> StateLayout:
        """The carry layout this config runs under: the explicit
        ``state_layout`` if set, else the minimal layout for its algorithm
        branches (mirror/prev_grad pruned when no branch is dasha)."""
        if self.state_layout is not None:
            return self.state_layout
        return StateLayout.for_algorithms(self.algorithms())

    def resolved_beta(self) -> float:
        if self.beta is not None:
            return self.beta
        # Theorem 1: beta = sqrt(1 - 24 gamma L), requires gamma <= 1/(24 L).
        val = 1.0 - 24.0 * self.gamma * self.smoothness_L
        if val <= 0.0:
            raise ValueError(
                f"gamma={self.gamma} too large for Theorem-1 beta schedule "
                f"(needs gamma <= 1/(24 L) = {1.0 / (24 * self.smoothness_L)})")
        return math.sqrt(val)

    def resolved_mvr_a(self) -> float:
        """DASHA's MVR coefficient ``a`` (defaults to ``1 - beta``)."""
        if self.mvr_a is not None:
            return self.mvr_a
        return 1.0 - (self.beta if self.beta is not None else 0.9)


def theorem1_hparams(L: float, ratio: float,
                     c: float = 23200.0) -> Tuple[float, float]:
    """Theorem 1's (gamma, beta): gamma = (k/d)/(cL), beta = sqrt(1-24 gamma L).

    The constant c = 23200 is the paper's (very conservative) analysis
    constant; practical runs (the paper's own Section 4 included) use far
    larger gamma with beta = 0.9.
    """
    gamma = ratio / (c * L)
    beta = math.sqrt(1.0 - 24.0 * gamma * L)
    return gamma, beta


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------


class ScenarioParams(NamedTuple):
    """Traced per-cell scenario vector for the fused grid axis.

    Every component is optional (``None`` components contribute no pytree
    leaves, so a ``ScenarioParams`` batch vmaps cleanly whichever subset is
    fused); a present component overrides the corresponding static config:

    ``attack_coeffs``: ``[2]`` attack parameter vector — the linear-family
      ``(a, b)`` coefficients for ``cfg.attack.name == 'linear'`` (see
      ``attacks.linear_attack``), or the per-branch parameter vector of the
      attack bank for ``cfg.attack.name == 'bank'``.
    ``attack_idx``: scalar int32 branch index into the attack bank
      (``repro.adversary.make_attack_bank``; requires
      ``cfg.attack.name == 'bank'``).
    ``agg_idx``: scalar int32 branch index into the aggregator bank
      (``aggregators.make_aggregator_bank``) replacing the static rule.
    ``ratio``: scalar keep-ratio replacing ``cfg.sparsifier.ratio``
      (only for ``compression.TRACED_RATIO_KINDS``).
    ``algo_idx``: scalar int32 branch index into the **algorithm bank**
      (:func:`make_algorithm_bank`; requires ``cfg.name == 'bank'``) — the
      cross-algorithm fusion axis.
    ``hparams``: ``[4]`` per-cell algorithm hyperparameters
      ``(beta, mvr_a, 1-beta, 1-mvr_a)`` — the RoSDHB momentum coefficient
      and DASHA's MVR coefficient as traced data (branches read the slots
      they use and ignore the rest). The complements are carried
      *precomputed* (double-precision at plan time) so the traced branches
      consume exactly the constants the static path folds in — that is what
      keeps bank and standalone trajectories bit-for-bit equal.
    ``gamma``: scalar step size, consumed by the *simulator*'s parameter
      update (``apply_direction``), so cells with different learning rates
      share one compiled program too.
    """

    attack_coeffs: Optional[jnp.ndarray] = None
    attack_idx: Optional[jnp.ndarray] = None
    agg_idx: Optional[jnp.ndarray] = None
    ratio: Optional[jnp.ndarray] = None
    algo_idx: Optional[jnp.ndarray] = None
    hparams: Optional[jnp.ndarray] = None
    gamma: Optional[jnp.ndarray] = None


class ServerState(NamedTuple):
    """Server-side algorithm state — ONE shape per *program* (carry layout
    chosen at plan time, uniform across every cell the program runs).

    ``momentum``: RoSDHB per-worker momentum bank ``[n, D]`` (Algorithm 1,
      step 5) — also reused as DASHA's MVR momentum.
    ``mirror``: DASHA's server-side gradient mirrors ``h_i`` ``[n, D]``;
      ``None`` (no pytree leaves) under a pruned :class:`StateLayout`.
    ``prev_grad``: previous-round per-worker gradients ``[n, D]`` for
      DASHA's MVR correction; ``None`` under a pruned layout.
    ``step``: iteration counter t.
    ``attack``: the adversary's carried memory
      (``repro.adversary.AttackState``) for stateful attacks and attack
      banks; ``None`` (no pytree leaves) for stateless attacks, so legacy
      configs keep their exact state structure.

    When a program DOES carry ``mirror``/``prev_grad`` (any dasha branch
    present, or ``StateLayout.full()`` forced), the slots are *padded but
    inert* for rosdhb/dgd/robust_dgd: their update rules pass both through
    bit-for-bit untouched (property-tested in tests/test_algo_bank.py),
    exactly like the unused slots of the ``AttackState`` slab — which is
    also why pruning them for dasha-free programs cannot change a
    trajectory (tests/test_state_layout.py pins that bit-for-bit). The
    full-width cost, charged only where DASHA actually needs it, is
    ``n*D`` momentum-dtype + ``n*D`` f32 floats per trajectory
    (:func:`server_state_bytes`).
    """

    momentum: jnp.ndarray
    mirror: Optional[jnp.ndarray]
    prev_grad: Optional[jnp.ndarray]
    step: jnp.ndarray
    attack: Optional[Any] = None


def _adversary():
    # local import: repro.adversary.core imports repro.core.attacks, so a
    # module-level import here would be circular
    from repro.adversary import core as adv
    return adv


def _init_attack_state(cfg: AlgorithmConfig, d: int) -> Optional[Any]:
    """Adversary memory slab for stateful attacks / attack banks; ``None``
    (structure-preserving) for the stateless legacy attacks."""
    adv = _adversary()
    if adv.needs_attack_state(cfg.attack.name, cfg.f):
        return adv.init_attack_state(d)
    return None


def init_state(cfg: AlgorithmConfig, d: int) -> ServerState:
    """Initial server state under ``cfg``'s resolved :class:`StateLayout`:
    dasha-free configs (standalone or bank) get the specialised carry with
    ``mirror``/``prev_grad`` pruned to ``None``; any config that can run a
    dasha branch materialises the full width. A pruned layout forced onto a
    dasha-capable config raises loudly (the branch cannot run without its
    variance-reduction state)."""
    n = cfg.n_workers
    if cfg.name != "bank" and cfg.name not in ALGO_STEPS:
        raise ValueError(
            f"unknown algorithm: {cfg.name!r} (expected one of "
            f"{'|'.join(ALGO_BANK)} or 'bank')")
    layout = cfg.resolved_state_layout()
    if "dasha" in cfg.algorithms() and not layout.is_full:
        raise ValueError(
            "state layout prunes mirror/prev_grad but the config can run a "
            f"dasha branch (algorithms={cfg.algorithms()}): dasha's MVR "
            "mirror state cannot be pruned — use StateLayout.full() or drop "
            "dasha from the bank")
    mdt = jnp.dtype(cfg.momentum_dtype)
    zeros = jnp.zeros((n, d), mdt)
    atk = _init_attack_state(cfg, d)
    return ServerState(
        momentum=zeros,
        mirror=zeros if layout.mirror else None,
        prev_grad=jnp.zeros((n, d), jnp.float32) if layout.prev_grad
        else None,
        step=jnp.zeros((), jnp.int32), attack=atk)


# --------------------------------------------------------------------------
# One server round
# --------------------------------------------------------------------------


def _byzantine_overwrite(cfg: AlgorithmConfig, atk_state: Optional[Any],
                         wire: jnp.ndarray, key: jax.Array,
                         attack_params: Optional[jnp.ndarray] = None,
                         attack_idx: Optional[jnp.ndarray] = None
                         ) -> Tuple[jnp.ndarray, Optional[Any]]:
    """Replace rows [0, f) of the wire payload with the attack vectors
    computed from the honest rows [f, n).

    Returns ``(wire, new_attack_state)``.  Dispatch: ``name='bank'`` runs
    the switch-based attack bank (``repro.adversary.make_attack_bank``)
    selected by the traced ``attack_idx``; statically configured *stateful*
    adversaries (mimic/spectral/ipm_greedy) run their registry step with the
    carried ``atk_state``; everything else stays on the stateless legacy
    ``attacks.apply_attack`` path.
    """
    name = cfg.attack.name
    if cfg.f == 0 or name == "none":
        return wire, atk_state
    honest = wire[cfg.f:]
    if name == "bank":
        adv = _adversary()
        if atk_state is None:
            raise ValueError(
                "attack bank needs the adversary memory slab: build the "
                "server state with init_state(cfg, d) (ServerState.attack)")
        entries = cfg.attack.bank or adv.DEFAULT_ATTACK_BANK
        if attack_idx is None or attack_params is None:
            raise ValueError(
                "bank attack needs traced branch selectors: pass a "
                "ScenarioParams with attack_idx and attack_coeffs "
                "(see sweep.FusedBank.scenario_params)")
        atk_state, byz = adv.make_attack_bank(entries, cfg.f)(
            atk_state, honest, key, attack_idx, attack_params)
    else:
        adv = _adversary()
        if adv.is_stateful(name):
            if atk_state is None:
                raise ValueError(
                    f"stateful attack {name!r} needs the adversary memory "
                    "slab: build the server state with init_state(cfg, d) "
                    "(ServerState.attack)")
            coeffs = (attack_params if attack_params is not None
                      else adv.static_coeffs(cfg.attack, cfg.n_workers,
                                             cfg.f))
            atk_state, byz = adv.ADVERSARIES[name].step(
                atk_state, honest, cfg.f, key, coeffs)
        else:
            byz = A.apply_attack(cfg.attack, honest, cfg.f, key=key,
                                 params=attack_params)
    return jnp.concatenate([byz.astype(wire.dtype), honest], axis=0), atk_state


# --------------------------------------------------------------------------
# Per-algorithm update branches (uniform signature — the algorithm bank
# switches between these on a traced index; the static path calls the same
# functions directly, so bank and standalone rounds share ONE code path)
# --------------------------------------------------------------------------

# step(cfg, agg, state, grads, mask_key, atk_key, hparams, attack_params,
#      attack_idx, ratio) -> (direction [D], new ServerState).
# ``hparams`` is indexable as (beta, mvr_a, 1-beta, 1-mvr_a) — a tuple of
# python floats on the static path, a traced [4] vector inside a bank; each
# branch reads the slots it uses. Every branch preserves the uniform
# ServerState structure and leaves the slots it does not own bit-for-bit
# untouched.
#
# Each memoryless branch (rosdhb / dgd / robust_dgd) is split into a WIRE
# half (what the clients jointly put on the uplink: sparsified unbiased
# reconstructions, with Byzantine rows overwritten) and an APPLY half (what
# the server does with a received wire bank: momentum, aggregation, state
# update). The step functions compose the two halves in the original op
# order, so the fused simulator graph is unchanged; the streaming parameter
# server (repro.serve) runs the same halves in separate programs — the
# clients the wire half, the server the apply half — which is what makes
# server <-> simulator trajectories bit-for-bit comparable. The apply
# halves additionally accept a ``present``/``discount`` row masking for
# partial participation + staleness discounting; ``None`` (the simulator
# path) compiles to exactly the legacy graph.
AlgoStepFn = Callable[..., Tuple[jnp.ndarray, ServerState]]


def _compressed_wire(cfg, atk_state, grads, mask_key, atk_key,
                     attack_params=None, attack_idx=None, ratio=None):
    # Steps 1-4: masks (global or local) + unbiased reconstruction, then the
    # Byzantine overwrite on the wire quantity.
    # compressed_estimate dispatches between the jnp sparsifier (identical
    # make_masks + compress graph) and the repro.kernels.randk Block-RandK
    # round trip per SparsifierConfig.use_pallas
    g_tilde = C.compressed_estimate(grads, mask_key, cfg.sparsifier,
                                    ratio=ratio)
    return _byzantine_overwrite(cfg, atk_state, g_tilde, atk_key,
                                attack_params, attack_idx)


def _row_mask(wire, prev, present, discount):
    """Stale-discounted participation masking: rows with ``present`` False
    keep ``prev``; present rows contribute ``discount * wire`` (discount is
    1.0 for fresh updates — an exact multiply, so full participation is
    bit-for-bit the unmasked path)."""
    eff = wire * discount[:, None].astype(wire.dtype)
    return jnp.where(present[:, None], eff, prev)


def _rosdhb_apply(cfg, agg, state, wire, hparams,
                  present=None, discount=None):
    # Step 5: per-worker server momentum (math dtype configurable — bf16
    # halves the per-round transient at LLM scale, EXPERIMENTS §Perf).
    beta, one_m_beta = hparams[0], hparams[2]
    cdt = jnp.dtype(cfg.server_compute_dtype)
    m_prev = state.momentum.astype(cdt)
    w = wire.astype(cdt)
    if discount is not None:
        w = w * discount[:, None].astype(cdt)
    m = beta * m_prev + one_m_beta * w
    if present is not None:
        # absent clients: momentum frozen (neither decayed nor fed) — the
        # streaming server's padding of clients that missed the round
        m = jnp.where(present[:, None], m, m_prev)
    # Step 6: robust aggregation of momenta.
    r = agg(m)
    new = state._replace(momentum=m.astype(jnp.dtype(cfg.momentum_dtype)),
                         step=state.step + 1)
    return r, new


def _dgd_apply(cfg, agg, state, wire, present=None, discount=None):
    # Compressed DGD, non-robust: plain mean of unbiased estimates (the
    # defining non-robust corner — the aggregator config is ignored).
    del agg
    if present is None:
        return jnp.mean(wire, axis=0), state._replace(step=state.step + 1)
    # Streaming partial participation: the momentum slot doubles as the
    # last-received-wire bank; absent clients keep their frozen row.
    bank = _row_mask(wire, state.momentum.astype(wire.dtype), present,
                     discount)
    r = jnp.mean(bank, axis=0)
    return r, state._replace(
        momentum=bank.astype(jnp.dtype(cfg.momentum_dtype)),
        step=state.step + 1)


def _robust_dgd_apply(cfg, agg, state, wire, present=None, discount=None):
    # Robust DGD without compression: aggregate raw gradients (the
    # sparsifier config is ignored).
    if present is None:
        return agg(wire), state._replace(step=state.step + 1)
    bank = _row_mask(wire, state.momentum.astype(wire.dtype), present,
                     discount)
    r = agg(bank)
    return r, state._replace(
        momentum=bank.astype(jnp.dtype(cfg.momentum_dtype)),
        step=state.step + 1)


def _rosdhb_step(cfg, agg, state, grads, mask_key, atk_key, hparams,
                 attack_params, attack_idx, ratio):
    g_tilde, atk = _compressed_wire(cfg, state.attack, grads, mask_key,
                                    atk_key, attack_params, attack_idx,
                                    ratio)
    return _rosdhb_apply(cfg, agg, state._replace(attack=atk), g_tilde,
                         hparams)


def _dgd_step(cfg, agg, state, grads, mask_key, atk_key, hparams,
              attack_params, attack_idx, ratio):
    g_tilde, atk = _compressed_wire(cfg, state.attack, grads, mask_key,
                                    atk_key, attack_params, attack_idx,
                                    ratio)
    return _dgd_apply(cfg, agg, state._replace(attack=atk), g_tilde)


def _robust_dgd_step(cfg, agg, state, grads, mask_key, atk_key, hparams,
                     attack_params, attack_idx, ratio):
    g, atk = _byzantine_overwrite(cfg, state.attack, grads, atk_key,
                                  attack_params, attack_idx)
    return _robust_dgd_apply(cfg, agg, state._replace(attack=atk), g)


def _dasha_step(cfg, agg, state, grads, mask_key, atk_key, hparams,
                attack_params, attack_idx, ratio):
    # Byz-DASHA-PAGE, p=1 branch.
    #   MVR momentum: m_i^t = g_i^t + (1-a)(m_i^{t-1} - g_i^{t-1})
    #   wire:         c_i^t = C((m_i^t - m_i^{t-1})
    #                          + b (m_i^{t-1} - h_i^{t-1}))
    #                 — compressed momentum difference plus DASHA's
    #                 mirror-drift correction with b = 1/(2 alpha), which
    #                 contracts E[h - m] at rate b while keeping the
    #                 alpha-scaled compression variance bounded.
    #   mirror:       h_i^t = h_i^{t-1} + c_i^t
    #   direction:    R^t = F(h_1^t ... h_n^t)
    if state.mirror is None or state.prev_grad is None:
        raise ValueError(
            "dasha needs the mirror/prev_grad state slots but the carry was "
            "built with a pruned StateLayout: init the state with a config "
            "whose algorithms() include 'dasha' (or StateLayout.full())")
    n, d = grads.shape
    # Byz-DASHA-PAGE runs an INDEPENDENT unbiased compressor per worker
    # (the analysis of [29] requires independent randomness; there is no
    # coordinated-mask trick — that is RoSDHB's contribution), so each
    # worker draws its own mask regardless of the grid-shared sparsifier's
    # ``local`` flag. algo_payload_bytes prices the matching wire format:
    # k values + coordinate indices.
    sp = dataclasses.replace(cfg.sparsifier, local=True)
    one_m_a = hparams[3]
    first = state.step == 0
    m_prev = state.momentum.astype(jnp.float32)
    h_prev = state.mirror.astype(jnp.float32)
    g32 = grads.astype(jnp.float32)
    m = jnp.where(first, g32, g32 + one_m_a * (m_prev - state.prev_grad))
    masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype, ratio=ratio)
    alpha = (1.0 / ratio) if ratio is not None else sp.alpha
    b = 1.0 / (2.0 * alpha)
    diff = C.compress((m - m_prev) + b * (m_prev - h_prev), masks, sp,
                      ratio=ratio)
    h = h_prev + diff
    h, atk = _byzantine_overwrite(cfg, state.attack, h, atk_key,
                                  attack_params, attack_idx)
    r = agg(h)
    mdt = jnp.dtype(cfg.momentum_dtype)
    new = ServerState(momentum=m.astype(mdt), mirror=h.astype(mdt),
                      prev_grad=g32, step=state.step + 1, attack=atk)
    return r, new


#: Branch order of the full algorithm bank (and the set of known algorithms).
ALGO_BANK: Tuple[str, ...] = ("rosdhb", "dasha", "robust_dgd", "dgd")

ALGO_STEPS = {
    "rosdhb": _rosdhb_step,
    "dasha": _dasha_step,
    "robust_dgd": _robust_dgd_step,
    "dgd": _dgd_step,
}

#: Algorithms the streaming parameter server (``repro.serve``) can run:
#: the memoryless-wire rules, whose client payload depends only on the
#: current gradient + broadcast round keys. ``dasha`` is excluded by
#: construction — Byz-DASHA-PAGE's wire is a compressed *difference*
#: against server-side mirrors and per-client MVR momentum, so its
#: per-client control variates go stale the moment a client misses a round
#: (the failure mode the paper's momentum-based RoSDHB avoids).
SERVE_ALGORITHMS: Tuple[str, ...] = ("rosdhb", "robust_dgd", "dgd")

_SERVE_APPLY = {
    "rosdhb": _rosdhb_apply,
    "robust_dgd": _robust_dgd_apply,
    "dgd": _dgd_apply,
}


def _check_serveable(name: str) -> None:
    if name not in SERVE_ALGORITHMS:
        raise ValueError(
            f"algorithm {name!r} cannot run as a streaming service "
            f"(serveable: {'|'.join(SERVE_ALGORITHMS)})"
            + (": dasha's wire is a compressed difference against "
               "server-side mirrors — its per-client control variates go "
               "stale under partial participation" if name == "dasha"
               else ""))


def make_wire_fn(cfg: AlgorithmConfig):
    """The client-side half of a serveable algorithm's round:
    ``wire_fn(atk_state, grads, mask_key, atk_key) -> (wire [n, D],
    new_atk_state)`` — exactly the op sequence the simulator's step runs
    before the server-side apply, so a client pool streaming these rows to
    ``repro.serve`` reproduces simulator trajectories bit-for-bit."""
    _check_serveable(cfg.name)
    if cfg.name == "robust_dgd":
        def wire_fn(atk_state, grads, mask_key, atk_key):
            del mask_key  # raw gradients: no compression
            return _byzantine_overwrite(cfg, atk_state, grads, atk_key)
    else:
        def wire_fn(atk_state, grads, mask_key, atk_key):
            return _compressed_wire(cfg, atk_state, grads, mask_key, atk_key)
    return wire_fn


def make_serve_apply_fn(cfg: AlgorithmConfig, agg):
    """The server-side half: ``apply_fn(state, wire, present, discount) ->
    (direction [D], new ServerState)``.

    ``present`` is a ``[n]`` bool row mask (clients that reported this
    round) and ``discount`` a ``[n]`` f32 staleness weight — both traced
    data, so one compiled program covers every participation level. With
    all rows present and ``discount == 1.0`` the graph computes exactly the
    simulator's full-participation round (multiply-by-1.0 and
    ``where(True, ...)`` are exact), which is the parity gate
    ``benchmarks/bench_serve.py`` enforces."""
    _check_serveable(cfg.name)
    hparams = static_hparams(cfg)
    apply_half = _SERVE_APPLY[cfg.name]

    def apply_fn(state: ServerState, wire: jnp.ndarray,
                 present: jnp.ndarray, discount: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, ServerState]:
        if cfg.name == "rosdhb":
            return apply_half(cfg, agg, state, wire, hparams,
                              present=present, discount=discount)
        return apply_half(cfg, agg, state, wire,
                          present=present, discount=discount)

    return apply_fn


def algo_index(name: str, entries: Optional[Sequence[str]] = None) -> int:
    """Branch index of algorithm ``name`` inside ``entries`` (default the
    full :data:`ALGO_BANK`)."""
    entries = tuple(entries) if entries is not None else ALGO_BANK
    try:
        return entries.index(name)
    except ValueError:
        raise ValueError(
            f"algorithm {name!r} is not a branch of the algorithm bank "
            f"{entries}") from None


def static_hparams(cfg: AlgorithmConfig) -> Tuple[float, float, float, float]:
    """The ``(beta, mvr_a, 1-beta, 1-mvr_a)`` hyperparameter vector of a
    statically configured algorithm — the values a fused bank carries as its
    traced ``ScenarioParams.hparams`` cell vector. Slots an algorithm does
    not use are 0/1 (inert). The complements are computed here in python
    double precision so the traced branches see the exact f32 constants the
    static path folds in (bank == standalone bit-for-bit)."""
    beta = cfg.resolved_beta() if cfg.name == "rosdhb" else 0.0
    a = cfg.resolved_mvr_a() if cfg.name == "dasha" else 0.0
    return (beta, a, 1.0 - beta, 1.0 - a)


def make_algorithm_bank(cfg: AlgorithmConfig,
                        entries: Optional[Sequence[str]] = None):
    """Build the switch-based algorithm bank
    ``step(state, grads, mask_key, atk_key, agg, algo_idx, hparams, ...)``.

    A ``lax.switch`` over uniformly-shaped algorithm branches — every branch
    maps the shared :class:`ServerState` + per-worker gradients to a descent
    direction + the same state shape — selected by the *traced* integer
    ``algo_idx``. Per-branch hyperparameters (RoSDHB's momentum ``beta``,
    DASHA's MVR ``a``) arrive as the traced ``hparams`` ``[4]`` vector, so
    the paper's entire cross-algorithm Table-1 comparison compiles to ONE
    XLA program per fused bank (see ``repro.core.sweep.plan_grid``).

    ``entries`` (default ``cfg.bank`` or the full :data:`ALGO_BANK`) is the
    branch set; as with the attack/aggregator banks, under ``vmap`` a switch
    computes every branch per lane — restrict ``entries`` to the algorithms
    the grid actually uses. Static config (sparsifier kind, aggregator
    ``f``, dtypes, ``n_workers``/``f``) is shared by every branch, and so is
    the carry's :class:`StateLayout`: a dasha-free entry set runs on the
    pruned (mirror/prev_grad-less) state; any dasha entry requires the full
    width (validated here, loudly).
    """
    entries = tuple(entries if entries is not None
                    else (cfg.bank or ALGO_BANK))
    if not entries:
        raise ValueError("algorithm bank needs at least one entry")
    unknown = [e for e in entries if e not in ALGO_STEPS]
    if unknown:
        raise ValueError(
            f"unknown algorithm-bank entries {unknown} (known algorithms: "
            f"{'|'.join(ALGO_BANK)})")
    if "dasha" in entries and not cfg.resolved_state_layout().is_full:
        raise ValueError(
            "algorithm bank contains a dasha branch but cfg's StateLayout "
            "prunes mirror/prev_grad — dasha's variance-reduction state "
            "cannot be pruned (use StateLayout.full() or drop dasha)")

    def apply(state: ServerState, grads: jnp.ndarray, mask_key: jax.Array,
              atk_key: jax.Array, agg, algo_idx: jnp.ndarray,
              hparams: jnp.ndarray,
              attack_params: Optional[jnp.ndarray] = None,
              attack_idx: Optional[jnp.ndarray] = None,
              ratio: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, ServerState]:
        branches = tuple(
            (lambda step: lambda st, g: step(
                cfg, agg, st, g, mask_key, atk_key, hparams,
                attack_params, attack_idx, ratio))(ALGO_STEPS[e])
            for e in entries)
        if len(branches) == 1:
            return branches[0](state, grads)
        return jax.lax.switch(algo_idx, branches, state, grads)

    return apply


# --------------------------------------------------------------------------
# Per-algorithm uplink + state-memory accounting
# --------------------------------------------------------------------------


def server_state_bytes(cfg: AlgorithmConfig, d: int) -> int:
    """Bytes of the ``[n, D]`` server banks one trajectory carries under
    ``cfg``'s resolved :class:`StateLayout` (momentum, plus mirror/prev_grad
    when materialised; the O(1) step counter and the attack slab are
    excluded).

    This is the paper's per-client *memory* comparison made executable:
    RoSDHB keeps one momentum vector per worker, while Byz-DASHA-PAGE
    additionally carries the gradient mirror h_i and the previous gradient
    for its MVR correction — so a dasha(-capable) config costs
    ``n*D*(2*momentum_dtype + 4)`` bytes against RoSDHB's ``n*D*dtype``
    (3x at f32). The carry specialisation makes the engine charge each
    algorithm exactly its own footprint instead of padding everyone to
    DASHA's width.
    """
    n = cfg.n_workers
    layout = cfg.resolved_state_layout()
    mdt_bytes = jnp.dtype(cfg.momentum_dtype).itemsize
    total = n * d * mdt_bytes                      # momentum bank
    if layout.mirror:
        total += n * d * mdt_bytes                 # dasha mirrors h_i
    if layout.prev_grad:
        total += n * d * 4                         # f32 previous gradients
    return total


def algo_payload_bytes(cfg: AlgorithmConfig, d: int,
                       bytes_per_value: int = 4) -> int:
    """Per-worker uplink bytes per round under ``cfg``'s ACTUAL wire format.

    Delegates to :mod:`repro.core.wire` — the one accounting shared with the
    streaming server's ``repro.serve.protocol``, so simulator and service
    can never disagree on what a round costs (see that module for the
    per-algorithm formats). Raises ``ValueError`` for bank configs — a bank
    mixes wire formats; account per cell with each cell's own config.
    """
    return W.per_worker_payload_bytes(cfg.name, d, cfg.sparsifier,
                                      bytes_per_value=bytes_per_value)


def _bank_payload_floats(entries: Sequence[str], d: int,
                         sp: C.SparsifierConfig,
                         ratio: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Traced ``[n_entries]`` per-branch payload-float counts (the bank's
    per-round aux must stay uniform across branches)."""
    if ratio is not None:
        k = jnp.maximum(1.0, jnp.round(ratio * d))
    else:
        k = float(C.payload_floats(d, sp))
    vals = [jnp.asarray(float(d) if e == "robust_dgd" else k, jnp.float32)
            for e in entries]
    return jnp.stack(vals)


def server_round(cfg: AlgorithmConfig, state: ServerState,
                 grads: jnp.ndarray, key: jax.Array,
                 attack_params: Optional[jnp.ndarray] = None,
                 scenario: Optional[ScenarioParams] = None
                 ) -> Tuple[jnp.ndarray, ServerState, dict]:
    """Execute one server round.

    Args:
      cfg: algorithm configuration.
      state: current server state.
      grads: honest-computed per-worker gradients ``[n, D]`` (f32). Rows of
        Byzantine workers are ignored and replaced by the attack.
      key: PRNG key for this round (mask sampling + stochastic attacks).
      attack_params: traced attack parameters — the ``[2]`` coefficient
        vector for ``attack.name='linear'`` (or the per-branch parameter
        vector for ``attack.name='bank'``); lets a grid of attacks share
        one compiled program (see ``repro.core.sweep``).
      scenario: traced :class:`ScenarioParams` cell vector — the fused grid
        axis. Its ``attack_coeffs`` supersede ``attack_params``;
        ``attack_idx`` selects the attack-bank branch
        (``attack.name='bank'``); ``agg_idx`` switches the aggregator bank;
        ``ratio`` overrides the sparsifier keep-ratio; ``algo_idx`` selects
        the algorithm-bank branch (``cfg.name='bank'``) with per-cell
        ``hparams``. Static config fills in whatever is ``None``. Stateful
        adversaries carry their memory in ``state.attack`` (threaded
        through the scan like every other server-state component).

    Returns:
      (direction R [D] to descend, next state, aux dict).
    """
    n, d = grads.shape
    assert n == cfg.n_workers, (n, cfg.n_workers)
    if cfg.clip_norm is not None:
        norms = jnp.linalg.norm(grads.astype(jnp.float32), axis=1,
                                keepdims=True)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norms, 1e-12))
        grads = (grads * scale.astype(grads.dtype))
    ratio = attack_idx = hparams = None
    if scenario is not None:
        if scenario.attack_coeffs is not None:
            attack_params = scenario.attack_coeffs
        attack_idx = scenario.attack_idx
        ratio = scenario.ratio
        hparams = scenario.hparams
    mask_key, atk_key = jax.random.split(key)
    if scenario is not None and scenario.agg_idx is not None:
        bank = G.make_aggregator_bank(cfg.aggregator)
        agg = lambda x: bank(x, scenario.agg_idx)  # noqa: E731
    else:
        agg = G.make_aggregator(cfg.aggregator)
    sp = cfg.sparsifier

    if cfg.name == "bank":
        # The cross-algorithm fusion axis: lax.switch over update rules on
        # the traced algo_idx, per-cell hyperparameters as traced data.
        if scenario is None or scenario.algo_idx is None:
            raise ValueError(
                "algorithm bank needs a traced branch selector: pass a "
                "ScenarioParams with algo_idx (and hparams) — see "
                "sweep.FusedBank.scenario_params")
        if hparams is None:
            raise ValueError(
                "algorithm bank needs per-cell hyperparameters: pass a "
                "ScenarioParams with hparams=[beta, mvr_a, 1-beta, 1-mvr_a] "
                "(see algorithms.static_hparams)")
        entries = tuple(cfg.bank or ALGO_BANK)
        r, new = make_algorithm_bank(cfg, entries)(
            state, grads, mask_key, atk_key, agg, scenario.algo_idx,
            hparams, attack_params, attack_idx, ratio)
        payload = _bank_payload_floats(entries, d, sp,
                                       ratio)[scenario.algo_idx]
        return r, new, {"payload_floats_per_worker": payload}

    try:
        step = ALGO_STEPS[cfg.name]
    except KeyError:
        raise ValueError(f"unknown algorithm: {cfg.name!r}") from None
    if hparams is None:
        hparams = static_hparams(cfg)
    r, new = step(cfg, agg, state, grads, mask_key, atk_key, hparams,
                  attack_params, attack_idx, ratio)
    aux = {"payload_floats_per_worker": (d if cfg.name == "robust_dgd"
                                         else C.payload_floats(d, sp))}
    return r, new, aux


def apply_direction(params_flat: jnp.ndarray, r: jnp.ndarray,
                    gamma) -> jnp.ndarray:
    """Step 7: theta^t = theta^{t-1} - gamma R^t (``gamma`` may be a traced
    per-cell scalar inside a fused bank)."""
    return params_flat - gamma * r
