"""Server-side distributed learning algorithms on flat gradient banks.

Everything here operates on flat stacked vectors ``[n_workers, D]`` — the
launcher (``repro/launch``) is responsible for producing per-worker gradients
from the sharded model and for resharding; these functions are pure math and
are shared between the paper-scale simulator and the LLM-scale pjit path.

Algorithms:
  * ``rosdhb``       — the paper's Algorithm 1 (global or local sparsification
                       chosen by the sparsifier config).
  * ``dasha``        — Byz-DASHA-PAGE [29] with p=1 (full-gradient PAGE
                       branch): per-worker MVR momentum + compressed-difference
                       server mirrors + robust aggregation.
  * ``robust_dgd``   — robust DGD, no compression (SOTA-without-compression
                       corner, [3]).
  * ``dgd``          — plain compressed DGD, non-robust (SOTA-without-
                       robustness corner, [1]).

The Byzantine adversary is simulated *on the wire quantity* each algorithm
actually transmits: compressed gradients for rosdhb/dgd, raw gradients for
robust_dgd, compressed differences (applied at the mirror level) for dasha.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as A
from repro.core import aggregators as G
from repro.core import compression as C


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AlgorithmConfig:
    """Full specification of a Byzantine-robust compressed training run.

    Attributes:
      name: ``rosdhb`` | ``dasha`` | ``robust_dgd`` | ``dgd``.
      n_workers: total workers n.
      f: number of Byzantine workers (the first ``f`` indices).
      gamma: learning rate.
      beta: momentum coefficient; ``None`` -> Theorem 1 schedule
        ``sqrt(1 - 24 gamma L)`` using ``smoothness_L``.
      smoothness_L: Lipschitz constant estimate used by the beta schedule.
      mvr_a: DASHA's MVR coefficient ``a`` (only for ``dasha``).
      sparsifier: compression config.
      aggregator: robust-aggregation config.
      attack: Byzantine strategy.
      momentum_dtype: dtype of the server momentum bank (f32 default;
        bf16/fp8 are beyond-paper memory optimizations, see DESIGN §3).
      server_compute_dtype: dtype the server does its momentum/aggregation
        math in (f32 default; bf16 halves the per-round transient at LLM
        scale — a beyond-paper optimization ablated in EXPERIMENTS §Perf).
    """

    name: str = "rosdhb"
    n_workers: int = 10
    f: int = 0
    gamma: float = 0.05
    beta: Optional[float] = 0.9
    smoothness_L: float = 1.0
    mvr_a: Optional[float] = None
    sparsifier: C.SparsifierConfig = dataclasses.field(
        default_factory=C.SparsifierConfig)
    aggregator: G.AggregatorConfig = dataclasses.field(
        default_factory=G.AggregatorConfig)
    attack: A.AttackConfig = dataclasses.field(
        default_factory=lambda: A.AttackConfig(name="none"))
    momentum_dtype: str = "float32"
    server_compute_dtype: str = "float32"
    clip_norm: Optional[float] = None  # per-worker L2 clip before compression

    @property
    def honest(self) -> int:
        return self.n_workers - self.f

    def resolved_beta(self) -> float:
        if self.beta is not None:
            return self.beta
        # Theorem 1: beta = sqrt(1 - 24 gamma L), requires gamma <= 1/(24 L).
        val = 1.0 - 24.0 * self.gamma * self.smoothness_L
        if val <= 0.0:
            raise ValueError(
                f"gamma={self.gamma} too large for Theorem-1 beta schedule "
                f"(needs gamma <= 1/(24 L) = {1.0 / (24 * self.smoothness_L)})")
        return math.sqrt(val)


def theorem1_hparams(L: float, ratio: float,
                     c: float = 23200.0) -> Tuple[float, float]:
    """Theorem 1's (gamma, beta): gamma = (k/d)/(cL), beta = sqrt(1-24 gamma L).

    The constant c = 23200 is the paper's (very conservative) analysis
    constant; practical runs (the paper's own Section 4 included) use far
    larger gamma with beta = 0.9.
    """
    gamma = ratio / (c * L)
    beta = math.sqrt(1.0 - 24.0 * gamma * L)
    return gamma, beta


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------


class ScenarioParams(NamedTuple):
    """Traced per-cell scenario vector for the fused grid axis.

    Every component is optional (``None`` components contribute no pytree
    leaves, so a ``ScenarioParams`` batch vmaps cleanly whichever subset is
    fused); a present component overrides the corresponding static config:

    ``attack_coeffs``: ``[2]`` linear-attack ``(a, b)`` coefficients
      (requires ``cfg.attack.name == 'linear'``, see ``attacks.linear_attack``).
    ``agg_idx``: scalar int32 branch index into the aggregator bank
      (``aggregators.make_aggregator_bank``) replacing the static rule.
    ``ratio``: scalar keep-ratio replacing ``cfg.sparsifier.ratio``
      (only for ``compression.TRACED_RATIO_KINDS``).
    """

    attack_coeffs: Optional[jnp.ndarray] = None
    agg_idx: Optional[jnp.ndarray] = None
    ratio: Optional[jnp.ndarray] = None


class ServerState(NamedTuple):
    """Server-side algorithm state.

    ``momentum``: RoSDHB per-worker momentum bank ``[n, D]`` (Algorithm 1,
      step 5) — also reused as DASHA's MVR momentum.
    ``mirror``: DASHA's server-side gradient mirrors ``h_i`` ``[n, D]``
      (zeros-shaped [1, 1] placeholder for other algorithms).
    ``prev_grad``: previous-round per-worker gradients for DASHA's MVR
      correction (placeholder otherwise).
    ``step``: iteration counter t.
    """

    momentum: jnp.ndarray
    mirror: jnp.ndarray
    prev_grad: jnp.ndarray
    step: jnp.ndarray


def init_state(cfg: AlgorithmConfig, d: int) -> ServerState:
    n = cfg.n_workers
    mdt = jnp.dtype(cfg.momentum_dtype)
    zeros = jnp.zeros((n, d), mdt)
    if cfg.name == "dasha":
        return ServerState(zeros, zeros, jnp.zeros((n, d), jnp.float32),
                           jnp.zeros((), jnp.int32))
    ph = jnp.zeros((1, 1), mdt)
    return ServerState(zeros, ph, ph, jnp.zeros((), jnp.int32))


# --------------------------------------------------------------------------
# One server round
# --------------------------------------------------------------------------


def _byzantine_overwrite(cfg: AlgorithmConfig, wire: jnp.ndarray,
                         key: jax.Array,
                         attack_params: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
    """Replace rows [0, f) of the wire payload with the attack vectors
    computed from the honest rows [f, n)."""
    if cfg.f == 0 or cfg.attack.name == "none":
        return wire
    honest = wire[cfg.f:]
    byz = A.apply_attack(cfg.attack, honest, cfg.f, key=key,
                         params=attack_params)
    return jnp.concatenate([byz.astype(wire.dtype), honest], axis=0)


def server_round(cfg: AlgorithmConfig, state: ServerState,
                 grads: jnp.ndarray, key: jax.Array,
                 attack_params: Optional[jnp.ndarray] = None,
                 scenario: Optional[ScenarioParams] = None
                 ) -> Tuple[jnp.ndarray, ServerState, dict]:
    """Execute one server round.

    Args:
      cfg: algorithm configuration.
      state: current server state.
      grads: honest-computed per-worker gradients ``[n, D]`` (f32). Rows of
        Byzantine workers are ignored and replaced by the attack.
      key: PRNG key for this round (mask sampling + stochastic attacks).
      attack_params: traced parameters for ``attack.name='linear'`` (a ``[2]``
        coefficient vector); lets a grid of mean/std-family attacks share one
        compiled program (see ``repro.core.sweep``).
      scenario: traced :class:`ScenarioParams` cell vector — the fused grid
        axis. Its ``attack_coeffs`` supersede ``attack_params``; ``agg_idx``
        switches the aggregator bank; ``ratio`` overrides the sparsifier
        keep-ratio. Static config fills in whatever is ``None``.

    Returns:
      (direction R [D] to descend, next state, aux dict).
    """
    n, d = grads.shape
    assert n == cfg.n_workers, (n, cfg.n_workers)
    if cfg.clip_norm is not None:
        norms = jnp.linalg.norm(grads.astype(jnp.float32), axis=1,
                                keepdims=True)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norms, 1e-12))
        grads = (grads * scale.astype(grads.dtype))
    ratio = None
    if scenario is not None:
        if scenario.attack_coeffs is not None:
            attack_params = scenario.attack_coeffs
        ratio = scenario.ratio
    mask_key, atk_key = jax.random.split(key)
    if scenario is not None and scenario.agg_idx is not None:
        bank = G.make_aggregator_bank(cfg.aggregator)
        agg = lambda x: bank(x, scenario.agg_idx)  # noqa: E731
    else:
        agg = G.make_aggregator(cfg.aggregator)
    sp = cfg.sparsifier
    mdt = jnp.dtype(cfg.momentum_dtype)
    aux = {"payload_floats_per_worker": C.payload_floats(d, sp)}

    if cfg.name == "rosdhb":
        # Steps 1-4: masks (global or local) + unbiased reconstruction.
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        g_tilde = C.compress(grads, masks, sp, ratio=ratio)
        g_tilde = _byzantine_overwrite(cfg, g_tilde, atk_key, attack_params)
        # Step 5: per-worker server momentum (math dtype configurable —
        # bf16 halves the per-round transient at LLM scale, EXPERIMENTS
        # section Perf).
        beta = cfg.resolved_beta()
        cdt = jnp.dtype(cfg.server_compute_dtype)
        m = (beta * state.momentum.astype(cdt)
             + (1.0 - beta) * g_tilde.astype(cdt))
        # Step 6: robust aggregation of momenta.
        r = agg(m)
        new = state._replace(momentum=m.astype(mdt), step=state.step + 1)
        return r, new, aux

    if cfg.name == "dgd":
        # Compressed DGD, non-robust: plain mean of unbiased estimates.
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        g_tilde = C.compress(grads, masks, sp, ratio=ratio)
        g_tilde = _byzantine_overwrite(cfg, g_tilde, atk_key, attack_params)
        r = jnp.mean(g_tilde, axis=0)
        return r, state._replace(step=state.step + 1), aux

    if cfg.name == "robust_dgd":
        # Robust DGD without compression: aggregate raw gradients.
        g = _byzantine_overwrite(cfg, grads, atk_key, attack_params)
        aux["payload_floats_per_worker"] = d
        r = agg(g)
        return r, state._replace(step=state.step + 1), aux

    if cfg.name == "dasha":
        # Byz-DASHA-PAGE, p=1 branch.
        #   MVR momentum: m_i^t = g_i^t + (1-a)(m_i^{t-1} - g_i^{t-1})
        #   wire:         c_i^t = C((m_i^t - m_i^{t-1})
        #                          + b (m_i^{t-1} - h_i^{t-1}))
        #                 — compressed momentum difference plus DASHA's
        #                 mirror-drift correction with b = 1/(2 alpha), which
        #                 contracts E[h - m] at rate b while keeping the
        #                 alpha-scaled compression variance bounded.
        #   mirror:       h_i^t = h_i^{t-1} + c_i^t
        #   direction:    R^t = F(h_1^t ... h_n^t)
        a = cfg.mvr_a if cfg.mvr_a is not None else (1.0 - (cfg.beta or 0.9))
        first = state.step == 0
        m_prev = state.momentum.astype(jnp.float32)
        h_prev = state.mirror.astype(jnp.float32)
        m = jnp.where(first, grads,
                      grads + (1.0 - a) * (m_prev - state.prev_grad))
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        alpha = (1.0 / ratio) if ratio is not None else sp.alpha
        b = 1.0 / (2.0 * alpha)
        diff = C.compress((m - m_prev) + b * (m_prev - h_prev), masks, sp,
                          ratio=ratio)
        h = h_prev + diff
        h = _byzantine_overwrite(cfg, h, atk_key, attack_params)
        r = agg(h)
        new = ServerState(momentum=m.astype(mdt), mirror=h.astype(mdt),
                          prev_grad=grads, step=state.step + 1)
        return r, new, aux

    raise ValueError(f"unknown algorithm: {cfg.name!r}")


def apply_direction(params_flat: jnp.ndarray, r: jnp.ndarray,
                    gamma: float) -> jnp.ndarray:
    """Step 7: theta^t = theta^{t-1} - gamma R^t."""
    return params_flat - gamma * r
