"""Server-side distributed learning algorithms on flat gradient banks.

Everything here operates on flat stacked vectors ``[n_workers, D]`` — the
launcher (``repro/launch``) is responsible for producing per-worker gradients
from the sharded model and for resharding; these functions are pure math and
are shared between the paper-scale simulator and the LLM-scale pjit path.

Algorithms:
  * ``rosdhb``       — the paper's Algorithm 1 (global or local sparsification
                       chosen by the sparsifier config).
  * ``dasha``        — Byz-DASHA-PAGE [29] with p=1 (full-gradient PAGE
                       branch): per-worker MVR momentum + compressed-difference
                       server mirrors + robust aggregation.
  * ``robust_dgd``   — robust DGD, no compression (SOTA-without-compression
                       corner, [3]).
  * ``dgd``          — plain compressed DGD, non-robust (SOTA-without-
                       robustness corner, [1]).

The Byzantine adversary is simulated *on the wire quantity* each algorithm
actually transmits: compressed gradients for rosdhb/dgd, raw gradients for
robust_dgd, compressed differences (applied at the mirror level) for dasha.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as A
from repro.core import aggregators as G
from repro.core import compression as C


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AlgorithmConfig:
    """Full specification of a Byzantine-robust compressed training run.

    Attributes:
      name: ``rosdhb`` | ``dasha`` | ``robust_dgd`` | ``dgd``.
      n_workers: total workers n.
      f: number of Byzantine workers (the first ``f`` indices).
      gamma: learning rate.
      beta: momentum coefficient; ``None`` -> Theorem 1 schedule
        ``sqrt(1 - 24 gamma L)`` using ``smoothness_L``.
      smoothness_L: Lipschitz constant estimate used by the beta schedule.
      mvr_a: DASHA's MVR coefficient ``a`` (only for ``dasha``).
      sparsifier: compression config.
      aggregator: robust-aggregation config.
      attack: Byzantine strategy.
      momentum_dtype: dtype of the server momentum bank (f32 default;
        bf16/fp8 are beyond-paper memory optimizations, see DESIGN §3).
      server_compute_dtype: dtype the server does its momentum/aggregation
        math in (f32 default; bf16 halves the per-round transient at LLM
        scale — a beyond-paper optimization ablated in EXPERIMENTS §Perf).
    """

    name: str = "rosdhb"
    n_workers: int = 10
    f: int = 0
    gamma: float = 0.05
    beta: Optional[float] = 0.9
    smoothness_L: float = 1.0
    mvr_a: Optional[float] = None
    sparsifier: C.SparsifierConfig = dataclasses.field(
        default_factory=C.SparsifierConfig)
    aggregator: G.AggregatorConfig = dataclasses.field(
        default_factory=G.AggregatorConfig)
    attack: A.AttackConfig = dataclasses.field(
        default_factory=lambda: A.AttackConfig(name="none"))
    momentum_dtype: str = "float32"
    server_compute_dtype: str = "float32"
    clip_norm: Optional[float] = None  # per-worker L2 clip before compression

    @property
    def honest(self) -> int:
        return self.n_workers - self.f

    def resolved_beta(self) -> float:
        if self.beta is not None:
            return self.beta
        # Theorem 1: beta = sqrt(1 - 24 gamma L), requires gamma <= 1/(24 L).
        val = 1.0 - 24.0 * self.gamma * self.smoothness_L
        if val <= 0.0:
            raise ValueError(
                f"gamma={self.gamma} too large for Theorem-1 beta schedule "
                f"(needs gamma <= 1/(24 L) = {1.0 / (24 * self.smoothness_L)})")
        return math.sqrt(val)


def theorem1_hparams(L: float, ratio: float,
                     c: float = 23200.0) -> Tuple[float, float]:
    """Theorem 1's (gamma, beta): gamma = (k/d)/(cL), beta = sqrt(1-24 gamma L).

    The constant c = 23200 is the paper's (very conservative) analysis
    constant; practical runs (the paper's own Section 4 included) use far
    larger gamma with beta = 0.9.
    """
    gamma = ratio / (c * L)
    beta = math.sqrt(1.0 - 24.0 * gamma * L)
    return gamma, beta


# --------------------------------------------------------------------------
# State
# --------------------------------------------------------------------------


class ScenarioParams(NamedTuple):
    """Traced per-cell scenario vector for the fused grid axis.

    Every component is optional (``None`` components contribute no pytree
    leaves, so a ``ScenarioParams`` batch vmaps cleanly whichever subset is
    fused); a present component overrides the corresponding static config:

    ``attack_coeffs``: ``[2]`` attack parameter vector — the linear-family
      ``(a, b)`` coefficients for ``cfg.attack.name == 'linear'`` (see
      ``attacks.linear_attack``), or the per-branch parameter vector of the
      attack bank for ``cfg.attack.name == 'bank'``.
    ``attack_idx``: scalar int32 branch index into the attack bank
      (``repro.adversary.make_attack_bank``; requires
      ``cfg.attack.name == 'bank'``).
    ``agg_idx``: scalar int32 branch index into the aggregator bank
      (``aggregators.make_aggregator_bank``) replacing the static rule.
    ``ratio``: scalar keep-ratio replacing ``cfg.sparsifier.ratio``
      (only for ``compression.TRACED_RATIO_KINDS``).
    """

    attack_coeffs: Optional[jnp.ndarray] = None
    attack_idx: Optional[jnp.ndarray] = None
    agg_idx: Optional[jnp.ndarray] = None
    ratio: Optional[jnp.ndarray] = None


class ServerState(NamedTuple):
    """Server-side algorithm state.

    ``momentum``: RoSDHB per-worker momentum bank ``[n, D]`` (Algorithm 1,
      step 5) — also reused as DASHA's MVR momentum.
    ``mirror``: DASHA's server-side gradient mirrors ``h_i`` ``[n, D]``
      (zeros-shaped [1, 1] placeholder for other algorithms).
    ``prev_grad``: previous-round per-worker gradients for DASHA's MVR
      correction (placeholder otherwise).
    ``step``: iteration counter t.
    ``attack``: the adversary's carried memory
      (``repro.adversary.AttackState``) for stateful attacks and attack
      banks; ``None`` (no pytree leaves) for stateless attacks, so legacy
      configs keep their exact state structure.
    """

    momentum: jnp.ndarray
    mirror: jnp.ndarray
    prev_grad: jnp.ndarray
    step: jnp.ndarray
    attack: Optional[Any] = None


def _adversary():
    # local import: repro.adversary.core imports repro.core.attacks, so a
    # module-level import here would be circular
    from repro.adversary import core as adv
    return adv


def _init_attack_state(cfg: AlgorithmConfig, d: int) -> Optional[Any]:
    """Adversary memory slab for stateful attacks / attack banks; ``None``
    (structure-preserving) for the stateless legacy attacks."""
    adv = _adversary()
    if adv.needs_attack_state(cfg.attack.name, cfg.f):
        return adv.init_attack_state(d)
    return None


def init_state(cfg: AlgorithmConfig, d: int) -> ServerState:
    n = cfg.n_workers
    mdt = jnp.dtype(cfg.momentum_dtype)
    zeros = jnp.zeros((n, d), mdt)
    atk = _init_attack_state(cfg, d)
    if cfg.name == "dasha":
        return ServerState(zeros, zeros, jnp.zeros((n, d), jnp.float32),
                           jnp.zeros((), jnp.int32), atk)
    ph = jnp.zeros((1, 1), mdt)
    return ServerState(zeros, ph, ph, jnp.zeros((), jnp.int32), atk)


# --------------------------------------------------------------------------
# One server round
# --------------------------------------------------------------------------


def _byzantine_overwrite(cfg: AlgorithmConfig, atk_state: Optional[Any],
                         wire: jnp.ndarray, key: jax.Array,
                         attack_params: Optional[jnp.ndarray] = None,
                         attack_idx: Optional[jnp.ndarray] = None
                         ) -> Tuple[jnp.ndarray, Optional[Any]]:
    """Replace rows [0, f) of the wire payload with the attack vectors
    computed from the honest rows [f, n).

    Returns ``(wire, new_attack_state)``.  Dispatch: ``name='bank'`` runs
    the switch-based attack bank (``repro.adversary.make_attack_bank``)
    selected by the traced ``attack_idx``; statically configured *stateful*
    adversaries (mimic/spectral/ipm_greedy) run their registry step with the
    carried ``atk_state``; everything else stays on the stateless legacy
    ``attacks.apply_attack`` path.
    """
    name = cfg.attack.name
    if cfg.f == 0 or name == "none":
        return wire, atk_state
    honest = wire[cfg.f:]
    if name == "bank":
        adv = _adversary()
        if atk_state is None:
            raise ValueError(
                "attack bank needs the adversary memory slab: build the "
                "server state with init_state(cfg, d) (ServerState.attack)")
        entries = cfg.attack.bank or adv.DEFAULT_ATTACK_BANK
        if attack_idx is None or attack_params is None:
            raise ValueError(
                "bank attack needs traced branch selectors: pass a "
                "ScenarioParams with attack_idx and attack_coeffs "
                "(see sweep.FusedBank.scenario_params)")
        atk_state, byz = adv.make_attack_bank(entries, cfg.f)(
            atk_state, honest, key, attack_idx, attack_params)
    else:
        adv = _adversary()
        if adv.is_stateful(name):
            if atk_state is None:
                raise ValueError(
                    f"stateful attack {name!r} needs the adversary memory "
                    "slab: build the server state with init_state(cfg, d) "
                    "(ServerState.attack)")
            coeffs = (attack_params if attack_params is not None
                      else adv.static_coeffs(cfg.attack, cfg.n_workers,
                                             cfg.f))
            atk_state, byz = adv.ADVERSARIES[name].step(
                atk_state, honest, cfg.f, key, coeffs)
        else:
            byz = A.apply_attack(cfg.attack, honest, cfg.f, key=key,
                                 params=attack_params)
    return jnp.concatenate([byz.astype(wire.dtype), honest], axis=0), atk_state


def server_round(cfg: AlgorithmConfig, state: ServerState,
                 grads: jnp.ndarray, key: jax.Array,
                 attack_params: Optional[jnp.ndarray] = None,
                 scenario: Optional[ScenarioParams] = None
                 ) -> Tuple[jnp.ndarray, ServerState, dict]:
    """Execute one server round.

    Args:
      cfg: algorithm configuration.
      state: current server state.
      grads: honest-computed per-worker gradients ``[n, D]`` (f32). Rows of
        Byzantine workers are ignored and replaced by the attack.
      key: PRNG key for this round (mask sampling + stochastic attacks).
      attack_params: traced attack parameters — the ``[2]`` coefficient
        vector for ``attack.name='linear'`` (or the per-branch parameter
        vector for ``attack.name='bank'``); lets a grid of attacks share
        one compiled program (see ``repro.core.sweep``).
      scenario: traced :class:`ScenarioParams` cell vector — the fused grid
        axis. Its ``attack_coeffs`` supersede ``attack_params``;
        ``attack_idx`` selects the attack-bank branch
        (``attack.name='bank'``); ``agg_idx`` switches the aggregator bank;
        ``ratio`` overrides the sparsifier keep-ratio. Static config fills
        in whatever is ``None``. Stateful adversaries carry their memory in
        ``state.attack`` (threaded through the scan like every other
        server-state component).

    Returns:
      (direction R [D] to descend, next state, aux dict).
    """
    n, d = grads.shape
    assert n == cfg.n_workers, (n, cfg.n_workers)
    if cfg.clip_norm is not None:
        norms = jnp.linalg.norm(grads.astype(jnp.float32), axis=1,
                                keepdims=True)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(norms, 1e-12))
        grads = (grads * scale.astype(grads.dtype))
    ratio = attack_idx = None
    if scenario is not None:
        if scenario.attack_coeffs is not None:
            attack_params = scenario.attack_coeffs
        attack_idx = scenario.attack_idx
        ratio = scenario.ratio
    mask_key, atk_key = jax.random.split(key)
    if scenario is not None and scenario.agg_idx is not None:
        bank = G.make_aggregator_bank(cfg.aggregator)
        agg = lambda x: bank(x, scenario.agg_idx)  # noqa: E731
    else:
        agg = G.make_aggregator(cfg.aggregator)
    sp = cfg.sparsifier
    mdt = jnp.dtype(cfg.momentum_dtype)
    aux = {"payload_floats_per_worker": C.payload_floats(d, sp)}

    if cfg.name == "rosdhb":
        # Steps 1-4: masks (global or local) + unbiased reconstruction.
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        g_tilde = C.compress(grads, masks, sp, ratio=ratio)
        g_tilde, atk = _byzantine_overwrite(cfg, state.attack, g_tilde,
                                            atk_key, attack_params,
                                            attack_idx)
        # Step 5: per-worker server momentum (math dtype configurable —
        # bf16 halves the per-round transient at LLM scale, EXPERIMENTS
        # section Perf).
        beta = cfg.resolved_beta()
        cdt = jnp.dtype(cfg.server_compute_dtype)
        m = (beta * state.momentum.astype(cdt)
             + (1.0 - beta) * g_tilde.astype(cdt))
        # Step 6: robust aggregation of momenta.
        r = agg(m)
        new = state._replace(momentum=m.astype(mdt), step=state.step + 1,
                             attack=atk)
        return r, new, aux

    if cfg.name == "dgd":
        # Compressed DGD, non-robust: plain mean of unbiased estimates.
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        g_tilde = C.compress(grads, masks, sp, ratio=ratio)
        g_tilde, atk = _byzantine_overwrite(cfg, state.attack, g_tilde,
                                            atk_key, attack_params,
                                            attack_idx)
        r = jnp.mean(g_tilde, axis=0)
        return r, state._replace(step=state.step + 1, attack=atk), aux

    if cfg.name == "robust_dgd":
        # Robust DGD without compression: aggregate raw gradients.
        g, atk = _byzantine_overwrite(cfg, state.attack, grads, atk_key,
                                      attack_params, attack_idx)
        aux["payload_floats_per_worker"] = d
        r = agg(g)
        return r, state._replace(step=state.step + 1, attack=atk), aux

    if cfg.name == "dasha":
        # Byz-DASHA-PAGE, p=1 branch.
        #   MVR momentum: m_i^t = g_i^t + (1-a)(m_i^{t-1} - g_i^{t-1})
        #   wire:         c_i^t = C((m_i^t - m_i^{t-1})
        #                          + b (m_i^{t-1} - h_i^{t-1}))
        #                 — compressed momentum difference plus DASHA's
        #                 mirror-drift correction with b = 1/(2 alpha), which
        #                 contracts E[h - m] at rate b while keeping the
        #                 alpha-scaled compression variance bounded.
        #   mirror:       h_i^t = h_i^{t-1} + c_i^t
        #   direction:    R^t = F(h_1^t ... h_n^t)
        a = cfg.mvr_a if cfg.mvr_a is not None else (1.0 - (cfg.beta or 0.9))
        first = state.step == 0
        m_prev = state.momentum.astype(jnp.float32)
        h_prev = state.mirror.astype(jnp.float32)
        m = jnp.where(first, grads,
                      grads + (1.0 - a) * (m_prev - state.prev_grad))
        masks = C.make_masks(mask_key, n, d, sp, dtype=grads.dtype,
                             ratio=ratio)
        alpha = (1.0 / ratio) if ratio is not None else sp.alpha
        b = 1.0 / (2.0 * alpha)
        diff = C.compress((m - m_prev) + b * (m_prev - h_prev), masks, sp,
                          ratio=ratio)
        h = h_prev + diff
        h, atk = _byzantine_overwrite(cfg, state.attack, h, atk_key,
                                      attack_params, attack_idx)
        r = agg(h)
        new = ServerState(momentum=m.astype(mdt), mirror=h.astype(mdt),
                          prev_grad=grads, step=state.step + 1, attack=atk)
        return r, new, aux

    raise ValueError(f"unknown algorithm: {cfg.name!r}")


def apply_direction(params_flat: jnp.ndarray, r: jnp.ndarray,
                    gamma: float) -> jnp.ndarray:
    """Step 7: theta^t = theta^{t-1} - gamma R^t."""
    return params_flat - gamma * r
