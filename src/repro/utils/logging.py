"""Structured training metrics: JSONL writer + console mirror (the launcher's
monitoring substrate; offline container, so no external trackers)."""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricsLogger:
    """Append-only JSONL metrics with wall-clock stamps.

    >>> log = MetricsLogger("runs/exp1/metrics.jsonl", console=True)
    >>> log.write(step=10, loss=2.3, acc=0.41)
    """

    def __init__(self, path: Optional[str] = None, console: bool = True):
        self.path = path
        self.console = console
        self._fh = None
        self._t0 = time.time()
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)

    def write(self, step: int, **metrics: Any) -> Dict[str, Any]:
        rec = {"step": int(step), "wall_s": round(time.time() - self._t0, 3)}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        if self.console:
            body = " ".join(f"{k}={v:.5g}" if isinstance(v, float) else
                            f"{k}={v}" for k, v in rec.items()
                            if k not in ("step", "wall_s"))
            print(f"[metrics] step {rec['step']:6d} ({rec['wall_s']:8.1f}s) "
                  f"{body}")
        return rec

    def close(self):
        if self._fh:
            self._fh.close()
