from repro.utils.logging import MetricsLogger
from repro.utils.tree import (
    tree_size,
    tree_ravel,
    tree_unravel,
    stacked_ravel,
    stacked_unravel,
    FlatSpec,
    make_flat_spec,
)

__all__ = [
    "MetricsLogger",
    "tree_size",
    "tree_ravel",
    "tree_unravel",
    "stacked_ravel",
    "stacked_unravel",
    "FlatSpec",
    "make_flat_spec",
]
