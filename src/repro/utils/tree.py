"""Pytree <-> flat-vector utilities.

The RoSDHB server operates on flattened parameter/gradient vectors: the
momentum bank is a dense ``[n_workers, D]`` array and the robust aggregators
are defined coordinate-wise over ``D``. These helpers convert between model
pytrees (possibly with a leading stacked worker axis) and flat vectors, with
optional padding so ``D`` divides the number of mesh devices evenly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of scalar elements across all leaves."""
    return int(sum(np.prod(l.shape, dtype=np.int64) if hasattr(l, "shape") else 1
                   for l in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static description of a pytree's flattened layout.

    Attributes:
      treedef: the pytree structure.
      shapes: per-leaf shapes, in ``tree_leaves`` order.
      dtypes: per-leaf dtypes.
      sizes: per-leaf element counts.
      offsets: per-leaf start offsets into the flat vector.
      size: total unpadded size ``D``.
      padded_size: ``D`` rounded up to a multiple of ``pad_to``.
    """

    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple
    offsets: tuple
    size: int
    padded_size: int

    @property
    def pad(self) -> int:
        return self.padded_size - self.size


def make_flat_spec(tree: Any, pad_to: int = 1) -> FlatSpec:
    """Build a :class:`FlatSpec` for ``tree`` (works on ShapeDtypeStructs too)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    size = int(sum(sizes))
    padded = int(-(-size // pad_to) * pad_to)
    return FlatSpec(treedef, shapes, dtypes, sizes, offsets, size, padded)


def tree_ravel(tree: Any, spec: FlatSpec | None = None,
               dtype: Any = jnp.float32) -> jnp.ndarray:
    """Flatten ``tree`` into a single 1-D vector of ``spec.padded_size``."""
    if spec is None:
        spec = make_flat_spec(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [jnp.reshape(l, (-1,)).astype(dtype) for l in leaves]
    if spec.pad:
        parts.append(jnp.zeros((spec.pad,), dtype=dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def tree_unravel(flat: jnp.ndarray, spec: FlatSpec) -> Any:
    """Inverse of :func:`tree_ravel` (drops padding, restores leaf dtypes)."""
    leaves = []
    for shape, dtype, size, off in zip(spec.shapes, spec.dtypes, spec.sizes,
                                       spec.offsets):
        leaves.append(jax.lax.slice_in_dim(flat, off, off + size)
                      .reshape(shape).astype(dtype))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def stacked_ravel(tree: Any, spec: FlatSpec | None = None,
                  dtype: Any = jnp.float32) -> jnp.ndarray:
    """Flatten a pytree whose every leaf has a leading stacked axis ``n``.

    Returns a ``[n, padded_size]`` array. ``spec`` must describe the
    *unstacked* tree (i.e. leaf shapes without the leading axis).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    if spec is None:
        unstacked = jax.tree_util.tree_map(lambda l: l[0], tree)
        spec = make_flat_spec(unstacked)
    parts = [jnp.reshape(l, (n, -1)).astype(dtype) for l in leaves]
    if spec.pad:
        parts.append(jnp.zeros((n, spec.pad), dtype=dtype))
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def stacked_unravel(flat: jnp.ndarray, spec: FlatSpec) -> Any:
    """Inverse of :func:`stacked_ravel`: ``[n, padded]`` -> stacked pytree."""
    n = flat.shape[0]
    leaves = []
    for shape, dtype, size, off in zip(spec.shapes, spec.dtypes, spec.sizes,
                                       spec.offsets):
        leaves.append(
            jax.lax.slice_in_dim(flat, off, off + size, axis=1)
            .reshape((n,) + shape).astype(dtype))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def tree_cast(tree: Any, dtype: Any) -> Any:
    return jax.tree_util.tree_map(lambda l: l.astype(dtype), tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda l: l * s, a)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
