"""The paper's own Section-4 model: ~11.8k-parameter CNN for 10-class
28x28 grayscale classification. Not part of the assigned-arch pool; used by
the paper-faithful reproduction benchmarks."""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(name="mnist_cnn", family="dense", n_layers=0,
                      d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=10),
    citation="the paper, Section 4",
)
