"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408/expert
vocab=102400; MLA kv_lora=512; MoE 64 routed experts top-6 + 2 shared;
first layer dense.  [arXiv:2405.04434]

Note: the assignment note mentions "160 routed" while the headline spec says
"MoE 64e top-6" — we follow the headline spec (64 routed, top-6) and record
the discrepancy here.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="deepseek_v2_lite_16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        n_experts=64,
        top_k=6,
        n_shared_experts=2,
        first_k_dense=1,
        use_mla=True,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    citation="arXiv:2405.04434 (DeepSeek-V2)",
)
