"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128.  SSD (state-space duality) [arXiv:2405.21060]"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="mamba2_1_3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=1,          # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,             # no MLP: the mamba block carries expand=2
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv_width=4,
        ssm_chunk=256,
        ssm_n_groups=1,
    ),
    citation="arXiv:2405.21060 (SSD)",
)
