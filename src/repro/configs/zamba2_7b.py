"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64; Mamba2 backbone + ONE weight-shared attention block applied
after every 6 mamba layers (13 invocations + 3 trailing mamba layers).
[arXiv:2411.15242]"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="zamba2_7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        attn_every=6,
    ),
    citation="arXiv:2411.15242 (Zamba2)",
)
