"""Config registry: assigned architectures, input shapes, run policies."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# sliding window applied to *attention* archs for the long_500k decode shape
# (SSM/hybrid run natively; MLA keeps its compact latent cache full-length).
LONG_CONTEXT_WINDOW = 8_192


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """An assigned architecture: model config + parallelism policy + source."""

    model: ModelConfig
    citation: str
    fsdp: bool = False          # additionally shard weights over "data"
    rosdhb_ratio: float = 0.05  # default k/d for the RoSDHB train step

    @property
    def name(self) -> str:
        return self.model.name


ARCH_IDS = [
    "stablelm_3b",
    "mamba2_1_3b",
    "deepseek_v2_lite_16b",
    "musicgen_medium",
    "dbrx_132b",
    "mistral_large_123b",
    "llama32_vision_11b",
    "qwen25_3b",
    "gemma_2b",
    "zamba2_7b",
]

# accept the assignment's hyphenated ids too
_ALIASES = {
    "stablelm-3b": "stablelm_3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "musicgen-medium": "musicgen_medium",
    "dbrx-132b": "dbrx_132b",
    "mistral-large-123b": "mistral_large_123b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "qwen2.5-3b": "qwen25_3b",
    "gemma-2b": "gemma_2b",
    "zamba2-7b": "zamba2_7b",
}


def get_arch(arch_id: str) -> ArchSpec:
    arch_id = _ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS + ["mnist_cnn"]:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SPEC


def model_for_shape(spec: ArchSpec, shape: InputShape) -> ModelConfig:
    """Apply shape-dependent policy (sliding window for long-context decode
    on attention archs)."""
    cfg = spec.model
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid") \
            and not cfg.use_mla:
        cfg = cfg.with_overrides(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def list_archs():
    return list(ARCH_IDS)
