"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32, i.e. MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family, scaled per assignment]"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="stablelm_3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        mlp="swiglu",
        norm="layernorm",   # StableLM-2 uses LayerNorm
        rope_theta=1e4,
    ),
    citation="hf:stabilityai/stablelm-2-1_6b",
)
