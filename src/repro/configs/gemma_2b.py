"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000;
GeGLU; head_dim=256; tied embeddings.  [arXiv:2403.08295]"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="gemma_2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp="geglu",
        tie_embeddings=True,
        rope_theta=1e4,
    ),
    citation="arXiv:2403.08295 (Gemma)",
)
