from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    ArchSpec,
    InputShape,
    get_arch,
    list_archs,
    model_for_shape,
)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_WINDOW", "ArchSpec",
    "InputShape", "get_arch", "list_archs", "model_for_shape",
]
