"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers (every 5th layer).
[hf:meta-llama/Llama-3.2-11B-Vision]

The ViT/projector frontend is a STUB per the assignment carve-out:
input_specs() provides precomputed patch embeddings [B, 1024, 4096]."""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="llama32_vision_11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=5e5,
        cross_attn_every=5,
        n_image_tokens=1024,
    ),
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)
