"""musicgen-medium [audio] — 48L d_model=1536 24H d_ff=6144 vocab=2048;
decoder-only over EnCodec tokens.  [arXiv:2306.05284]

The EnCodec frontend is a STUB per the assignment carve-out: input_specs()
provides precomputed frame embeddings [B, S, 1536]; targets are codebook ids.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

SPEC = ArchSpec(
    model=ModelConfig(
        name="musicgen_medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        mlp="gelu",         # MusicGen uses standard transformer FFN
        norm="layernorm",
        input_kind="embeddings",
    ),
    citation="arXiv:2306.05284 (MusicGen)",
)
