"""Quickstart: RoSDHB in 40 lines, then Table 1 as ONE compiled program.

Part 1 — the algorithm itself: ten workers (two Byzantine, running ALIE)
minimise heterogeneous quadratics; the server sees only 10% of each gradient
per round (global RandK), keeps a Polyak momentum per worker, and aggregates
with NNM+CWTM.

Part 2 — the paper's headline comparison: the ``table1-mini`` registry
scenario (all four algorithms x {alie, foe} x CWTM+NNM) plans to a
single cross-algorithm bank — the algorithm choice, its hyperparameters,
the attack, and the aggregator are all *traced data* switched inside one
XLA program (``repro.core.algorithms.make_algorithm_bank``).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        plan_grid, quadratic_testbed, run_scenarios,
                        server_round)

# ----------------------------------------------------------------------
# Part 1: one RoSDHB training run, step by step
# ----------------------------------------------------------------------

D, N, F = 64, 10, 2

cfg = AlgorithmConfig(
    name="rosdhb", n_workers=N, f=F, gamma=0.1, beta=0.9,
    sparsifier=SparsifierConfig(kind="randk", ratio=0.1),   # send 10% of d
    aggregator=AggregatorConfig(name="cwtm", f=F, pre_nnm=True),
    attack=AttackConfig(name="alie", z=1.5),
)

targets = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.1 + 1.0
honest_opt = jnp.mean(targets[F:], axis=0)

theta = jnp.zeros(D)
state = init_state(cfg, D)
key = jax.random.PRNGKey(1)

for t in range(800):
    key, sub = jax.random.split(key)
    grads = theta[None, :] - targets          # worker i's local gradient
    direction, state, aux = server_round(cfg, state, grads, sub)
    theta = apply_direction(theta, direction, cfg.gamma)
    if t % 200 == 0 or t == 799:
        print(f"round {t:4d}  dist-to-honest-opt="
              f"{float(jnp.linalg.norm(theta - honest_opt)):.4f}  "
              f"uplink floats/worker={aux['payload_floats_per_worker']}"
              f" (of {D})")

assert float(jnp.linalg.norm(theta - honest_opt)) < 0.3
print("OK: converged to the honest optimum under attack at 10x compression.")

# ----------------------------------------------------------------------
# Part 2: a Table-1 mini-grid — 4 algorithms x 2 attacks, ONE program
# ----------------------------------------------------------------------

from repro.adversary import registry  # noqa: E402

spec = registry.get_spec("table1-mini")
scenarios = spec.expand()
plan = plan_grid(scenarios)
print(f"\n{plan.describe()}")
assert plan.n_programs == 1, "the whole cross-algorithm grid is one program"

loss_fn, params0, batch_fn, _ = quadratic_testbed(spec.n_workers, D)
rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                     batches=batch_fn, seeds=[0, 1], steps=300,
                     shard=False)

print(f"\n{'scenario':<42} {'final_loss':>10} {'comm_MB':>8}")
by_label = {}
for r in rows:
    acc = by_label.setdefault(r["scenario"], {"loss": 0.0, "mb": 0.0, "k": 0})
    acc["loss"] += r["final_loss"]
    acc["mb"] = r["comm_bytes"] / 1e6
    acc["k"] += 1
for label, acc in by_label.items():
    print(f"{label:<42} {acc['loss'] / acc['k']:>10.4f} {acc['mb']:>8.2f}")

# the robust+compressed corner (rosdhb) should beat the non-robust corner
# (dgd, which FoE wrecks), at ~10x less uplink than robust_dgd
mean_loss = lambda algo: sum(  # noqa: E731
    r["final_loss"] for r in rows if r["algo"] == algo) / max(
    1, sum(1 for r in rows if r["algo"] == algo))
assert mean_loss("rosdhb") < mean_loss("dgd")
rosdhb_mb = next(r["comm_bytes"] for r in rows if r["algo"] == "rosdhb")
robust_mb = next(r["comm_bytes"] for r in rows if r["algo"] == "robust_dgd")
assert rosdhb_mb * 5 < robust_mb
print("\nOK: one compiled program reproduced the Table-1 comparison "
      f"({len(rows)} cells).")
