"""Quickstart: RoSDHB in 40 lines.

Ten workers (two Byzantine, running ALIE) minimise heterogeneous quadratics;
the server sees only 10% of each gradient per round (global RandK), keeps a
Polyak momentum per worker, and aggregates with NNM+CWTM.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        SparsifierConfig, apply_direction, init_state,
                        server_round)

D, N, F = 64, 10, 2

cfg = AlgorithmConfig(
    name="rosdhb", n_workers=N, f=F, gamma=0.1, beta=0.9,
    sparsifier=SparsifierConfig(kind="randk", ratio=0.1),   # send 10% of d
    aggregator=AggregatorConfig(name="cwtm", f=F, pre_nnm=True),
    attack=AttackConfig(name="alie", z=1.5),
)

targets = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.1 + 1.0
honest_opt = jnp.mean(targets[F:], axis=0)

theta = jnp.zeros(D)
state = init_state(cfg, D)
key = jax.random.PRNGKey(1)

for t in range(800):
    key, sub = jax.random.split(key)
    grads = theta[None, :] - targets          # worker i's local gradient
    direction, state, aux = server_round(cfg, state, grads, sub)
    theta = apply_direction(theta, direction, cfg.gamma)
    if t % 200 == 0 or t == 799:
        print(f"round {t:4d}  dist-to-honest-opt="
              f"{float(jnp.linalg.norm(theta - honest_opt)):.4f}  "
              f"uplink floats/worker={aux['payload_floats_per_worker']}"
              f" (of {D})")

assert float(jnp.linalg.norm(theta - honest_opt)) < 0.3
print("OK: converged to the honest optimum under attack at 10x compression.")
