"""End-to-end driver reproducing the paper's Section-4 experiment.

Trains the ~12k-parameter CNN on the (synthetic, offline) MNIST-like dataset
with 10 honest workers plus f Byzantine workers running ALIE, trimmed-mean
aggregation, and RandK at a chosen compression ratio; reports accuracy and
cumulative communication until the tau = 0.85 threshold — the protocol
behind Figure 1.

Runs on the batched scan engine (core/simulator.py): a single seed uses the
chunked ``Simulator.run`` wrapper (eval + early stop preserved); with
``--seeds N`` all N trajectories execute in ONE vmapped lax.scan
(``repro.core.sweep.rollout_over_seeds``) and mean +- std accuracy is
reported.

    PYTHONPATH=src python examples/paper_mnist.py --ratio 0.05 --f 5
    PYTHONPATH=src python examples/paper_mnist.py --ratio 0.05 --f 5 --seeds 4
"""

import argparse

import jax
import numpy as np

from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                        Simulator, SparsifierConfig, rollout_over_seeds)
from repro.core.sweep import eval_over_seeds
from repro.data import SyntheticMNIST
from repro.models import cnn_accuracy, cnn_init, cnn_loss


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--ratio", type=float, default=0.05, help="k/d")
    p.add_argument("--f", type=int, default=5, help="# Byzantine workers")
    p.add_argument("--attack", default="alie")
    p.add_argument("--gamma", type=float, default=None)
    p.add_argument("--steps", type=int, default=400)
    p.add_argument("--algo", default="rosdhb",
                   choices=["rosdhb", "dasha", "robust_dgd", "dgd"])
    p.add_argument("--local-masks", action="store_true",
                   help="RoSDHB-Local (uncoordinated sparsification)")
    p.add_argument("--seeds", type=int, default=1,
                   help=">1 runs all seeds in one vmapped scan")
    args = p.parse_args()

    # learning rates tuned per ratio at f=0 (the paper's tuning protocol)
    gamma_by_ratio = {0.01: 0.01, 0.05: 0.05, 0.1: 0.05, 0.3: 0.1,
                      0.5: 0.1, 1.0: 0.2}
    gamma = args.gamma or gamma_by_ratio.get(args.ratio, 0.05)
    n = 10 + args.f

    ds = SyntheticMNIST(n_workers=n, per_worker=2000, seed=0)
    cfg = AlgorithmConfig(
        name=args.algo, n_workers=n, f=args.f, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=args.ratio,
                                    local=args.local_masks),
        aggregator=AggregatorConfig(name="cwtm", f=max(args.f, 1)),
        attack=AttackConfig(name=args.attack))
    sim = Simulator(loss_fn=cnn_loss, params0=cnn_init(jax.random.PRNGKey(0)),
                    cfg=cfg, eval_fn=lambda p, b: {"acc": cnn_accuracy(p, b)})

    print(f"algo={args.algo} n={n} f={args.f} attack={args.attack} "
          f"k/d={args.ratio} gamma={gamma} "
          f"uplink/round={sim.payload_bytes_per_round()/1e3:.1f}KB")

    if args.seeds > 1:
        seeds = list(range(args.seeds))
        states, metrics = rollout_over_seeds(sim, seeds,
                                             ds.worker_batches(60),
                                             steps=args.steps)
        accs = np.asarray(eval_over_seeds(sim, states, ds.eval_batch)["acc"])
        loss = np.asarray(metrics["loss"])
        total_mb = sim.payload_bytes_per_round() * args.steps / 1e6
        print(f"{args.seeds}-seed sweep, one vmapped scan of {args.steps} "
              f"rounds ({total_mb:.2f} MB uplink each):")
        print(f"  final loss {loss[:, -1].mean():.3f}+-{loss[:, -1].std():.3f}"
              f"  final acc {accs.mean():.3f}+-{accs.std():.3f}")
        return

    st = sim.init()
    st, hist = sim.run(
        st, ds.worker_batches(60), steps=args.steps, eval_every=20,
        eval_batch=ds.eval_batch,
        stop_fn=lambda m: m.get("acc", 0.0) >= 0.85)
    for i in range(len(hist["step"])):
        print(f"round {hist['step'][i]:4d}  loss={hist['loss'][i]:.3f}  "
              f"acc={hist['acc'][i]:.3f}  comm={hist['comm_bytes'][i]/1e6:.2f}MB")
    if hist["acc"] and hist["acc"][-1] >= 0.85:
        print(f"reached tau=0.85 with {hist['comm_bytes'][-1]/1e6:.2f} MB "
              f"total uplink")
    else:
        print("did not reach tau within the step budget")


if __name__ == "__main__":
    main()
