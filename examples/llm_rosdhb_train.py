"""LLM-scale RoSDHB path on the host mesh: trains a reduced qwen-family
transformer (~3M params) for a few hundred steps with the SAME pjit train
step used by the production dry-run — per-worker vmapped gradients,
coordinate-sharded momentum bank, Byzantine overwrite, CWTM.

    PYTHONPATH=src python examples/llm_rosdhb_train.py --steps 60
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_arch
from repro.configs.base import ArchSpec, InputShape
from repro.core import (AggregatorConfig, AttackConfig, SparsifierConfig)
from repro.core import algorithms as alg
from repro.launch import make_host_mesh
from repro.launch.steps import (TrainState, build_train_step,
                                make_train_plan)
from repro.models import model_init
from repro.utils import tree as T


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen25_3b")
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--n-workers", type=int, default=8)
    p.add_argument("--f", type=int, default=2)
    p.add_argument("--ratio", type=float, default=0.1)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=16)
    args = p.parse_args()

    spec = get_arch(args.arch)
    reduced = ArchSpec(model=spec.model.reduced(n_layers=2, d_model=256)
                       .with_overrides(vocab_size=512),
                       citation=spec.citation)
    shape = InputShape("host_train", args.seq, args.batch, "train")
    mesh = make_host_mesh()

    plan = make_train_plan(
        reduced, shape, mesh, n_workers=args.n_workers,
        algo_overrides={
            "f": args.f, "gamma": 0.5,
            "sparsifier": SparsifierConfig(kind="block", ratio=args.ratio,
                                           block_size=128),
            "aggregator": AggregatorConfig(name="cwtm", f=args.f),
            "attack": AttackConfig(name="alie"),
            "momentum_dtype": "float32",
        })
    step = jax.jit(build_train_step(plan, mesh))
    cfg = plan.model

    with mesh:
        params = model_init(jax.random.PRNGKey(0), cfg)
        state = TrainState(
            params=params,
            server=alg.init_state(plan.algo, plan.flat_spec.padded_size),
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(1))

        rng = np.random.default_rng(0)
        lb = shape.global_batch // plan.n_workers
        print(f"arch={args.arch}(reduced) d={plan.flat_spec.padded_size} params, "
              f"n_workers={plan.n_workers} f={args.f} k/d={args.ratio}")
        t0 = time.time()
        for t in range(args.steps):
            toks = rng.integers(0, cfg.vocab_size,
                                (plan.n_workers, lb, args.seq))
            toks[..., 1::2] = (toks[..., 0::2] + 1) % cfg.vocab_size
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            state, metrics = step(state, batch)
            if t % 10 == 0 or t == args.steps - 1:
                print(f"step {t:4d} loss={float(metrics['loss']):.4f} "
                      f"|R|={float(metrics['dir_norm']):.3f} "
                      f"uplink={int(metrics['payload_floats_per_worker'])} "
                      f"floats/worker ({time.time()-t0:.1f}s)")
        assert float(metrics["loss"]) < 6.1
        print("OK: loss decreasing under ALIE with 10x-compressed uplink.")


if __name__ == "__main__":
    main()
