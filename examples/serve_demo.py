"""Batched serving demo: prefill a batch of prompts then decode tokens with
the same serve step the dry-run lowers (KV/SSM caches, greedy sampling),
on the host mesh with a reduced model.

    PYTHONPATH=src python examples/serve_demo.py --arch zamba2_7b --tokens 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch import make_host_mesh
from repro.models import (cache_init, forward, logits_fn, make_decode_step,
                          model_init)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="zamba2_7b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--tokens", type=int, default=16)
    args = p.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.model.reduced(n_layers=2, d_model=256).with_overrides(
        vocab_size=512, dtype="float32")
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.tokens

    with mesh:
        params = model_init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        b, s = args.batch, args.prompt_len
        batch = {}
        if cfg.input_kind == "tokens":
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        else:
            batch["embeddings"] = jnp.asarray(
                rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeddings"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)),
                jnp.float32)

        caches = cache_init(cfg, b, max_len, dtype=jnp.float32)
        t0 = time.time()
        hidden, caches, _ = forward(params, cfg, batch, mode="prefill",
                                    pos=0, caches=caches)
        last = jnp.argmax(logits_fn(params, cfg, hidden[:, -1:]), -1)
        print(f"prefill [{b}x{s}] in {time.time()-t0:.2f}s "
              f"(family={cfg.family}, cache kinds="
              f"{sorted(caches.keys())})")

        # the shared jitted decode step (repro.models.make_decode_step):
        # traced position, one compiled program for the whole decode loop
        decode_one = make_decode_step(cfg, batch.get("image_embeddings"))

        tok = last
        out = [np.asarray(tok)[:, 0]]
        t0 = time.time()
        for i in range(args.tokens - 1):
            tok, caches = decode_one(params, tok, caches,
                                     jnp.asarray(s + i, jnp.int32))
            out.append(np.asarray(tok)[:, 0])
        dt = time.time() - t0
        gen = np.stack(out, 1)
        print(f"decoded {args.tokens - 1} steps x {b} seqs in {dt:.2f}s "
              f"({(args.tokens - 1) * b / max(dt, 1e-9):.1f} tok/s)")
        print("sampled ids[0]:", gen[0].tolist())


if __name__ == "__main__":
    main()
