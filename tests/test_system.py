"""End-to-end behaviour tests: the paper's full pipeline at test scale.

Reproduces the qualitative claims of Section 4 in miniature:
  * RoSDHB trains the CNN to the paper's accuracy threshold under heavy
    compression with Byzantine workers present;
  * naive compressed DGD fails under the same attack;
  * compression delivers a communication saving at equal target accuracy;
  * checkpoint round-trip preserves the training state.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, Simulator,
    SparsifierConfig,
)
from repro.data import SyntheticMNIST
from repro.models import cnn_accuracy, cnn_init, cnn_loss

N_HONEST = 10


def _sim(f=0, attack="none", ratio=1.0, gamma=0.1, agg="cwtm", ds=None,
         algo="rosdhb"):
    n = N_HONEST + f
    ds = ds or SyntheticMNIST(n_workers=n, per_worker=800, seed=0)
    cfg = AlgorithmConfig(
        name=algo, n_workers=n, f=f, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=(AggregatorConfig(name="mean") if agg == "mean" else
                    AggregatorConfig(name=agg, f=max(f, 1))),
        attack=AttackConfig(name=attack))
    sim = Simulator(loss_fn=cnn_loss, params0=cnn_init(jax.random.PRNGKey(0)),
                    cfg=cfg, eval_fn=lambda p, b: {"acc": cnn_accuracy(p, b)})
    return sim, ds


@pytest.mark.slow
def test_rosdhb_reaches_threshold_under_attack_and_compression():
    f = 5
    ds = SyntheticMNIST(n_workers=N_HONEST + f, per_worker=800, seed=0)
    sim, _ = _sim(f=f, attack="alie", ratio=0.1, gamma=0.03, ds=ds)
    st = sim.init()
    st, hist = sim.run(st, ds.worker_batches(60), steps=400, eval_every=25,
                       eval_batch=ds.eval_batch,
                       stop_fn=lambda m: m.get("acc", 0) >= 0.85)
    # the paper's metric is communication-to-tau (first crossing); at
    # aggressive gamma the post-tau trajectory can oscillate (EXPERIMENTS
    # section Paper, stability note), so we assert the crossing itself.
    assert max(hist["acc"]) >= 0.85


@pytest.mark.slow
def test_naive_dgd_fails_under_foe():
    f = 5
    ds = SyntheticMNIST(n_workers=N_HONEST + f, per_worker=800, seed=0)
    sim, _ = _sim(f=f, attack="foe", ratio=0.1, gamma=0.05, agg="mean",
                  algo="dgd", ds=ds)
    st = sim.init()
    st, hist = sim.run(st, ds.worker_batches(60), steps=150, eval_every=50,
                       eval_batch=ds.eval_batch)
    assert hist["acc"][-1] < 0.85


@pytest.mark.slow
def test_compression_saves_communication_to_threshold():
    """The paper's headline: bytes-to-tau is much smaller at k/d << 1."""
    def bytes_to_tau(ratio, gamma):
        ds = SyntheticMNIST(n_workers=N_HONEST, per_worker=800, seed=0)
        sim, _ = _sim(f=0, ratio=ratio, gamma=gamma, ds=ds)
        st = sim.init()
        st, hist = sim.run(st, ds.worker_batches(60), steps=500,
                           eval_every=25, eval_batch=ds.eval_batch,
                           stop_fn=lambda m: m.get("acc", 0) >= 0.85)
        assert hist["acc"][-1] >= 0.85, f"ratio={ratio} never reached tau"
        return hist["comm_bytes"][-1]

    full = bytes_to_tau(1.0, 0.2)
    comp = bytes_to_tau(0.05, 0.05)
    assert comp < full


def test_simulator_state_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt
    sim, ds = _sim(f=2, attack="alie", ratio=0.2)
    st = sim.init()
    st, _ = sim.run(st, ds.worker_batches(16), steps=3)
    path = str(tmp_path / "state.npz")
    ckpt.save(path, st._asdict(), step=3)
    restored = ckpt.restore(path, st._asdict())
    np.testing.assert_allclose(np.asarray(st.params_flat),
                               restored["params_flat"])
    np.testing.assert_allclose(np.asarray(st.server.momentum),
                               restored["server"].momentum)
    assert ckpt.latest_step(path) == 3
