"""Transport-boundary tests: frame codec roundtrips, checksum
attribution, loopback/TCP parity with the in-process server, retrying
clients, deterministic fault plans, and the protocol-fault budget."""

import threading
import time

import numpy as np
import pytest

from repro.core.sweep import grid_scenarios, quadratic_testbed
from repro.serve import (
    ByzantineRobustServer, ClientGaveUp, ClientPool, FaultPlan, FaultSpec,
    FaultyEndpoint, LoopbackTransport, RetryingClient, RetryPolicy,
    ServeConfig, ServeTimeout, TcpTransport, TransportReset,
    TransportTimeout, get_chaos, make_transport, run_chaos, run_service,
)
from repro.serve import protocol
from repro.serve.server import FaultBudgetExceeded
from repro.serve.transport import ServerBinding

D = 32
ROUNDS = 8


def _cfg(**kw):
    kw.setdefault("n_honest", 10)
    kw.setdefault("f", 3)
    return grid_scenarios(("rosdhb",), ("alie",), ("cwtm",), **kw)[0].cfg


def _testbed(cfg):
    return quadratic_testbed(cfg.n_workers, d=D)


# --------------------------------------------------------------------------
# frame codec
# --------------------------------------------------------------------------


def test_frame_roundtrip_all_message_types():
    ann = protocol.RoundAnnouncement(
        round_id=7, params=np.arange(11, dtype=np.float32),
        mask_key=np.asarray([1, 2], np.uint32),
        atk_key=np.asarray([3, 4], np.uint32))
    raw = protocol.encode_announcement(ann)
    mt, sender, payload = protocol.decode_frame(raw)
    assert (mt, sender) == (protocol.MSG_ANNOUNCE, protocol.SERVER_SENDER)
    got = protocol.decode_announcement(payload)
    assert got.round_id == 7 and got.mask_id == ann.mask_id
    np.testing.assert_array_equal(got.params, ann.params)  # bit-for-bit
    np.testing.assert_array_equal(got.mask_key, ann.mask_key)
    np.testing.assert_array_equal(got.atk_key, ann.atk_key)

    u = protocol.ClientUpdate(
        client_id=5, round_id=7, mask_id=ann.mask_id,
        values=np.linspace(-1, 1, 11).astype(np.float32),
        payload_bytes=123, sent_at=4.5)
    raw = protocol.encode_update(u)
    mt, sender, payload = protocol.decode_frame(raw)
    assert (mt, sender) == (protocol.MSG_UPDATE, 5)
    got = protocol.decode_update(payload, sender)
    assert (got.client_id, got.round_id, got.mask_id,
            got.payload_bytes, got.sent_at) == (5, 7, ann.mask_id, 123, 4.5)
    np.testing.assert_array_equal(got.values, u.values)

    raw = protocol.encode_announce_req(3, client_id=9)
    mt, sender, payload = protocol.decode_frame(raw)
    assert (mt, sender) == (protocol.MSG_ANNOUNCE_REQ, 9)
    assert protocol.decode_announce_req(payload) == 3

    raw = protocol.encode_ack(11, "queued")
    mt, _, payload = protocol.decode_frame(raw)
    assert mt == protocol.MSG_ACK
    assert protocol.decode_ack(payload) == (11, "queued")


def test_corrupt_payload_is_bad_checksum_with_sender():
    u = protocol.ClientUpdate(client_id=4, round_id=2, mask_id=1,
                              values=np.ones(8, np.float32),
                              payload_bytes=32)
    raw = bytearray(protocol.encode_update(u))
    raw[protocol.HEADER_SIZE + 9] ^= 0xFF
    with pytest.raises(protocol.BadChecksum) as ei:
        protocol.decode_frame(bytes(raw))
    assert ei.value.sender == 4      # header intact: fault is attributable
    # a mangled header is NOT attributable — plain FrameError
    raw2 = bytearray(protocol.encode_update(u))
    raw2[0] ^= 0xFF
    with pytest.raises(protocol.FrameError) as ei2:
        protocol.decode_frame(bytes(raw2))
    assert not isinstance(ei2.value, protocol.BadChecksum)


def test_frame_length_splits_corrupt_payload():
    """Stream framing must survive payload corruption: the length field
    lives in the (intact) header, CRC is checked later by the binding."""
    u = protocol.ClientUpdate(client_id=0, round_id=0, mask_id=0,
                              values=np.zeros(8, np.float32),
                              payload_bytes=32)
    raw = bytearray(protocol.encode_update(u))
    raw[protocol.HEADER_SIZE] ^= 0xFF
    assert protocol.frame_length(bytes(raw[:protocol.HEADER_SIZE])) \
        == len(raw)


# --------------------------------------------------------------------------
# transport parity: the framed path is bit-for-bit the in-process server
# --------------------------------------------------------------------------


@pytest.mark.parametrize("transport", ["loopback", "tcp"])
def test_transport_parity_with_in_process_server(transport):
    """Fault-free chaos over the real framed transport == run_service on
    the same seed, bit for bit (the tier-1 loopback smoke; TCP rides the
    same gate over real sockets)."""
    import dataclasses
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn)
    run_service(server, pool, ROUNDS)
    chaos = dataclasses.replace(get_chaos("fault-free"),
                                transport=transport)
    res = run_chaos(cfg, params0, batch_fn, loss_fn, chaos, ROUNDS, seed=0)
    np.testing.assert_array_equal(res.final_params,
                                  np.asarray(server.params_flat))
    assert res.step_traces == [1]
    assert res.all_rounds_terminated()


def test_tcp_rebind_keeps_port():
    cfg = _cfg()
    _, params0, _, _ = _testbed(cfg)
    s1 = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    t = TcpTransport(s1)
    addr = t.address
    ep = t.connect(0)
    t.unbind()
    with pytest.raises((TransportReset, TransportTimeout)):
        ep.request(protocol.encode_announce_req(0, 0))
    s2 = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    t.bind(s2)
    assert t.address == addr        # endpoints survive the restart
    t.close()


def test_loopback_unbound_raises_reset():
    t = LoopbackTransport()
    ep = t.connect(0)
    with pytest.raises(TransportReset):
        ep.request(protocol.encode_announce_req(0, 0))


def test_make_transport_unknown_kind():
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("carrier-pigeon")


# --------------------------------------------------------------------------
# retrying clients
# --------------------------------------------------------------------------


class _FlakyEndpoint:
    """Fails the first k requests, then delegates."""

    def __init__(self, inner, fail_times):
        self.inner = inner
        self.fail_times = fail_times
        self.calls = 0

    def request(self, raw, **ctx):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise TransportTimeout(f"flaky ({self.calls})")
        return self.inner.request(raw, **ctx)

    def close(self):
        self.inner.close()


def test_retrying_client_survives_transient_faults():
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    server.start()
    try:
        t = LoopbackTransport(server)
        sleeps = []
        c = RetryingClient(
            _FlakyEndpoint(t.connect(3), fail_times=3), 3,
            RetryPolicy(max_attempts=5, backoff_base_s=0.01),
            sleep=sleeps.append)
        ann = c.fetch_announcement(0)
        assert ann.round_id == 0
        assert c.stats["retries"] == 3
        # exponential backoff: each sleep at least doubles the base floor
        assert len(sleeps) == 3
        assert sleeps[0] >= 0.01 and sleeps[1] >= 0.02 and sleeps[2] >= 0.04
    finally:
        server.stop()


def test_retrying_client_gives_up_loudly():
    t = LoopbackTransport()              # unbound: every request resets
    c = RetryingClient(t.connect(1), 1,
                       RetryPolicy(max_attempts=3, backoff_base_s=0.0))
    with pytest.raises(ClientGaveUp) as ei:
        c.fetch_announcement(0)
    assert ei.value.attempts == 3 and ei.value.client_id == 1
    assert "TransportReset" in ei.value.last_error


def test_retrying_client_resubmission_is_idempotent():
    """Submitting the same update twice (ack lost -> client retried) must
    land exactly one buffered row."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    server.start()
    try:
        t = LoopbackTransport(server)
        pool = ClientPool(loss_fn, params0, cfg, batch_fn)
        ann = server.announce(timeout=10.0)
        sched = pool.round_payloads(ann)
        c = RetryingClient(t.connect(5), 5, RetryPolicy(max_attempts=2))
        u = sched[5].update
        assert c.submit(u) == "queued"
        assert c.submit(u) == "queued"   # the duplicate is absorbed
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with server._cond:
                if server._buffer.count == 1:
                    break
            time.sleep(0.01)
        with server._cond:
            assert server._buffer.count == 1
        assert server.metrics.decisions.get("duplicate", 0) == 1
    finally:
        server.stop()


def test_retry_backoff_is_seeded_deterministic():
    p = RetryPolicy(seed=42)
    r1 = np.random.default_rng((42, 7))
    r2 = np.random.default_rng((42, 7))
    a = [p.backoff_s(7, k, r1) for k in range(4)]
    b = [p.backoff_s(7, k, r2) for k in range(4)]
    assert a == b


# --------------------------------------------------------------------------
# deterministic fault plans
# --------------------------------------------------------------------------


def test_fault_plan_is_replayable_and_order_independent():
    spec = FaultSpec(drop=0.3, duplicate=0.3, corrupt=0.3, reorder=0.2,
                     delay=0.2, reset=0.2)
    p1, p2 = FaultPlan(spec, seed=9), FaultPlan(spec, seed=9)
    coords = [(c, r, op, a) for c in range(5) for r in range(5)
              for op in ("announce", "update") for a in range(3)]
    forward = [p1.decide(*c) for c in coords]
    backward = [p2.decide(*c) for c in reversed(coords)]
    assert forward == list(reversed(backward))
    # a different seed draws a different schedule
    p3 = FaultPlan(spec, seed=10)
    assert [p3.decide(*c) for c in coords] != forward


def test_fault_plan_corrupt_bytes_deterministic_and_payload_only():
    plan = FaultPlan(FaultSpec(corrupt=1.0), seed=0)
    u = protocol.ClientUpdate(client_id=2, round_id=4, mask_id=0,
                              values=np.ones(16, np.float32),
                              payload_bytes=64)
    raw = protocol.encode_update(u)
    c1 = plan.corrupt_bytes(raw, 2, 4, "update")
    c2 = plan.corrupt_bytes(raw, 2, 4, "update")
    assert c1 == c2 and c1 != raw
    assert c1[:protocol.HEADER_SIZE] == raw[:protocol.HEADER_SIZE]
    with pytest.raises(protocol.BadChecksum) as ei:
        protocol.decode_frame(c1)
    assert ei.value.sender == 2


def test_fault_plan_partition_windows():
    plan = FaultPlan(FaultSpec(partitions=((2, 5, (1, 3)),)), seed=0)
    assert plan.decide(1, 2, "update").partitioned
    assert plan.decide(3, 4, "announce").partitioned
    assert not plan.decide(1, 5, "update").partitioned   # window end
    assert not plan.decide(2, 3, "update").partitioned   # other client
    assert plan.decide(1, 1, "update").clean


def test_faulty_endpoint_drop_and_reset_surface_as_transport_errors():
    inner_calls = []

    class _Sink:
        def request(self, raw, **ctx):
            inner_calls.append(raw)
            return protocol.encode_ack(0, "queued")

        def close(self):
            pass

    ep = FaultyEndpoint(_Sink(), 0, FaultPlan(FaultSpec(drop=1.0)))
    with pytest.raises(TransportTimeout):
        ep.request(b"x", round_id=0, op="update", attempt=0)
    assert not inner_calls and ep.injected["drop"] == 1

    ep = FaultyEndpoint(_Sink(), 0, FaultPlan(FaultSpec(duplicate=1.0)))
    ep.request(b"x", round_id=0, op="update", attempt=0)
    assert len(inner_calls) == 2 and ep.injected["duplicate"] == 1


# --------------------------------------------------------------------------
# protocol-fault budget + typed timeouts
# --------------------------------------------------------------------------


def _corrupt_update_frame(cfg, params0, client_id):
    n_pad = ByzantineRobustServer(cfg, params0).spec.padded_size
    u = protocol.ClientUpdate(client_id=client_id, round_id=0, mask_id=0,
                              values=np.zeros(n_pad, np.float32),
                              payload_bytes=1)
    raw = bytearray(protocol.encode_update(u))
    raw[protocol.HEADER_SIZE + 3] ^= 0xFF
    return bytes(raw)


def test_persistent_corruption_breaches_fault_budget():
    """One HONEST client corrupting past fault_tolerance joins the f
    declared-Byzantine rows: f+1 implicated > f -> loud rejection."""
    cfg = _cfg()
    _, params0, _, _ = _testbed(cfg)
    server = ByzantineRobustServer(
        cfg, params0, ServeConfig(fault_tolerance=3), seed=0)
    server.start()
    try:
        binding = ServerBinding(server)
        bad = _corrupt_update_frame(cfg, params0, client_id=cfg.f + 1)
        for _ in range(3):
            _, _, payload = protocol.decode_frame(binding.handle(bad))
            assert protocol.decode_ack(payload)[1] == "bad_checksum"
        assert server.protocol_faulty == (cfg.f + 1,)
        with pytest.raises(FaultBudgetExceeded) as ei:
            server.wait_round(0, timeout=1.0)
        assert ei.value.faulty == (cfg.f + 1,) and ei.value.f == cfg.f
        assert server.metrics.fault_budget_events
    finally:
        server.stop()


def test_valid_frame_clears_protocol_fault_state():
    """Transient corruption repaired by retransmission never accumulates:
    a valid update resets the client's consecutive-fault count."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(
        cfg, params0, ServeConfig(fault_tolerance=2), seed=0)
    server.start()
    try:
        binding = ServerBinding(server)
        cid = cfg.f + 2
        bad = _corrupt_update_frame(cfg, params0, cid)
        binding.handle(bad)                       # 1 consecutive fault
        pool = ClientPool(loss_fn, params0, cfg, batch_fn)
        ann = server.announce(timeout=10.0)
        good = protocol.encode_update(pool.round_payloads(ann)[cid].update)
        _, _, payload = protocol.decode_frame(binding.handle(good))
        assert protocol.decode_ack(payload)[1] == "queued"
        binding.handle(bad)                       # back to 1, not 2
        assert server.protocol_faulty == ()
    finally:
        server.stop()


def test_announce_and_wait_round_raise_typed_serve_timeout():
    cfg = _cfg()
    _, params0, _, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    server.start()
    try:
        with pytest.raises(ServeTimeout) as ei:
            server.wait_round(0, timeout=0.15)
        e = ei.value
        assert isinstance(e, TimeoutError)        # old handlers still work
        assert e.round_id == 0 and e.reason == "deadline"
        assert e.quorum == cfg.n_workers == e.base_quorum
        assert e.buffer_count == 0 and isinstance(e.decisions, dict)
        with pytest.raises(ServeTimeout) as ei2:
            server.announce(timeout=0.1, min_round=99)
        assert ei2.value.reason == "deadline"
    finally:
        server.stop()
