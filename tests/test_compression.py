"""Sparsifier properties: unbiasedness, variance envelope, payload, masks —
under both static-config and traced keep-ratios (the fused grid axis)."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compression as C


@pytest.mark.parametrize("kind", ["randk", "bernoulli", "block", "block_hash"])
def test_mask_shape_and_dtype(kind):
    cfg = C.SparsifierConfig(kind=kind, ratio=0.25, block_size=8)
    m = C.make_mask(jax.random.PRNGKey(0), 64, cfg)
    assert m.shape == (64,)
    assert set(np.unique(np.asarray(m))) <= {0.0, 1.0}


def test_randk_exact_k():
    cfg = C.SparsifierConfig(kind="randk", ratio=0.25)
    m = C.make_mask(jax.random.PRNGKey(1), 100, cfg)
    assert int(np.asarray(m).sum()) == 25


def test_block_mask_is_blockwise():
    cfg = C.SparsifierConfig(kind="block", ratio=0.25, block_size=16)
    m = np.asarray(C.make_mask(jax.random.PRNGKey(2), 128, cfg))
    blocks = m.reshape(-1, 16)
    # every block entirely 0 or entirely 1
    assert np.all((blocks.sum(1) == 0) | (blocks.sum(1) == 16))
    assert blocks.sum() == 32  # 2 of 8 blocks


def test_global_masks_identical_local_differ():
    g = C.SparsifierConfig(kind="randk", ratio=0.2, local=False)
    l = C.SparsifierConfig(kind="randk", ratio=0.2, local=True)
    mg = np.asarray(C.make_masks(jax.random.PRNGKey(3), 6, 50, g))
    ml = np.asarray(C.make_masks(jax.random.PRNGKey(3), 6, 50, l))
    assert np.all(mg == mg[0])
    assert not np.all(ml == ml[0])


@pytest.mark.parametrize("kind", ["randk", "bernoulli", "block", "block_hash"])
def test_unbiasedness(kind):
    """E[(d/k)(g o mask)] = g over mask randomness (paper, Section 2)."""
    d = 64
    cfg = C.SparsifierConfig(kind=kind, ratio=0.25, block_size=8)
    g = jax.random.normal(jax.random.PRNGKey(0), (d,))
    keys = jax.random.split(jax.random.PRNGKey(42), 4000)
    est = jax.vmap(
        lambda k: C.compress(g, C.make_mask(k, d, cfg), cfg))(keys)
    mean = jnp.mean(est, axis=0)
    assert float(jnp.max(jnp.abs(mean - g))) < 0.15 * float(
        jnp.max(jnp.abs(g)) + 0.3)


def test_randk_variance_envelope():
    """E||g_tilde - g||^2 <= (alpha - 1)||g||^2 for exact RandK."""
    d = 60
    cfg = C.SparsifierConfig(kind="randk", ratio=0.2)
    g = jax.random.normal(jax.random.PRNGKey(0), (d,))
    keys = jax.random.split(jax.random.PRNGKey(7), 3000)
    est = jax.vmap(
        lambda k: C.compress(g, C.make_mask(k, d, cfg), cfg))(keys)
    var = float(jnp.mean(jnp.sum(jnp.square(est - g[None]), axis=1)))
    bound = (cfg.alpha - 1.0) * float(jnp.sum(jnp.square(g)))
    assert var <= 1.1 * bound


@given(d=st.integers(8, 300), ratio=st.floats(0.05, 1.0))
@settings(max_examples=25, deadline=None)
def test_payload_counts(d, ratio):
    cfg = C.SparsifierConfig(kind="randk", ratio=ratio)
    k = C.payload_floats(d, cfg)
    assert 1 <= k <= d
    # global sparsification sends no index bits (shared PRNG)
    assert C.payload_bytes(d, cfg, with_mask_indices=True) == 4 * k
    # local sparsification charges ceil(log2(d)/8) bytes per index — NOT a
    # flat 4 — so comm-to-threshold curves stay honest for small models
    loc = C.SparsifierConfig(kind="randk", ratio=ratio, local=True)
    idx = max(1, math.ceil(math.log2(d) / 8.0))
    expected = (4 + idx) * k if ratio < 1.0 else 4 * k
    assert C.payload_bytes(d, loc, with_mask_indices=True) == expected


def test_index_bytes_scales_with_log_dimension():
    assert C.index_bytes(1) == 1
    assert C.index_bytes(200) == 1
    assert C.index_bytes(256) == 1  # 8 bits address 0..255
    assert C.index_bytes(257) == 2
    assert C.index_bytes(11_800) == 2  # the paper's CNN scale
    assert C.index_bytes(1 << 16) == 2
    assert C.index_bytes((1 << 16) + 1) == 3
    assert C.index_bytes(1 << 26) == 4  # LLM scale: 4 bytes, the old flat rate
    # small-d local payloads are strictly cheaper than the old accounting
    loc = C.SparsifierConfig(kind="randk", ratio=0.25, local=True)
    k = C.payload_floats(200, loc)
    assert C.payload_bytes(200, loc, with_mask_indices=True) == 5 * k < 8 * k


def test_compress_none_identity():
    cfg = C.SparsifierConfig(kind="none")
    g = jnp.arange(10.0)
    assert jnp.all(C.compress(g, jnp.ones(10), cfg) == g)


def test_block_hash_deterministic_and_blockwise():
    """The TPU-scale coordinated mask: same key -> identical mask on every
    worker (0-byte broadcast), different round keys -> different masks,
    decisions constant within blocks."""
    cfg = C.SparsifierConfig(kind="block_hash", ratio=0.3, block_size=8)
    m1 = np.asarray(C.make_mask(jax.random.PRNGKey(5), 256, cfg))
    m2 = np.asarray(C.make_mask(jax.random.PRNGKey(5), 256, cfg))
    m3 = np.asarray(C.make_mask(jax.random.PRNGKey(6), 256, cfg))
    assert np.array_equal(m1, m2)
    assert not np.array_equal(m1, m3)
    blocks = m1.reshape(-1, 8)
    assert np.all((blocks.sum(1) == 0) | (blocks.sum(1) == 8))


def test_natural_compression_unbiased_and_bounded():
    """Appendix C: stochastic power-of-two rounding is an unbiased
    compressor with E||C(x)||^2 <= (9/8)||x||^2."""
    cfg = C.SparsifierConfig(kind="natural")
    g = jnp.asarray([0.75, -1.3, 3.0, 0.0, 1e-4, -2.0, 5.5, 0.001])
    keys = jax.random.split(jax.random.PRNGKey(0), 6000)
    est = jax.vmap(
        lambda k: C.compress(g, C.make_mask(k, g.shape[0], cfg), cfg))(keys)
    mean = jnp.mean(est, axis=0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g), rtol=0.05,
                               atol=1e-6)
    # all outputs are signed powers of two (or zero)
    nz = np.asarray(est)[np.asarray(est) != 0]
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-5)
    second = float(jnp.mean(jnp.sum(jnp.square(est), axis=1)))
    assert second <= 9 / 8 * float(jnp.sum(jnp.square(g))) * 1.05
    # wire cost ~9 bits/coordinate
    assert C.payload_bytes(1024, cfg) < 1024 * 4 / 3


# --------------------------------------------------------------------------
# Compressor contracts under static AND traced keep-ratios (satellite):
# the fused grid axis feeds the ratio in as data, so the keep-ratio,
# unbiasedness, and contraction properties must hold on both paths.
# --------------------------------------------------------------------------


@given(ratio=st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_traced_ratio_mask_matches_static(ratio):
    """Contract: a traced ratio reproduces the static-config mask exactly
    (same key), so fusing the ratio axis cannot change trajectories."""
    d = 192
    for kind in C.TRACED_RATIO_KINDS:
        cfg = C.SparsifierConfig(kind=kind, ratio=ratio, block_size=8)
        neutral = C.SparsifierConfig(kind=kind, ratio=1.0, block_size=8)
        key = jax.random.PRNGKey(int(ratio * 1e6))
        m_static = C.make_mask(key, d, cfg)
        m_traced = C.make_mask(key, d, neutral, ratio=jnp.float32(ratio))
        np.testing.assert_array_equal(np.asarray(m_static),
                                      np.asarray(m_traced), err_msg=kind)


@given(ratio=st.floats(0.1, 0.9))
@settings(max_examples=5, deadline=None)
def test_keep_ratio_static_and_traced(ratio):
    """E[k]/d ~= ratio for the Bernoulli-family sparsifiers, on both the
    static and the traced path."""
    d = 256
    for kind in C.TRACED_RATIO_KINDS:
        cfg = C.SparsifierConfig(kind=kind, ratio=ratio, block_size=8)
        keys = jax.random.split(jax.random.PRNGKey(3), 400)
        dens_s = jax.vmap(lambda k: jnp.mean(C.make_mask(k, d, cfg)))(keys)
        dens_t = jax.vmap(lambda k: jnp.mean(C.make_mask(
            k, d, C.SparsifierConfig(kind=kind, ratio=1.0, block_size=8),
            ratio=jnp.float32(ratio))))(keys)
        assert abs(float(jnp.mean(dens_s)) - ratio) < 0.05, kind
        assert abs(float(jnp.mean(dens_t)) - ratio) < 0.05, kind


def test_randk_exact_keep_ratio_property():
    """randk's k is exact (not just in expectation) for every ratio/d."""
    for d in (17, 64, 201):
        for ratio in (0.1, 0.33, 0.8):
            cfg = C.SparsifierConfig(kind="randk", ratio=ratio)
            m = C.make_mask(jax.random.PRNGKey(d), d, cfg)
            assert int(np.asarray(m).sum()) == cfg.k(d)


@pytest.mark.parametrize("kind", C.TRACED_RATIO_KINDS)
def test_unbiasedness_under_traced_ratio(kind):
    """E[(1/r)(g o mask)] = g when the ratio arrives as traced data."""
    d, ratio = 64, 0.25
    neutral = C.SparsifierConfig(kind=kind, ratio=1.0, block_size=8)
    g = jax.random.normal(jax.random.PRNGKey(0), (d,))
    keys = jax.random.split(jax.random.PRNGKey(42), 4000)
    r = jnp.float32(ratio)
    est = jax.vmap(lambda k: C.compress(
        g, C.make_mask(k, d, neutral, ratio=r), neutral, ratio=r))(keys)
    mean = jnp.mean(est, axis=0)
    assert float(jnp.max(jnp.abs(mean - g))) < 0.15 * float(
        jnp.max(jnp.abs(g)) + 0.3)


@pytest.mark.parametrize("traced", [False, True])
def test_bernoulli_contraction_envelope(traced):
    """E||C(g) - g||^2 = (1/r - 1)||g||^2 for Bernoulli masks (the alpha-
    scaled variance bound of the paper's omega-compressor class), identical
    on the static and traced paths."""
    d, ratio = 80, 0.2
    g = jax.random.normal(jax.random.PRNGKey(1), (d,))
    keys = jax.random.split(jax.random.PRNGKey(9), 4000)
    if traced:
        cfg = C.SparsifierConfig(kind="bernoulli", ratio=1.0)
        r = jnp.float32(ratio)
        est = jax.vmap(lambda k: C.compress(
            g, C.make_mask(k, d, cfg, ratio=r), cfg, ratio=r))(keys)
    else:
        cfg = C.SparsifierConfig(kind="bernoulli", ratio=ratio)
        est = jax.vmap(
            lambda k: C.compress(g, C.make_mask(k, d, cfg), cfg))(keys)
    var = float(jnp.mean(jnp.sum(jnp.square(est - g[None]), axis=1)))
    bound = (1.0 / ratio - 1.0) * float(jnp.sum(jnp.square(g)))
    assert 0.85 * bound <= var <= 1.15 * bound


def test_clip_norm_bounds_worker_rows():
    from repro.core import (AlgorithmConfig, AggregatorConfig, AttackConfig,
                            init_state, server_round)
    cfg = AlgorithmConfig(name="rosdhb", n_workers=4, f=0, beta=0.0,
                          clip_norm=1.0,
                          sparsifier=C.SparsifierConfig(kind="none"),
                          aggregator=AggregatorConfig(name="mean"),
                          attack=AttackConfig(name="none"))
    st = init_state(cfg, 8)
    g = jnp.ones((4, 8)) * 100.0
    r, _, _ = server_round(cfg, st, g, jax.random.PRNGKey(0))
    # each row clipped to norm 1 -> mean direction has norm <= 1
    assert float(jnp.linalg.norm(r)) <= 1.0 + 1e-5


# --------------------------------------------------------------------------
# Pallas rand-k kernel dispatch (compressed_estimate use_pallas path)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("local", [False, True])
def test_compressed_estimate_kernel_matches_jnp(local):
    """The Pallas block-rand-k round trip (interpret mode off-TPU) must be
    bit-for-bit the jnp mask-multiply: the traced-mask contract samples the
    SAME blocks from the same key, and keep/zero is exact in f32."""
    n, d, block = 6, 512, 128
    cfg = C.SparsifierConfig(kind="block", ratio=0.25, block_size=block,
                             local=local)
    grads = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    key = jax.random.PRNGKey(7)
    ref = C.compressed_estimate(grads, key, dataclasses.replace(cfg, use_pallas=False))
    got = C.compressed_estimate(grads, key, dataclasses.replace(cfg, use_pallas=True))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # sanity: it actually compressed (3/4 of blocks zeroed)
    kept = float(jnp.mean(jnp.any(ref.reshape(n, -1, block) != 0, axis=-1)))
    assert kept <= 0.5


def test_compressed_estimate_kernel_ineligible_falls_back():
    """d not a block multiple / traced ratio / kind != block all dispatch to
    the jnp path even with use_pallas=True — identical results, no crash."""
    key = jax.random.PRNGKey(0)
    # d % block_size != 0
    cfg = C.SparsifierConfig(kind="block", ratio=0.25, block_size=128,
                             use_pallas=True)
    g = jax.random.normal(key, (4, 200))
    np.testing.assert_array_equal(
        np.asarray(C.compressed_estimate(g, key, cfg)),
        np.asarray(C.compressed_estimate(g, key, dataclasses.replace(
            cfg, use_pallas=False))))
    # ratio=1.0 (no compression) stays on the mask path
    cfg2 = C.SparsifierConfig(kind="block", ratio=1.0, block_size=64,
                              use_pallas=True)
    g2 = jax.random.normal(key, (4, 256))
    np.testing.assert_array_equal(
        np.asarray(C.compressed_estimate(g2, key, cfg2)),
        np.asarray(C.compressed_estimate(g2, key, dataclasses.replace(
            cfg2, use_pallas=False))))
    # non-block kinds never hit the kernel
    cfg3 = C.SparsifierConfig(kind="randk", ratio=0.25, use_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(C.compressed_estimate(g2, key, cfg3)),
        np.asarray(C.compressed_estimate(g2, key, dataclasses.replace(
            cfg3, use_pallas=False))))


def test_kernel_backend_label_resolution():
    assert C.kernel_backend_label(
        C.SparsifierConfig(kind="block", use_pallas=False)) == "jnp"
    lbl = C.kernel_backend_label(
        C.SparsifierConfig(kind="block", use_pallas=True))
    assert lbl in ("pallas", "pallas-interpret")
    auto = C.kernel_backend_label(C.SparsifierConfig(kind="block"))
    assert auto in ("jnp", "pallas")  # None -> TPU auto-detect
