"""Batched experiment engine tests: the lax.scan rollout must reproduce the
legacy per-round Python loop exactly, and the vmap-over-seeds sweep must
match per-seed sequential rollouts (tentpole of the scan/vmap engine PR).
"""

import jax
import numpy as np
import pytest

from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, Simulator,
    SparsifierConfig, bytes_to_threshold, grid_scenarios, quadratic_testbed,
    rollout_over_seeds, run_scenarios, stack_batches,
)
from repro.core.sweep import eval_over_seeds, init_states

N, F, D, STEPS = 13, 3, 48, 50


def _sim(algo, attack="alie", agg=None, ratio=0.2, local=False):
    loss_fn, params0, batch_fn, tg = quadratic_testbed(N, D)
    agg = agg or ("mean" if algo == "dgd" else "cwtm")
    cfg = AlgorithmConfig(
        name=algo, n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(
            kind="randk", ratio=1.0 if algo == "robust_dgd" else ratio,
            local=local),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=(agg != "mean")),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))
    return Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg), batch_fn, tg


@pytest.mark.parametrize("algo,attack", [
    ("rosdhb", "alie"),
    ("dasha", "alie"),
    ("dgd", "signflip"),
    ("robust_dgd", "foe"),
])
def test_scan_rollout_matches_per_round_loop(algo, attack):
    """Full-trajectory equivalence under f>0 attacks, for every algorithm."""
    sim, batch_fn, _ = _sim(algo, attack=attack)
    st_loop = sim.init(0)
    loop_metrics = []
    for t in range(STEPS):
        st_loop, m = sim._round(st_loop, batch_fn(t))
        loop_metrics.append({k: float(v) for k, v in m.items()})
    st_scan, ms = sim.rollout(sim.init(0), batch_fn, steps=STEPS)

    np.testing.assert_allclose(np.asarray(st_scan.params_flat),
                               np.asarray(st_loop.params_flat),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(st_scan.server.momentum),
                               np.asarray(st_loop.server.momentum),
                               rtol=1e-5, atol=1e-7)
    assert int(st_scan.server.step) == int(st_loop.server.step) == STEPS
    for k in ("loss", "grad_norm", "dir_norm"):
        np.testing.assert_allclose(
            np.asarray(ms[k]), np.asarray([m[k] for m in loop_metrics]),
            rtol=1e-5, atol=1e-7, err_msg=f"{algo}/{attack}/{k}")


def test_scan_rollout_local_masks_match():
    """RoSDHB-Local (per-worker masks) is scan-safe too."""
    sim, batch_fn, _ = _sim("rosdhb", local=True)
    st_loop = sim.init(1)
    for t in range(STEPS):
        st_loop, _ = sim._round(st_loop, batch_fn(t))
    st_scan, _ = sim.rollout(sim.init(1), batch_fn, steps=STEPS)
    np.testing.assert_allclose(np.asarray(st_scan.params_flat),
                               np.asarray(st_loop.params_flat),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("algo", ["rosdhb", "dasha"])
def test_vmap_sweep_matches_sequential_seeds(algo):
    """rollout_over_seeds == per-seed sequential rollouts, bit for bit in
    structure and close in value."""
    sim, batch_fn, _ = _sim(algo)
    seeds = [0, 1, 2, 3]
    batches = stack_batches(batch_fn, STEPS)
    states, metrics = rollout_over_seeds(sim, seeds, batches)
    assert np.asarray(metrics["loss"]).shape == (len(seeds), STEPS)
    for i, s in enumerate(seeds):
        st_seq, ms_seq = sim.rollout(sim.init(s), batches)
        np.testing.assert_allclose(
            np.asarray(states.params_flat[i]), np.asarray(st_seq.params_flat),
            rtol=1e-5, atol=1e-7, err_msg=f"seed {s}")
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][i]), np.asarray(ms_seq["loss"]),
            rtol=1e-5, atol=1e-7)


def test_run_wrapper_matches_legacy_history():
    """Simulator.run (chunked scan) reproduces run_per_round's eval schedule,
    history, and early stopping."""
    sim, batch_fn, tg = _sim("rosdhb")
    kw = dict(steps=23, eval_every=5)
    st_a, h_a = sim.run_per_round(sim.init(0), batch_fn, **kw)
    st_b, h_b = sim.run(sim.init(0), batch_fn, **kw)
    assert h_a["step"] == h_b["step"] == [0, 5, 10, 15, 20, 22]
    assert h_a["comm_bytes"] == h_b["comm_bytes"]
    np.testing.assert_allclose(h_a["loss"], h_b["loss"], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st_a.params_flat),
                               np.asarray(st_b.params_flat),
                               rtol=1e-5, atol=1e-7)

    # early stop fires at the same eval round on both engines
    thresh = h_a["loss"][2]
    stop = lambda m: m["loss"] <= thresh  # noqa: E731
    _, h_c = sim.run_per_round(sim.init(0), batch_fn, stop_fn=stop, **kw)
    _, h_d = sim.run(sim.init(0), batch_fn, stop_fn=stop, **kw)
    assert h_c["step"] == h_d["step"]
    assert len(h_d["step"]) < len(h_b["step"])


def test_run_without_eval_is_single_scan():
    sim, batch_fn, _ = _sim("rosdhb")
    st, hist = sim.run(sim.init(0), batch_fn, steps=7)
    assert hist["step"] == [] and int(st.server.step) == 7


def test_stack_batches_orders_stateful_streams():
    calls = []

    def batch_fn(t):
        calls.append(t)
        return {"x": np.full((2, 3), t, np.float32)}

    b = stack_batches(batch_fn, 4, start=2)
    assert calls == [2, 3, 4, 5]
    assert b["x"].shape == (4, 2, 3)
    np.testing.assert_array_equal(b["x"][:, 0, 0], [2, 3, 4, 5])


def test_grid_scenarios_and_results_table():
    scenarios = grid_scenarios(["rosdhb", "dgd"], ["alie", "foe"], ["cwtm"],
                               n_honest=8, f=2, ratio=0.25, gamma=0.05)
    assert len(scenarios) == 4
    assert {s.cfg.attack.name for s in scenarios} == {"alie", "foe"}
    # dgd always pairs with its non-robust mean corner
    assert all(s.cfg.aggregator.name == "mean" for s in scenarios
               if s.cfg.name == "dgd")

    loss_fn, params0, batch_fn, _ = quadratic_testbed(10, 16)
    rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=[0, 1], steps=10)
    assert len(rows) == 8  # 4 scenarios x 2 seeds
    assert {r["seed"] for r in rows} == {0, 1}
    for r in rows:
        assert np.isfinite(r["final_loss"]) or r["algo"] == "dgd"
        assert r["comm_bytes"] > 0


def test_eval_over_seeds_matches_sequential():
    sim, batch_fn, tg = _sim("rosdhb")
    sim = Simulator(loss_fn=sim.loss_fn, params0=sim.params0, cfg=sim.cfg,
                    eval_fn=lambda p, b: {
                        "dist": jax.numpy.linalg.norm(p["w"] - b["opt"])})
    eval_batch = {"opt": np.asarray(tg[F:]).mean(0)}
    seeds = [0, 1]
    states, _ = rollout_over_seeds(sim, seeds, batch_fn, steps=20)
    batched = eval_over_seeds(sim, states, eval_batch)
    for i, s in enumerate(seeds):
        st, _ = sim.rollout(sim.init(s), batch_fn, steps=20)
        one = sim.eval_fn(sim.params(st), eval_batch)
        np.testing.assert_allclose(float(batched["dist"][i]),
                                   float(one["dist"]), rtol=1e-5)


def test_fused_attack_rollout_matches_per_attack_scenarios():
    """The traced linear-attack axis (one compile for the whole attack grid)
    reproduces the per-attack compiled programs."""
    import dataclasses

    from repro.core import fused_attack_rollout

    attacks = [AttackConfig(name="alie", z=1.5),
               AttackConfig(name="foe"),
               AttackConfig(name="signflip")]
    sim_ref, batch_fn, _ = _sim("rosdhb")
    batches = stack_batches(batch_fn, 30)
    seeds = [0, 1]
    lin = dataclasses.replace(sim_ref.cfg, attack=AttackConfig(name="linear"))
    sim = Simulator(loss_fn=sim_ref.loss_fn, params0=sim_ref.params0, cfg=lin)
    states, metrics = fused_attack_rollout(sim, attacks, seeds, batches)
    assert np.asarray(metrics["loss"]).shape == (len(attacks), len(seeds), 30)
    for a, atk in enumerate(attacks):
        cfg = dataclasses.replace(sim_ref.cfg, attack=atk)
        ref = Simulator(loss_fn=sim_ref.loss_fn, params0=sim_ref.params0,
                        cfg=cfg)
        ref_states, ref_metrics = rollout_over_seeds(ref, seeds, batches)
        np.testing.assert_allclose(
            np.asarray(states.params_flat[a]),
            np.asarray(ref_states.params_flat),
            rtol=1e-5, atol=1e-7, err_msg=atk.name)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][a]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7)


def test_fused_attack_rollout_rejects_nonlinear_attacks():
    import dataclasses

    from repro.core import fused_attack_rollout

    sim_ref, batch_fn, _ = _sim("rosdhb")
    lin = dataclasses.replace(sim_ref.cfg, attack=AttackConfig(name="linear"))
    sim = Simulator(loss_fn=sim_ref.loss_fn, params0=sim_ref.params0, cfg=lin)
    with pytest.raises(ValueError, match="linear"):
        fused_attack_rollout(sim, [AttackConfig(name="mimic")], [0],
                             batch_fn, steps=2)


def test_run_scenarios_fusion_matches_unfused():
    loss_fn, params0, batch_fn, _ = quadratic_testbed(10, 16)
    scenarios = grid_scenarios(["rosdhb"], ["alie", "foe", "zero"], ["cwtm"],
                               n_honest=8, f=2, ratio=0.25)
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1], steps=15)
    fused = run_scenarios(scenarios, fuse_attacks=True, **kw)
    unfused = run_scenarios(scenarios, fuse_attacks=False, **kw)
    assert [(r["scenario"], r["seed"]) for r in fused] == \
        [(r["scenario"], r["seed"]) for r in unfused]
    for rf, ru in zip(fused, unfused):
        np.testing.assert_allclose(rf["final_loss"], ru["final_loss"],
                                   rtol=1e-5)
        np.testing.assert_allclose(rf["min_loss"], ru["min_loss"], rtol=1e-5)


def test_linear_coeffs_cover_the_mean_std_family():
    from repro.core.attacks import _alie_z, linear_coeffs

    n, f = 13, 3
    assert linear_coeffs(AttackConfig(name="alie", z=1.5), n, f) == (1.0, -1.5)
    a, b = linear_coeffs(AttackConfig(name="alie"), n, f)
    assert b == -_alie_z(n, f)
    assert linear_coeffs(AttackConfig(name="signflip"), n, f) == (-1.0, 0.0)
    assert linear_coeffs(AttackConfig(name="foe"), n, f) == (-10.0, 0.0)
    assert linear_coeffs(AttackConfig(name="ipm"), n, f) == (-0.5, 0.0)
    assert linear_coeffs(AttackConfig(name="zero"), n, f) == (0.0, 0.0)
    assert linear_coeffs(AttackConfig(name="mimic"), n, f) is None
    assert linear_coeffs(AttackConfig(name="gauss"), n, f) is None


def test_bytes_to_threshold_post_hoc():
    traj = np.asarray([5.0, 3.0, 1.0, 0.5, 0.4])
    assert bytes_to_threshold(traj, 100, 1.0) == 300.0  # crosses at round 3
    assert bytes_to_threshold(traj, 100, 0.1) == np.inf
    stacked = np.stack([traj, traj * 10])
    np.testing.assert_array_equal(bytes_to_threshold(stacked, 100, 1.0),
                                  [300.0, np.inf])
    # rising-metric mode (accuracy-to-tau)
    acc = np.asarray([0.1, 0.5, 0.9])
    assert bytes_to_threshold(acc, 7, 0.85, mode=">=") == 21.0


def test_init_states_stacks_seed_axis():
    sim, _, _ = _sim("rosdhb")
    states = init_states(sim, [0, 1, 2])
    assert states.params_flat.shape == (3, sim.spec.padded_size)
    keys = np.asarray(states.key)
    assert not np.array_equal(keys[0], keys[1])
