"""Carry-specialisation property tests (the PR-6 tentpole).

A dasha-free config (or bank) scans a pruned ``ServerState`` — no
``mirror``/``prev_grad`` leaves — and must reproduce the legacy padded-state
trajectory BIT-FOR-BIT: those slots were provably inert for non-dasha update
rules (tests/test_algo_bank.py pins the inertness), so removing them from
the carry cannot change a single bit of params/metrics. Mixed banks with a
dasha branch must keep the full width, dasha with a pruned layout must fail
loudly, and the per-algorithm state-memory accounting must show the paper's
RoSDHB-vs-Byz-DASHA-PAGE gap (arXiv 2508.17129: RoSDHB needs less per-client
memory — momentum only, vs momentum + mirror + prev_grad).
"""

import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    ALGO_BANK, AlgorithmConfig, AggregatorConfig, AttackConfig, Simulator,
    SparsifierConfig, StateLayout, grid_scenarios, init_state, plan_grid,
    quadratic_testbed, server_state_bytes, stack_batches,
)
from repro.core.sweep import fused_grid_rollout

N, F, D, STEPS = 13, 3, 16, 8
SEEDS = (0, 1)
DASHA_FREE = ("rosdhb", "dgd", "robust_dgd")


def _cfg(algo, attack="alie", agg="cwtm", **kw):
    return AlgorithmConfig(
        name=algo, n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.2),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=True),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None),
        **kw)


def _rollout(cfg, seed, steps=STEPS):
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
    st_, metrics = sim.rollout(sim.init(seed), batch_fn, steps=steps)
    return sim, st_, metrics


# --------------------------------------------------------------------------
# layout resolution
# --------------------------------------------------------------------------


def test_layout_resolution_prunes_exactly_the_dasha_free_configs():
    for algo in DASHA_FREE:
        assert _cfg(algo).resolved_state_layout() == StateLayout.pruned()
    assert _cfg("dasha").resolved_state_layout() == StateLayout.full()
    mixed = dataclasses.replace(_cfg("rosdhb"), name="bank",
                                bank=("rosdhb", "dasha"))
    assert mixed.resolved_state_layout() == StateLayout.full()
    free = dataclasses.replace(_cfg("rosdhb"), name="bank",
                               bank=("rosdhb", "dgd"))
    assert free.resolved_state_layout() == StateLayout.pruned()
    # name='bank' with bank=None means the full ALGO_BANK — dasha included
    allb = dataclasses.replace(_cfg("rosdhb"), name="bank", bank=None)
    assert allb.resolved_state_layout() == StateLayout.full()
    # an explicit layout wins over the inferred one
    forced = dataclasses.replace(_cfg("rosdhb"),
                                 state_layout=StateLayout.full())
    assert forced.resolved_state_layout() == StateLayout.full()


def test_dasha_with_pruned_layout_fails_loudly():
    bad = dataclasses.replace(_cfg("dasha"),
                              state_layout=StateLayout.pruned())
    with pytest.raises(ValueError, match="prunes mirror/prev_grad"):
        init_state(bad, D)
    from repro.core import make_algorithm_bank
    bad_bank = dataclasses.replace(_cfg("rosdhb"), name="bank",
                                   bank=("rosdhb", "dasha"),
                                   state_layout=StateLayout.pruned())
    with pytest.raises(ValueError, match="prunes mirror/prev_grad"):
        make_algorithm_bank(bad_bank)


# --------------------------------------------------------------------------
# bit-for-bit parity: pruned carry == legacy padded carry
# --------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(algo=st.integers(0, len(DASHA_FREE) - 1), seed=st.integers(0, 31),
       gamma=st.floats(0.01, 0.1))
def test_pruned_state_matches_padded_trajectory_bitwise(algo, seed, gamma):
    """Property (standalone scan): for any dasha-free algorithm, seed, and
    step size, the default pruned carry reproduces the forced-full padded
    carry bit-for-bit — params, momentum, and every metric."""
    cfg = dataclasses.replace(_cfg(DASHA_FREE[algo]), gamma=gamma)
    assert cfg.resolved_state_layout() == StateLayout.pruned()
    _, st_p, m_p = _rollout(cfg, seed, steps=5)
    full = dataclasses.replace(cfg, state_layout=StateLayout.full())
    _, st_f, m_f = _rollout(full, seed, steps=5)
    assert st_p.server.mirror is None and st_p.server.prev_grad is None
    np.testing.assert_array_equal(np.asarray(st_p.params_flat),
                                  np.asarray(st_f.params_flat))
    np.testing.assert_array_equal(np.asarray(st_p.server.momentum),
                                  np.asarray(st_f.server.momentum))
    for k in m_p:
        np.testing.assert_array_equal(np.asarray(m_p[k]),
                                      np.asarray(m_f[k]), err_msg=k)


def test_pruned_bank_matches_padded_bank_bitwise():
    """The same property through a fused dasha-free cross-algorithm bank:
    plan_grid prunes its carry, and the bank program's whole cells x seeds
    grid matches the forced-full bank bit-for-bit."""
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    scenarios = grid_scenarios(DASHA_FREE, ("alie", "foe"), ("cwtm",),
                               n_honest=N - F, f=F, ratio=0.2, gamma=0.05)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1
    bank = plan.banks[0]
    assert bank.cfg.resolved_state_layout() == StateLayout.pruned()
    batches = stack_batches(batch_fn, STEPS)

    def run(cfg):
        sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
        return fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                  batches, shard=False)

    st_p, m_p = run(bank.cfg)
    st_f, m_f = run(dataclasses.replace(bank.cfg,
                                        state_layout=StateLayout.full()))
    assert st_p.server.mirror is None and st_f.server.mirror is not None
    np.testing.assert_array_equal(np.asarray(st_p.params_flat),
                                  np.asarray(st_f.params_flat))
    np.testing.assert_array_equal(np.asarray(m_p["loss"]),
                                  np.asarray(m_f["loss"]))


def test_mixed_bank_keeps_full_width_and_dasha_uses_it():
    """A bank WITH a dasha branch must keep the full carry (plan_grid leaves
    the layout full) and its dasha cells must actually move the slots."""
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    scenarios = grid_scenarios(ALGO_BANK, ("alie",), ("cwtm",),
                               n_honest=N - F, f=F, ratio=0.2, gamma=0.05)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1
    bank = plan.banks[0]
    assert bank.cfg.resolved_state_layout() == StateLayout.full()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    states, _ = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                   stack_batches(batch_fn, STEPS),
                                   shard=False)
    mirror = np.asarray(states.server.mirror)
    dasha_cells = [c for c, sc in enumerate(bank.scenarios)
                   if sc.cfg.name == "dasha"]
    assert dasha_cells and all(np.any(mirror[c] != 0) for c in dasha_cells)


def test_checkpoint_roundtrip_with_pruned_state(tmp_path):
    """The pruned carry (None leaves) survives the path-based checkpoint
    save/restore unchanged."""
    from repro import checkpoint as ckpt
    _, st_, _ = _rollout(_cfg("rosdhb"), seed=0, steps=3)
    assert st_.server.mirror is None
    path = str(tmp_path / "state.npz")
    ckpt.save(path, st_._asdict(), step=3)
    restored = ckpt.restore(path, st_._asdict())
    assert restored["server"].mirror is None
    np.testing.assert_array_equal(np.asarray(st_.server.momentum),
                                  restored["server"].momentum)


# --------------------------------------------------------------------------
# memory accounting (the paper's RoSDHB vs Byz-DASHA-PAGE claim)
# --------------------------------------------------------------------------


def test_server_state_bytes_matches_paper_memory_gap():
    rosdhb = server_state_bytes(_cfg("rosdhb"), D)
    dasha = server_state_bytes(_cfg("dasha"), D)
    assert rosdhb == N * D * 4            # momentum bank only
    assert dasha == 3 * rosdhb            # + mirror + prev_grad, all f32
    # a forced-full rosdhb pays dasha's footprint (the pre-specialisation
    # engine behaviour this PR removes)
    padded = dataclasses.replace(_cfg("rosdhb"),
                                 state_layout=StateLayout.full())
    assert server_state_bytes(padded, D) == dasha
    loss_fn, params0, _, _ = quadratic_testbed(N, D)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg("rosdhb"))
    assert sim.server_state_bytes() == N * sim.spec.padded_size * 4
    assert sim.state_layout() == StateLayout.pruned()


def test_launch_train_input_specs_follow_layout():
    """The LLM-path abstract input specs mirror init_state's layout: pruned
    server slots are absent (None), dasha keeps them — so the lowered train
    step's state really is momentum-only for RoSDHB at LLM scale."""
    from repro.configs import INPUT_SHAPES, get_arch
    from repro.launch import steps as L

    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    spec = get_arch("gemma_2b")
    shape = INPUT_SHAPES["train_4k"]

    def server_specs(algo):
        plan = L.make_train_plan(spec, shape, mesh,
                                 algo_overrides={"name": algo}, n_workers=4)
        state, _ = L.train_input_specs(plan, mesh)
        return plan, state.server

    plan, pruned = server_specs("rosdhb")
    assert plan.algo.resolved_state_layout() == StateLayout.pruned()
    assert pruned.mirror is None and pruned.prev_grad is None
    assert pruned.momentum.shape[0] == 4
    _, full = server_specs("dasha")
    assert full.mirror is not None and full.prev_grad is not None
    assert full.mirror.shape == full.momentum.shape
