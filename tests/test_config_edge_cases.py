"""Edge-case unit tests for the config surfaces: Theorem-1 schedules and
robustness-coefficient bounds at their domain boundaries."""

import math

import numpy as np
import pytest

from repro.core import AggregatorConfig, AlgorithmConfig, theorem1_hparams


class TestResolvedBeta:
    def test_explicit_beta_wins(self):
        assert AlgorithmConfig(beta=0.42, gamma=100.0).resolved_beta() == 0.42

    def test_schedule_value(self):
        cfg = AlgorithmConfig(beta=None, gamma=0.01, smoothness_L=1.0)
        assert cfg.resolved_beta() == pytest.approx(math.sqrt(1 - 0.24))

    def test_gamma_too_large_raises(self):
        # Theorem 1 needs gamma <= 1/(24 L); at the boundary the sqrt
        # argument hits 0 and the schedule degenerates.
        cfg = AlgorithmConfig(beta=None, gamma=1.0 / 24.0, smoothness_L=1.0)
        with pytest.raises(ValueError, match="too large"):
            cfg.resolved_beta()
        cfg = AlgorithmConfig(beta=None, gamma=0.05, smoothness_L=2.0)
        with pytest.raises(ValueError, match="1/\\(24 L\\)"):
            cfg.resolved_beta()

    def test_gamma_just_below_boundary_ok(self):
        cfg = AlgorithmConfig(beta=None, gamma=(1.0 - 1e-6) / 24.0,
                              smoothness_L=1.0)
        assert 0.0 < cfg.resolved_beta() < 0.01


class TestTheorem1Hparams:
    def test_values_and_consistency(self):
        gamma, beta = theorem1_hparams(L=2.0, ratio=0.1)
        assert gamma == pytest.approx(0.1 / (23200 * 2.0))
        assert beta == pytest.approx(math.sqrt(1 - 24 * gamma * 2.0))
        # schedule agrees with resolved_beta on the same gamma
        cfg = AlgorithmConfig(beta=None, gamma=gamma, smoothness_L=2.0)
        assert cfg.resolved_beta() == pytest.approx(beta)

    def test_custom_constant(self):
        gamma, beta = theorem1_hparams(L=1.0, ratio=1.0, c=100.0)
        assert gamma == pytest.approx(0.01)
        assert beta == pytest.approx(math.sqrt(1 - 0.24))

    def test_more_compression_means_smaller_gamma(self):
        g_small, b_small = theorem1_hparams(L=1.0, ratio=0.01)
        g_big, b_big = theorem1_hparams(L=1.0, ratio=0.5)
        assert g_small < g_big
        assert b_small > b_big  # tighter compression -> heavier momentum


class TestKappaBound:
    @pytest.mark.parametrize("name", ["cwtm", "median", "geomed", "krum",
                                      "multikrum"])
    @pytest.mark.parametrize("n,f", [(4, 2), (6, 3), (5, 3), (2, 1)])
    def test_n_at_most_2f_is_inf(self, name, n, f):
        # robustness is information-theoretically impossible at n <= 2f
        assert AggregatorConfig(name=name, f=f).kappa_bound(n) == float("inf")

    def test_f_zero_is_zero(self):
        for name in ["cwtm", "median", "geomed", "krum", "mean"]:
            assert AggregatorConfig(name=name, f=0).kappa_bound(10) == 0.0

    def test_mean_never_robust(self):
        assert AggregatorConfig(name="mean", f=1).kappa_bound(1000) == \
            float("inf")
        # and NNM cannot rescue it (pre_nnm composition skips mean)
        assert AggregatorConfig(name="mean", f=1, pre_nnm=True).kappa_bound(
            1000) == float("inf")

    def test_just_above_breakdown_is_finite(self):
        for name in ["cwtm", "median", "geomed", "krum"]:
            k = AggregatorConfig(name=name, f=2).kappa_bound(5)  # n = 2f + 1
            assert np.isfinite(k) and k > 0

    def test_nnm_improves_cwtm_at_paper_setup(self):
        base = AggregatorConfig(name="cwtm", f=2, pre_nnm=False)
        nnm = AggregatorConfig(name="cwtm", f=2, pre_nnm=True)
        assert nnm.kappa_bound(16) < 2.0  # Theorem-1 precondition regime
        assert np.isfinite(base.kappa_bound(16))
