"""(f, kappa)-robustness property tests (Definition 2.2 of the paper).

The defining inequality — for EVERY subset S of size n - f:
    ||F(x) - mean_S||^2 <= (kappa/|S|) * sum_{i in S} ||x_i - mean_S||^2
is checked with hypothesis-generated inputs against each rule's published
kappa bound.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import aggregators as G


def _check_resilience(agg, kappa: float, x: np.ndarray, f: int) -> bool:
    n = x.shape[0]
    out = np.asarray(agg(jnp.asarray(x)))
    for s in itertools.combinations(range(n), n - f):
        xs = x[list(s)]
        mu = xs.mean(0)
        lhs = float(((out - mu) ** 2).sum())
        rhs = kappa / len(s) * float(((xs - mu) ** 2).sum(1).sum())
        if lhs > rhs + 1e-6:
            return False
    return True


@pytest.mark.parametrize("name", ["cwtm", "median", "geomed", "krum"])
@pytest.mark.parametrize("pre_nnm", [False, True])
def test_robustness_inequality(name, pre_nnm):
    n, f, d = 7, 2, 5
    cfg = G.AggregatorConfig(name=name, f=f, pre_nnm=pre_nnm,
                             geomed_iters=64)
    agg = G.make_aggregator(cfg)
    kappa = cfg.kappa_bound(n)
    rng = np.random.default_rng(0)
    for trial in range(8):
        x = rng.normal(size=(n, d)).astype(np.float32)
        # adversarial rows: blow up the first f
        x[:f] *= rng.uniform(5, 50)
        assert _check_resilience(agg, kappa, x, f), (name, pre_nnm, trial)


@given(st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_cwtm_between_min_max(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(9, 12)).astype(np.float32)
    out = np.asarray(G.trimmed_mean(jnp.asarray(x), f=2))
    assert np.all(out <= x.max(0) + 1e-6)
    assert np.all(out >= x.min(0) - 1e-6)


def test_cwtm_ignores_f_outliers():
    x = np.zeros((10, 4), np.float32)
    x[:3] = 1e9  # 3 Byzantine rows
    out = np.asarray(G.trimmed_mean(jnp.asarray(x), f=3))
    assert np.all(np.abs(out) < 1e-3)


def test_geomed_resists_outliers():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(11, 6)).astype(np.float32)
    x[:2] = 1e6
    out = np.asarray(G.geometric_median(jnp.asarray(x), iters=128))
    assert np.linalg.norm(out) < 10.0


def test_krum_selects_inlier():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 5)).astype(np.float32)
    x[0] = 100.0
    out = np.asarray(G.krum(jnp.asarray(x), f=1))
    assert np.linalg.norm(out) < 10.0


def test_nnm_shape_and_mixing():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(6, 4)).astype(np.float32)
    mixed = np.asarray(G.nnm(jnp.asarray(x), f=2))
    assert mixed.shape == x.shape
    # mixing contracts the spread
    assert mixed.std(0).mean() <= x.std(0).mean() + 1e-6


def test_mean_equals_numpy():
    x = np.arange(12, dtype=np.float32).reshape(4, 3)
    assert np.allclose(np.asarray(G.mean(jnp.asarray(x))), x.mean(0))


def test_kappa_bounds_finite_and_ordered():
    for n, f in [(10, 2), (19, 9), (16, 2)]:
        for name in ["cwtm", "median", "geomed", "krum"]:
            k = G.AggregatorConfig(name=name, f=f).kappa_bound(n)
            assert np.isfinite(k) if n > 2 * f else True
    # mean is never robust
    assert G.AggregatorConfig(name="mean", f=1).kappa_bound(10) == float("inf")
    # cwtm + nnm should satisfy Theorem 1's precondition for the paper's setup
    cfg = G.AggregatorConfig(name="cwtm", f=2, pre_nnm=True)
    assert cfg.kappa_bound(16) < 2.0
