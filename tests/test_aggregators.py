"""(f, kappa)-robustness property tests (Definition 2.2 of the paper).

The defining inequality — for EVERY subset S of size n - f:
    ||F(x) - mean_S||^2 <= (kappa/|S|) * sum_{i in S} ||x_i - mean_S||^2
is checked with hypothesis-generated inputs against each rule's published
kappa bound.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import aggregators as G


def _check_resilience(agg, kappa: float, x: np.ndarray, f: int) -> bool:
    n = x.shape[0]
    out = np.asarray(agg(jnp.asarray(x)))
    for s in itertools.combinations(range(n), n - f):
        xs = x[list(s)]
        mu = xs.mean(0)
        lhs = float(((out - mu) ** 2).sum())
        rhs = kappa / len(s) * float(((xs - mu) ** 2).sum(1).sum())
        if lhs > rhs + 1e-6:
            return False
    return True


@pytest.mark.parametrize("name", ["cwtm", "median", "geomed", "krum"])
@pytest.mark.parametrize("pre_nnm", [False, True])
def test_robustness_inequality(name, pre_nnm):
    n, f, d = 7, 2, 5
    cfg = G.AggregatorConfig(name=name, f=f, pre_nnm=pre_nnm,
                             geomed_iters=64)
    agg = G.make_aggregator(cfg)
    kappa = cfg.kappa_bound(n)
    rng = np.random.default_rng(0)
    for trial in range(8):
        x = rng.normal(size=(n, d)).astype(np.float32)
        # adversarial rows: blow up the first f
        x[:f] *= rng.uniform(5, 50)
        assert _check_resilience(agg, kappa, x, f), (name, pre_nnm, trial)


@given(st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_cwtm_between_min_max(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(9, 12)).astype(np.float32)
    out = np.asarray(G.trimmed_mean(jnp.asarray(x), f=2))
    assert np.all(out <= x.max(0) + 1e-6)
    assert np.all(out >= x.min(0) - 1e-6)


def test_cwtm_ignores_f_outliers():
    x = np.zeros((10, 4), np.float32)
    x[:3] = 1e9  # 3 Byzantine rows
    out = np.asarray(G.trimmed_mean(jnp.asarray(x), f=3))
    assert np.all(np.abs(out) < 1e-3)


def test_geomed_resists_outliers():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(11, 6)).astype(np.float32)
    x[:2] = 1e6
    out = np.asarray(G.geometric_median(jnp.asarray(x), iters=128))
    assert np.linalg.norm(out) < 10.0


def test_krum_selects_inlier():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 5)).astype(np.float32)
    x[0] = 100.0
    out = np.asarray(G.krum(jnp.asarray(x), f=1))
    assert np.linalg.norm(out) < 10.0


def test_nnm_shape_and_mixing():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(6, 4)).astype(np.float32)
    mixed = np.asarray(G.nnm(jnp.asarray(x), f=2))
    assert mixed.shape == x.shape
    # mixing contracts the spread
    assert mixed.std(0).mean() <= x.std(0).mean() + 1e-6


def test_mean_equals_numpy():
    x = np.arange(12, dtype=np.float32).reshape(4, 3)
    assert np.allclose(np.asarray(G.mean(jnp.asarray(x))), x.mean(0))


@pytest.mark.parametrize("name", G.BANK_NAMES)
@pytest.mark.parametrize("pre_nnm", [False, True])
def test_bank_matches_direct_aggregator(name, pre_nnm):
    """The switch-bank branch selected by index reproduces the directly
    built aggregator for every rule, with and without NNM."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(11, 24)).astype(np.float32))
    cfg = G.AggregatorConfig(name=name, f=2, pre_nnm=pre_nnm)
    direct = np.asarray(G.make_aggregator(cfg)(x))
    bank = G.make_aggregator_bank(G.AggregatorConfig(name="bank", f=2))
    via_bank = np.asarray(bank(x, jnp.int32(G.bank_index(cfg))))
    np.testing.assert_allclose(via_bank, direct, rtol=1e-6, atol=1e-7)


def test_bank_index_mapping():
    # mean + NNM maps onto the plain-mean branch (NNM skips mean)
    assert G.bank_index(G.AggregatorConfig(name="mean", pre_nnm=True)) == \
        G.bank_index(G.AggregatorConfig(name="mean", pre_nnm=False))
    # restricted banks index within their own branch tuple
    bank = (("cwtm", True), ("median", False))
    assert G.bank_index(G.AggregatorConfig(name="median"), bank) == 1
    with pytest.raises(ValueError, match="not a branch"):
        G.bank_index(G.AggregatorConfig(name="krum"), bank)


def test_restricted_bank_only_builds_listed_branches():
    bank_cfg = G.AggregatorConfig(name="bank", f=2,
                                  bank=(("cwtm", False), ("geomed", False)))
    bank = G.make_aggregator_bank(bank_cfg)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(9, 6)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(bank(x, jnp.int32(0))),
        np.asarray(G.trimmed_mean(x, f=2)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(bank(x, jnp.int32(1))),
        np.asarray(G.geometric_median(x, iters=8)), rtol=1e-6)


def test_bank_vmapped_index_selects_per_lane():
    """Under vmap the switch becomes a per-lane select — each lane must
    still get exactly its own rule's output."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    bank = G.make_aggregator_bank(G.AggregatorConfig(name="bank", f=1))
    idxs = jnp.asarray([G.bank_index(G.AggregatorConfig(name=n, f=1))
                        for n in ("mean", "cwtm", "median")], jnp.int32)
    out = jax.vmap(lambda i: bank(x, i))(idxs)
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(G.mean(x)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]),
                               np.asarray(G.trimmed_mean(x, f=1)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[2]),
                               np.asarray(G.coordinate_median(x)), rtol=1e-6)


def test_kappa_bound_unknown_name_raises_value_error():
    """Unknown names raise ValueError (not a bare KeyError), matching
    make_aggregator's validation."""
    with pytest.raises(ValueError, match="unknown aggregator"):
        G.AggregatorConfig(name="trimmed", f=2).kappa_bound(10)
    with pytest.raises(ValueError, match="unknown aggregator"):
        G.AggregatorConfig(name="bank", f=0).kappa_bound(10)


def test_kappa_bounds_finite_and_ordered():
    for n, f in [(10, 2), (19, 9), (16, 2)]:
        for name in ["cwtm", "median", "geomed", "krum"]:
            k = G.AggregatorConfig(name=name, f=f).kappa_bound(n)
            assert np.isfinite(k) if n > 2 * f else True
    # mean is never robust
    assert G.AggregatorConfig(name="mean", f=1).kappa_bound(10) == float("inf")
    # cwtm + nnm should satisfy Theorem 1's precondition for the paper's setup
    cfg = G.AggregatorConfig(name="cwtm", f=2, pre_nnm=True)
    assert cfg.kappa_bound(16) < 2.0
