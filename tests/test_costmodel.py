"""Cost-model tests: calibration fit, persistence, and plan determinism.

The measured model (repro.core.costmodel) decides fusion vs. per-algorithm
partition per candidate bank in ``plan_grid``. These tests pin: the fit
arithmetic recovers known rates; save/load round-trips (and rejects stale
keys); decisions are DETERMINISTIC given a pinned COST_MODEL.json; the
partitioned plan is still fully fused along the attack/aggregator/ratio
axes and reproduces the fused plan's rows; duplicate scenario labels and a
missing ``rounds`` fail loudly.
"""

import dataclasses
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, CostModel,
    DEFAULT_COST_MODEL, SparsifierConfig, grid_scenarios, plan_grid,
    quadratic_testbed, run_scenarios,
)
from repro.core.sweep import Scenario

N, F, D, STEPS = 13, 3, 16, 8

#: strongly prefers ONE program: branches are free at runtime, compiles
#: are expensive
FUSE_HAPPY = CostModel(compile_s=10.0, compile_s_per_branch=5.0,
                       cell_round_us=100.0, cell_round_us_per_branch=0.0,
                       source="test-fuse")
#: strongly prefers the partition: switch divergence dominates, compiles
#: are free
SPLIT_HAPPY = CostModel(compile_s=0.0, compile_s_per_branch=0.0,
                        cell_round_us=100.0,
                        cell_round_us_per_branch=1e5, source="test-split")


def _grid(algos=("rosdhb", "dgd"), attacks=("alie", "foe"), aggs=("cwtm",)):
    return grid_scenarios(algos, attacks, aggs, n_honest=N - F, f=F,
                          ratio=0.2, gamma=0.05)


# --------------------------------------------------------------------------
# the model itself
# --------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(c1=st.floats(0.5, 5.0), cb=st.floats(0.1, 3.0),
       r1=st.floats(50.0, 500.0), rb=st.floats(10.0, 400.0),
       branches=st.integers(2, 4))
def test_fit_recovers_the_generating_rates(c1, cb, r1, rb, branches):
    """Property: timings synthesised from a known model fit back to it."""
    truth = CostModel(compile_s=c1, compile_s_per_branch=cb,
                      cell_round_us=r1, cell_round_us_per_branch=rb)
    rows_1, rows_w, rounds = 8, 24, 50
    warm_1 = truth.cell_round_us * 1e-6 * rows_1 * rounds
    warm_w = (truth.cell_round_us + truth.cell_round_us_per_branch
              * (branches - 1)) * 1e-6 * rows_w * rounds
    got = CostModel.fit(
        single_cold_s=warm_1 + c1 + cb, single_warm_s=warm_1,
        single_rows=rows_1,
        fused_cold_s=warm_w + c1 + cb * branches, fused_warm_s=warm_w,
        fused_rows=rows_w, branches=branches, rounds=rounds)
    assert got.compile_s == pytest.approx(c1, rel=1e-6, abs=1e-9)
    assert got.compile_s_per_branch == pytest.approx(cb, rel=1e-6)
    assert got.cell_round_us == pytest.approx(r1, rel=1e-6)
    assert got.cell_round_us_per_branch == pytest.approx(rb, rel=1e-6)


def test_fit_clamps_noisy_rates_at_zero():
    # warm "faster" than cold and multi-branch "faster" than single: every
    # derived rate clamps to >= 0 instead of going negative
    m = CostModel.fit(single_cold_s=1.0, single_warm_s=2.0, single_rows=4,
                      fused_cold_s=0.5, fused_warm_s=1.0, fused_rows=16,
                      branches=4, rounds=10)
    assert m.compile_s >= 0 and m.compile_s_per_branch >= 0
    assert m.cell_round_us >= 0 and m.cell_round_us_per_branch >= 0


def test_decision_flips_with_grid_size():
    """The pinned default's structure: tiny/short grids amortise nothing —
    fuse; big/long grids pay branch divergence every round — partition."""
    cells = {"rosdhb": 4, "dasha": 4, "dgd": 2}
    assert DEFAULT_COST_MODEL.prefer_fused(cells, n_seeds=1, rounds=5)
    assert not DEFAULT_COST_MODEL.prefer_fused(cells, n_seeds=16,
                                               rounds=3000)


def test_sharded_compile_overhead_charges_per_program():
    """The mesh-compile overhead is paid once per program, so it penalises
    the many-program partition: a grid on the fused/partitioned knife edge
    tips toward fusing when sharded."""
    m = dataclasses.replace(SPLIT_HAPPY, compile_s=1.0,
                            sharded_compile_overhead_s=2.5)
    cells = {"a": 2, "b": 2, "c": 2}
    for sharded in (False, True):
        fused = m.fused_s(cells, n_seeds=1, rounds=10, sharded=sharded)
        part = m.partitioned_s(cells, n_seeds=1, rounds=10, sharded=sharded)
        base_f = m.fused_s(cells, n_seeds=1, rounds=10)
        base_p = m.partitioned_s(cells, n_seeds=1, rounds=10)
        if sharded:
            # 1 program vs len(cells) programs
            assert fused == pytest.approx(base_f + 2.5)
            assert part == pytest.approx(base_p + 2.5 * len(cells))
        else:
            assert (fused, part) == (base_f, base_p)
    # default: zero overhead, sharded is a no-op
    assert DEFAULT_COST_MODEL.sharded_compile_overhead_s == 0.0
    assert DEFAULT_COST_MODEL.program_s(branches=2, rows=4, rounds=10,
                                        sharded=True) == \
        DEFAULT_COST_MODEL.program_s(branches=2, rows=4, rounds=10)


def test_load_tolerates_pre_sharded_schema(tmp_path):
    """COST_MODEL.json files written before the sharded term existed load
    with the 0.0 default (missing keys are NOT stale keys)."""
    path = str(tmp_path / "COST_MODEL.json")
    DEFAULT_COST_MODEL.save(path)
    with open(path) as fh:
        raw = json.load(fh)
    del raw["sharded_compile_overhead_s"]
    with open(path, "w") as fh:
        json.dump(raw, fh)
    got = CostModel.load(path)
    assert got.sharded_compile_overhead_s == 0.0
    assert got == DEFAULT_COST_MODEL


def test_save_load_roundtrip_and_stale_key_rejection(tmp_path):
    path = str(tmp_path / "COST_MODEL.json")
    saved = dataclasses.replace(DEFAULT_COST_MODEL, source="calib-test")
    assert saved.save(path) == path
    assert CostModel.load(path) == saved
    with open(path) as fh:
        raw = json.load(fh)
    raw["warm_gain"] = 2.0  # a key from an imagined older/newer schema
    with open(path, "w") as fh:
        json.dump(raw, fh)
    with pytest.raises(ValueError, match="unknown cost-model keys"):
        CostModel.load(path)
    # load_or_default: pinned default when nothing is on disk
    missing = str(tmp_path / "nope" / "COST_MODEL.json")
    assert CostModel.load_or_default(missing) == DEFAULT_COST_MODEL


# --------------------------------------------------------------------------
# plan_grid decisions
# --------------------------------------------------------------------------


def test_plan_decisions_deterministic_given_pinned_model(tmp_path):
    """Acceptance: with a pinned COST_MODEL.json the plan (bank partition,
    cell order, notes) is a pure function of the scenario grid."""
    path = str(tmp_path / "COST_MODEL.json")
    SPLIT_HAPPY.save(path)
    scenarios = _grid(algos=("rosdhb", "dasha", "dgd"))
    plans = [plan_grid(scenarios, cost_model=CostModel.load(path),
                       rounds=STEPS, n_seeds=2) for _ in range(3)]
    ref = plans[0]
    assert ref.notes and "partitioned" in ref.notes[0]
    for p in plans[1:]:
        assert [b.cfg for b in p.banks] == [b.cfg for b in ref.banks]
        assert [tuple(sc.label for sc in b.scenarios) for b in p.banks] == \
            [tuple(sc.label for sc in b.scenarios) for b in ref.banks]
        assert [sc.label for sc in p.singles] == \
            [sc.label for sc in ref.singles]
        assert p.notes == ref.notes


def test_cost_model_partition_splits_by_algorithm_only():
    """A partitioned group becomes per-algorithm banks that keep the
    attack/agg axes fused (1-entry algorithm banks, traced hparams);
    single-cell leftovers fall back to singles."""
    scenarios = _grid(algos=("rosdhb", "dasha", "dgd"),
                      attacks=("alie", "foe"))
    fused = plan_grid(scenarios, cost_model=FUSE_HAPPY, rounds=STEPS,
                      n_seeds=2)
    assert fused.n_programs == 1 and "fused" in fused.notes[0]
    assert fused.banks[0].cfg.bank == ("rosdhb", "dasha", "dgd")
    split = plan_grid(scenarios, cost_model=SPLIT_HAPPY, rounds=STEPS,
                      n_seeds=2)
    assert "partitioned" in split.notes[0]
    assert len(split.banks) == 3 and not split.singles
    for b in split.banks:
        assert b.cfg.name == "bank" and len(b.cfg.bank) == 1
        assert b.algo_idx == (0,) * b.n_cells  # still the traced-hparam path
        assert len({sc.cfg.name for sc in b.scenarios}) == 1
    # dasha-free parts get the pruned carry, the dasha part keeps full width
    by_algo = {b.cfg.bank[0]: b for b in split.banks}
    assert not by_algo["rosdhb"].cfg.resolved_state_layout().is_full
    assert by_algo["dasha"].cfg.resolved_state_layout().is_full
    # a 1-cell leftover (dgd has a single mean cell per attack -> with one
    # attack it is a singleton) drops to a classic single
    one = plan_grid(_grid(algos=("rosdhb", "dgd"), attacks=("alie",)),
                    cost_model=SPLIT_HAPPY, rounds=STEPS, n_seeds=2)
    assert [sc.cfg.name for sc in one.singles] == ["rosdhb", "dgd"]


def test_partitioned_rows_match_fused_rows():
    """End to end: the cost-model-partitioned plan reproduces the fused
    plan's result rows (same labels/order, near-identical numerics — the
    multi-branch switch may drift by float-fusion ulps)."""
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    scenarios = _grid()
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1], steps=STEPS, shard=False)
    fused = run_scenarios(scenarios, cost_model=FUSE_HAPPY, **kw)
    split = run_scenarios(scenarios, cost_model=SPLIT_HAPPY, **kw)
    legacy = run_scenarios(scenarios, cross_algo=False, **kw)
    assert [(r["scenario"], r["seed"]) for r in fused] == \
        [(r["scenario"], r["seed"]) for r in split] == \
        [(r["scenario"], r["seed"]) for r in legacy]
    for rf, rs, rl in zip(fused, split, legacy):
        # 1-entry banks are bit-for-bit the legacy per-algorithm banks
        assert rs["final_loss"] == rl["final_loss"], rs["scenario"]
        np.testing.assert_allclose(rf["final_loss"], rs["final_loss"],
                                   rtol=1e-5, err_msg=rf["scenario"])


# --------------------------------------------------------------------------
# loud failure modes
# --------------------------------------------------------------------------


def test_plan_grid_requires_rounds_with_cost_model():
    with pytest.raises(ValueError, match="needs rounds"):
        plan_grid(_grid(), cost_model=DEFAULT_COST_MODEL)


def test_plan_grid_rejects_duplicate_labels():
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.2),
        aggregator=AggregatorConfig(name="cwtm", f=F),
        attack=AttackConfig(name="alie", z=1.5))
    twice = [Scenario(label="cell", cfg=cfg), Scenario(label="cell", cfg=cfg)]
    with pytest.raises(ValueError, match="duplicate scenario labels"):
        plan_grid(twice)
    with pytest.raises(ValueError, match="duplicate scenario labels"):
        plan_grid(twice, fuse=False)
