"""Numerical equivalence of the hand-scheduled bank transforms on a real
multi-device mesh (8 virtual CPU devices via a subprocess, since the device
count is fixed at jax init)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import flatten as sf
    from repro.sharding import partitioning as sp

    try:  # jax >= 0.5: explicit Auto axis types
        from jax.sharding import AxisType
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    except ImportError:  # jax 0.4.x: all mesh axes are Auto already
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    n = 4
    key = jax.random.PRNGKey(0)
    # mimic model params: a model-sharded 2D leaf, an fsdp-style leaf, a
    # replicated vector
    abstract = {
        "wq": {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)},
        "norm": {"scale": jax.ShapeDtypeStruct((8,), jnp.float32)},
        "embed": jax.ShapeDtypeStruct((10, 8), jnp.float32),
    }
    with mesh:
        spec = sf.make_sharded_flat_spec(abstract, mesh, align=1)
        stacked = {
            "wq": {"w": jax.random.normal(key, (n, 8, 16))},
            "norm": {"scale": jax.random.normal(key, (n, 8))},
            "embed": jax.random.normal(key, (n, 10, 8)),
        }

        @jax.jit
        def roundtrip(tree):
            bank = sf.flatten_to_bank(tree, spec, mesh)
            # aggregate = mean over workers, then back to param layout
            direction = jnp.mean(bank, axis=0)
            return sf.bank_to_param_tree(direction, spec, mesh), bank

        out, bank = roundtrip(stacked)
        assert bank.shape == (n, spec.padded_size), bank.shape
        expect = jax.tree_util.tree_map(lambda l: jnp.mean(l, 0), stacked)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(out)[0],
                jax.tree_util.tree_flatten_with_path(expect)[0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, err_msg=str(pa))
        # every coordinate of every worker appears exactly once in the bank
        total = sum(np.prod(l.shape[1:]) for l in
                    jax.tree_util.tree_leaves(stacked))
        nz = sum(int(np.prod(l.shape[1:])) for l in
                 jax.tree_util.tree_leaves(stacked))
        flat_sum = float(jnp.sum(bank))
        tree_sum = float(sum(jnp.sum(l) for l in
                             jax.tree_util.tree_leaves(stacked)))
        np.testing.assert_allclose(flat_sum, tree_sum, rtol=1e-5)
    print("MULTIDEVICE-OK")
""")


@pytest.mark.slow
def test_bank_transforms_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIDEVICE-OK" in r.stdout
