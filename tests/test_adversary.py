"""Adversary subsystem: stateful attack banks, (G,B)-heterogeneity, registry.

Acceptance (ISSUE 3):
* a mixed grid of >= 6 attacks (mimic, gauss, and the adaptive spectral
  attack included) x 3 aggregators compiles to ONE program per algorithm
  bank, and stateful-bank trajectories match the legacy per-round
  ``apply_attack``-style loop bit-for-bit for mimic/gauss;
* Dirichlet partitioner label skew is monotone in alpha and the (G, B)
  probe reports higher G for alpha=0.1 than for i.i.d. splits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adversary import (
    ADVERSARIES, DEFAULT_ATTACK_BANK, ScenarioSpec, attack_index, bank_entry,
    dirichlet_mnist, expand_scenario, gb_probe, get_spec, init_attack_state,
    is_stateful, label_histograms, label_skew, make_attack_bank,
    partition_pool,
)
from repro.adversary import registry as R
from repro.core import (
    AggregatorConfig, AlgorithmConfig, AttackConfig, Simulator,
    SparsifierConfig, attacks as A, grid_scenarios, init_state, plan_grid,
    quadratic_testbed, server_round, stack_batches,
)
from repro.core.sweep import fused_grid_rollout, rollout_over_seeds

N, F, D, STEPS = 13, 3, 32, 12
H = N - F


def _honest_seq(steps=STEPS, h=H, d=D, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (steps, h, d))


def _cfg(attack="alie", agg="cwtm", ratio=0.2):
    return AlgorithmConfig(
        name="rosdhb", n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=True),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))


# --------------------------------------------------------------------------
# Adversary API + attack bank
# --------------------------------------------------------------------------


def test_attack_state_slab_is_uniform():
    st = init_attack_state(7)
    assert st.vec.shape == (7,) and st.mu.shape == (7,)
    assert st.scalars.shape == (4,) and st.step.shape == ()
    assert st.step.dtype == jnp.int32


@pytest.mark.parametrize("name", ["mimic", "gauss", "spectral", "ipm_greedy"])
def test_bank_scan_matches_per_round_loop_bit_for_bit(name):
    """ACCEPTANCE: the fused bank inside ``lax.scan`` reproduces the legacy
    *execution protocol* — one jitted dispatch per round (`Adversary.step`
    for stateful names, `apply_attack` for stateless ones) — EXACTLY: same
    byz payloads, same carried state.  NOTE this gates fused-vs-per-round
    execution, not pre-PR attack semantics: `mimic` on the simulator path
    now MEANS the tracked variant (see
    test_simulator_mimic_is_the_tracked_variant)."""
    honest_seq = _honest_seq()
    keys = jax.random.split(jax.random.PRNGKey(7), STEPS)
    cfg = AttackConfig(name=name)
    branch, coeffs = bank_entry(cfg, N, F)
    idx = jnp.asarray(attack_index(branch), jnp.int32)
    cvec = jnp.asarray(coeffs, jnp.float32)
    bank = make_attack_bank(DEFAULT_ATTACK_BANK, F)

    def step(state, inp):
        h, k = inp
        state, byz = bank(state, h, k, idx, cvec)
        return state, byz

    final, byz_scan = jax.lax.scan(step, init_attack_state(D),
                                   (honest_seq, keys))

    # legacy per-round loop: one jitted dispatch per round (the
    # Simulator.run_per_round protocol), stateless attacks through
    # apply_attack, stateful through the registry step
    if is_stateful(name):
        loop_step = jax.jit(
            lambda st, h, k: ADVERSARIES[name].step(st, h, F, k, cvec))
    else:
        loop_step = jax.jit(
            lambda st, h, k: (st._replace(step=st.step + 1),
                              A.apply_attack(cfg, h, F, key=k)))
    state = init_attack_state(D)
    byz_loop = []
    for t in range(STEPS):
        state, byz = loop_step(state, honest_seq[t], keys[t])
        byz_loop.append(np.asarray(byz))

    np.testing.assert_array_equal(np.asarray(byz_scan),
                                  np.stack(byz_loop), err_msg=name)
    assert int(final.step) == STEPS


def test_bank_linear_branch_matches_apply_attack():
    """The linear branch with alie coefficients == stateless alie."""
    x = _honest_seq(1)[0]
    cfg = AttackConfig(name="alie", z=1.5)
    branch, coeffs = bank_entry(cfg, N, F)
    bank = make_attack_bank(DEFAULT_ATTACK_BANK, F)
    _, byz = bank(init_attack_state(D), x, jax.random.PRNGKey(0),
                  jnp.asarray(attack_index(branch), jnp.int32),
                  jnp.asarray(coeffs, jnp.float32))
    np.testing.assert_allclose(np.asarray(byz),
                               np.asarray(A.alie(x, F, z=1.5)),
                               rtol=1e-6, atol=1e-7)


def test_mimic_tracks_the_outlier_worker():
    """Under heterogeneity the tracked mimic should lock onto the honest
    worker that dominates the update variance, not worker 0."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(STEPS, H, D)).astype(np.float32) * 0.05
    direction = np.zeros(D, np.float32)
    direction[3] = 1.0
    base[:, 5, :] += 4.0 * direction  # worker 5 is the persistent outlier
    honest_seq = jnp.asarray(base)
    state = init_attack_state(D)
    step = ADVERSARIES["mimic"].step
    for t in range(STEPS):
        state, byz = step(state, honest_seq[t], F, jax.random.PRNGKey(t),
                          jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(byz[0]),
                                  np.asarray(honest_seq[-1][5]))
    assert byz.shape == (F, D)


def test_spectral_power_iteration_finds_top_direction():
    """The carried power iteration converges to the planted top covariance
    direction, and the payload shifts the honest mean along it."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(STEPS, H, D)).astype(np.float32)
    x[..., 0] *= 6.0  # dominant variance along e0
    state = init_attack_state(D)
    step = ADVERSARIES["spectral"].step
    coeffs = jnp.asarray([1.5, 0.0])
    for t in range(STEPS):
        state, byz = step(state, jnp.asarray(x[t]), F, jax.random.PRNGKey(t),
                          coeffs)
    v = np.asarray(state.vec)
    assert abs(v[0]) / (np.linalg.norm(v) + 1e-12) > 0.9
    mu = x[-1].mean(0)
    shift = np.asarray(byz[0]) - mu
    cos = abs(shift @ v) / (np.linalg.norm(shift) * np.linalg.norm(v) + 1e-12)
    assert cos > 0.99


def test_ipm_greedy_state_and_payload():
    """Epsilon-greedy IPM sends -scale * honest mean with scale in the arm
    set, remembers the honest mean, and updates arm values."""
    honest_seq = _honest_seq(8)
    coeffs = jnp.asarray([0.5, 5.0])
    state = init_attack_state(D)
    step = ADVERSARIES["ipm_greedy"].step
    for t in range(8):
        state, byz = step(state, honest_seq[t], F, jax.random.PRNGKey(t),
                          coeffs)
        mu = np.asarray(honest_seq[t].mean(0))
        ratios = np.asarray(byz[0]) / np.where(np.abs(mu) > 1e-9, mu, 1.0)
        scale = -np.median(ratios)
        assert np.isclose(scale, 0.5, rtol=1e-4) or np.isclose(
            scale, 5.0, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(state.mu), mu, rtol=1e-6)
    vals = np.asarray(state.scalars[:2])
    assert np.all(np.isfinite(vals)) and vals.max() > 0.0


def test_make_attack_bank_rejects_unknown_entries():
    with pytest.raises(ValueError, match="unknown attack-bank"):
        make_attack_bank(("linear", "bogus"), F)
    with pytest.raises(ValueError, match="not a branch"):
        attack_index("mimic", ("linear", "gauss"))


# --------------------------------------------------------------------------
# Simulator integration: stateful attacks in the scan carry / fused banks
# --------------------------------------------------------------------------


def test_simulator_mimic_is_the_tracked_variant():
    """DELIBERATE semantic change (PR 3): ``AttackConfig(name='mimic')`` on
    the simulator/server_round path now runs the *tracked* mimic (online
    power-iteration target), not ``attacks.mimic``'s fixed target 0 —
    pre-PR mimic trajectories are not reproducible by design.  The
    stateless fixed-target variant remains available as ``attacks.mimic`` /
    ``apply_attack``."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(H, D)).astype(np.float32) * 0.05
    base[5] += 3.0  # worker 5 dominates the variance; target 0 does not
    honest = jnp.asarray(base)
    cfg = _cfg("mimic")
    st = init_state(cfg, D)
    wire = jnp.concatenate([jnp.zeros((F, D)), honest])
    grads = wire  # rosdhb with ratio-1 sparsifier would distort; use robust_dgd
    cfg_raw = dataclasses.replace(cfg, name="robust_dgd")
    _, new_st, _ = server_round(cfg_raw, st, grads, jax.random.PRNGKey(0))
    assert int(new_st.attack.step) == 1  # tracked state advanced
    tracked = ADVERSARIES["mimic"].step(
        init_attack_state(D), honest, F, jax.random.PRNGKey(0),
        jnp.zeros(2))[1]
    legacy = A.apply_attack(A.AttackConfig(name="mimic"), honest, F)
    np.testing.assert_array_equal(np.asarray(tracked[0]),
                                  np.asarray(honest[5]))
    np.testing.assert_array_equal(np.asarray(legacy[0]),
                                  np.asarray(honest[0]))
    assert not np.array_equal(np.asarray(tracked), np.asarray(legacy))


def test_stateful_static_attack_threads_state_through_scan():
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg("mimic"))
    state, metrics = sim.rollout(sim.init(0), batch_fn, STEPS)
    assert int(state.server.attack.step) == STEPS
    assert np.isfinite(np.asarray(metrics["loss"])).all()
    # stateless configs keep the legacy (leafless) attack slot
    sim2 = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg("alie"))
    assert sim2.init(0).server.attack is None


def test_plan_grid_fuses_stateful_attacks_into_bank():
    scenarios = grid_scenarios(["rosdhb"], ["alie", "mimic", "gauss"],
                               ["cwtm"], n_honest=10, f=3)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and not plan.singles
    bank = plan.banks[0]
    assert bank.cfg.attack.name == "bank"
    assert bank.cfg.attack.bank == ("linear", "mimic", "gauss")
    assert bank.attack_idx == (0, 1, 2)


def test_mixed_stateful_grid_is_one_program_and_matches_per_scenario():
    """ACCEPTANCE core: 6 attacks (mimic, gauss, spectral included) x 3
    aggregators -> ONE compiled program whose cells match the per-scenario
    (statically configured) rollouts."""
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    scenarios = grid_scenarios(
        ["rosdhb"], ["alie", "signflip", "foe", "mimic", "gauss", "spectral"],
        ["cwtm", "median", "geomed"], n_honest=H, f=F, ratio=0.2)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == 18
    bank = plan.banks[0]
    seeds = [0, 1]
    batches = stack_batches(batch_fn, STEPS)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    states, metrics = fused_grid_rollout(sim, bank.scenario_params(), seeds,
                                         batches, shard=False)
    assert sim.round_traces == 1  # ONE compiled program for the whole bank
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        ref_states, ref_metrics = rollout_over_seeds(ref, seeds, batches)
        np.testing.assert_allclose(
            np.asarray(states.params_flat[c]),
            np.asarray(ref_states.params_flat),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


def test_stateful_attack_without_state_raises_clearly():
    """A stateful attack on a server state missing the memory slab must
    fail loudly at trace time, not with an AttributeError deep inside."""
    cfg = _cfg("mimic")
    st = init_state(cfg, D)._replace(attack=None)
    with pytest.raises(ValueError, match="memory slab"):
        server_round(cfg, st, jnp.ones((N, D)), jax.random.PRNGKey(0))


def _load_launch_steps():
    """Import repro/launch/steps.py WITHOUT the package __init__ —
    repro.launch.__init__ pulls in mesh.py, which needs jax.sharding.AxisType
    (absent on the 0.4.x jax in CI; steps.py itself is 0.4.x-clean)."""
    import importlib.util
    import os
    import sys
    path = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                        "launch", "steps.py")
    spec = importlib.util.spec_from_file_location("_launch_steps_test", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod  # dataclass annotation resolution needs it
    spec.loader.exec_module(mod)
    return mod


def test_launch_attack_state_specs_match_init_state():
    """The launch path's abstract input specs must mirror init_state's
    attack slab (stateful attacks train at LLM scale too)."""
    from jax.sharding import Mesh
    steps = _load_launch_steps()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    specs = steps._attack_state_specs(_cfg("mimic"), 16, mesh)
    real = init_state(_cfg("mimic"), 16).attack
    assert specs is not None
    s_leaves = jax.tree_util.tree_leaves(specs)
    r_leaves = jax.tree_util.tree_leaves(real)
    assert len(s_leaves) == len(r_leaves)
    for s, r in zip(s_leaves, r_leaves):
        assert s.shape == r.shape and s.dtype == r.dtype
    # stateless attacks keep the leafless slot on both paths
    assert steps._attack_state_specs(_cfg("alie"), 16, mesh) is None
    assert init_state(_cfg("alie"), 16).attack is None


def test_rosdhb_resists_stateful_attacks():
    """CWTM+NNM keeps RoSDHB near the honest optimum under the new
    stateful adversaries too."""
    loss_fn, params0, batch_fn, targets = quadratic_testbed(N, D)
    honest_opt = np.asarray(targets[F:]).mean(0)
    batches = stack_batches(batch_fn, 250)
    for attack in ("mimic", "spectral", "ipm_greedy"):
        sim = Simulator(loss_fn=loss_fn, params0=params0,
                        cfg=dataclasses.replace(_cfg(attack), gamma=0.1))
        state, _ = sim.rollout(sim.init(3), batches)
        params = np.asarray(state.params_flat[:D])
        assert np.linalg.norm(params - honest_opt) < 0.5, attack


# --------------------------------------------------------------------------
# Heterogeneity: Dirichlet partitioners + the (G, B) probe
# --------------------------------------------------------------------------


def test_dirichlet_label_skew_monotone_in_alpha():
    """ACCEPTANCE: skew(alpha=0.1) > skew(alpha=1) > skew(iid)."""
    skews = {}
    for alpha in (0.1, 1.0, None):
        ds = dirichlet_mnist(n_workers=8, alpha=alpha, per_worker=300, seed=0)
        skews[alpha] = label_skew(label_histograms(ds.labels, ds.n_classes))
    assert skews[0.1] > skews[1.0] > skews[None]
    assert skews[None] < 0.1  # iid split is near-uniform
    assert skews[0.1] > 0.4  # strong concentration


def test_partition_pool_is_a_partition_and_skewed():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=4000)
    parts = partition_pool(np.random.default_rng(1), labels, 8, alpha=0.1)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)  # disjoint cover
    hists = np.stack([
        np.bincount(labels[p], minlength=10) / max(len(p), 1) for p in parts])
    iid_parts = partition_pool(np.random.default_rng(1), labels, 8,
                               alpha=1e6)
    iid_hists = np.stack([
        np.bincount(labels[p], minlength=10) / max(len(p), 1)
        for p in iid_parts])
    assert label_skew(hists) > label_skew(iid_hists)


def _linear_testbed(alpha, n_workers=8, per_worker=120, bs=48, seed=0):
    ds = dirichlet_mnist(n_workers=n_workers, alpha=alpha,
                         per_worker=per_worker, seed=seed)
    batch = ds.worker_batches(bs)(0)

    def loss_fn(params, b):
        x = b["images"].reshape((b["images"].shape[0], -1))
        logits = x @ params["w"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            logp, b["labels"][:, None], axis=1))

    params0 = {"w": jnp.zeros((28 * 28, ds.n_classes))}
    return loss_fn, params0, batch


def test_gb_probe_reports_higher_G_under_heterogeneity():
    """ACCEPTANCE: the empirical (G, B) probe sees more gradient
    dissimilarity on a Dirichlet(0.1) split than on the i.i.d. split."""
    loss_fn, params0, batch_het = _linear_testbed(alpha=0.1)
    _, _, batch_iid = _linear_testbed(alpha=None)
    est_het = gb_probe(loss_fn, params0, batch_het, n_probes=6, radius=0.05)
    est_iid = gb_probe(loss_fn, params0, batch_iid, n_probes=6, radius=0.05)
    assert est_het.G > est_iid.G
    assert est_het.G > 0.0
    assert np.all(est_het.dissimilarity >= 0.0)
    assert est_het.B >= 0.0 and est_iid.B >= 0.0


def test_gb_probe_zero_for_identical_workers():
    """Identical worker data -> zero dissimilarity -> G = B = 0."""
    batch = {"target": jnp.ones((6, D))}

    def loss_fn(params, b):
        return 0.5 * jnp.sum(jnp.square(params["w"] - b["target"]))

    est = gb_probe(loss_fn, {"w": jnp.zeros(D)}, batch, n_probes=4,
                   radius=0.5)
    assert est.G == 0.0 and est.B == 0.0
    with pytest.raises(ValueError, match="at least 2"):
        gb_probe(loss_fn, {"w": jnp.zeros(D)}, batch, n_probes=1)


# --------------------------------------------------------------------------
# Scenario registry + CLI name validation (satellite)
# --------------------------------------------------------------------------


def test_registry_expands_named_scenarios():
    cells = expand_scenario("mixed-attacks")
    assert len(cells) == 18  # 6 attacks x 3 aggregators
    assert all(c.label.startswith("mixed-attacks/") for c in cells)
    attacks = {c.cfg.attack.name for c in cells}
    assert {"mimic", "gauss", "spectral"} <= attacks
    # the acceptance property: the whole named scenario is ONE program
    assert plan_grid(cells).n_programs == 1


def test_registry_byz_fraction_axis():
    cells = expand_scenario("byz-fraction")
    fs = sorted({c.cfg.f for c in cells})
    assert fs == [1, 2, 3, 4]
    assert all(c.cfg.n_workers == 13 for c in cells)
    assert all(f"/f{c.cfg.f}/" in c.label for c in cells)
    # one bank per f (aggregator f is baked into compiled branches)
    assert plan_grid(cells).n_programs == len(fs)


def test_registry_heterogeneous_specs_carry_alpha():
    assert get_spec("mimic-dirichlet01").alpha_het == 0.1
    assert get_spec("mimic-iid").alpha_het is None
    assert get_spec("mimic-dirichlet01").testbed == "mnist"


def test_registry_unknown_name_lists_known():
    with pytest.raises(ValueError, match="mixed-attacks"):
        get_spec("not-a-scenario")


def test_registry_register_roundtrip():
    spec = ScenarioSpec("tmp-test", "temporary", attacks=("alie", "mimic"))
    R.register(spec)
    try:
        assert get_spec("tmp-test") is spec
        assert len(spec.expand()) == 2
    finally:
        del R.REGISTRY["tmp-test"]
    bad = ScenarioSpec("tmp-bad", "bad f", byz_f=(99,))
    with pytest.raises(ValueError, match="byz_f"):
        bad.expand()


def test_grid_scenarios_unknown_names_raise_with_known_lists():
    """Satellite: the sweep CLI fails fast with the known-name list instead
    of deep inside plan_grid/tracing."""
    with pytest.raises(ValueError, match=r"unknown attack: 'bogus'.*mimic"):
        grid_scenarios(["rosdhb"], ["bogus"], ["cwtm"])
    with pytest.raises(ValueError,
                       match=r"unknown algorithm: 'sgd'.*rosdhb"):
        grid_scenarios(["sgd"], ["alie"], ["cwtm"])
    with pytest.raises(ValueError,
                       match=r"unknown aggregator: 'trimmed'.*cwtm"):
        grid_scenarios(["rosdhb"], ["alie"], ["trimmed"])


def test_sweep_cli_scenario_plan(capsys):
    from repro.core import sweep
    rows = sweep.main(["--scenario", "stateful-core", "--plan"])
    assert rows == []
    out = capsys.readouterr().out
    assert "1 programs" in out
    sweep.main(["--list-scenarios"])
    out = capsys.readouterr().out
    assert "mixed-attacks" in out and "byz-fraction" in out
