"""Optional-`hypothesis` shim for the property-based test modules.

When `hypothesis` is installed (see requirements-test.txt) this re-exports
the real ``given``/``settings``/``st``. When it is not, a deterministic
miniature takes over: each strategy draws from a fixed-seed PRNG and
``@given`` runs the test body on ``max_examples`` pre-drawn examples — the
property checks derandomize into fixed example sets instead of breaking
collection with an ImportError.

Only the strategy surface the test suite uses is implemented
(``st.integers``, ``st.floats``, ``st.lists``); extend as tests grow.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw rule: ``example(rng)`` produces one value."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics the `hypothesis.strategies` namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [elements.example(rng)
                             for _ in range(rng.randint(min_size, max_size))])

    def settings(max_examples=10, **_ignored):
        """Record the example budget for the fallback ``given`` runner."""

        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        """Run the test once per pre-drawn example (seed fixed at 0, so the
        same example set is exercised on every run)."""

        def deco(fn):
            # NOTE: deliberately no functools.wraps — the wrapper must
            # present a ZERO-arg signature or pytest mistakes the
            # strategy-supplied parameters for fixtures.
            def wrapper():
                # settings() may sit below @given (attribute on fn) or above
                # it (attribute on this wrapper) — honour both orders
                n = getattr(wrapper, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples", 10))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in arg_strategies)
                    drawn_kw = {k: s.example(rng)
                                for k, s in kw_strategies.items()}
                    fn(*drawn, **drawn_kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
