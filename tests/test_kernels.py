"""Per-kernel interpret-mode sweeps against the pure-jnp oracles
(shape x dtype grids per the deliverable-c requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cwtm import cwtm_pallas, cwtm_pallas_batched, cwtm_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.median import median_pallas_batched, median_ref
from repro.kernels.pairdist import pairdist_pallas_batched, pairdist_ref
from repro.kernels.randk import (
    block_compress, block_compress_ref, block_decompress,
    block_decompress_ref, momentum_scatter, momentum_scatter_ref,
)

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# cwtm
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n,f", [(4, 1), (10, 2), (16, 3), (19, 9), (32, 7)])
@pytest.mark.parametrize("d", [128, 300, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cwtm_sweep(n, f, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(n * d + f), (n, d)) * 3
         ).astype(dtype)
    got = cwtm_pallas(x, f, block_d=256, interpret=True)
    want = cwtm_ref(x, f)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_cwtm_handles_outliers_like_ref():
    x = jax.random.normal(KEY, (10, 512))
    x = x.at[:3].set(1e9)
    got = cwtm_pallas(x, 3, block_d=256, interpret=True)
    assert float(jnp.max(jnp.abs(got))) < 10.0


# --------------------------------------------------------------------------
# batched aggregation kernels (the grid engine's [B, n, d] layout)
# --------------------------------------------------------------------------

# awkward-shape sweep: n odd / not a power of two (bitonic padding path),
# d not a multiple of the 128-lane tile (block padding path), f=0 (cwtm
# degenerates to the mean), n-2f=1 (single surviving rank)
AWKWARD = [(3, 13, 3, 300), (2, 7, 0, 130), (4, 5, 2, 257),
           (1, 19, 9, 128), (5, 4, 1, 64), (2, 16, 3, 1024)]


@pytest.mark.parametrize("b,n,f,d", AWKWARD)
def test_cwtm_batched_sweep(b, n, f, d):
    x = jax.random.normal(jax.random.PRNGKey(b * d + n), (b, n, d)) * 3
    got = cwtm_pallas_batched(x, f, block_d=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(cwtm_ref(x, f)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,n,f,d", AWKWARD)
def test_median_batched_sweep(b, n, f, d):
    x = jax.random.normal(jax.random.PRNGKey(b * d + n + 1), (b, n, d)) * 3
    got = median_pallas_batched(x, block_d=256, interpret=True)
    # rank selection out of the same sort network is exact, even-n midpoint
    # averaging matches jnp.median's convention bit-for-bit in f32
    np.testing.assert_allclose(np.asarray(got), np.asarray(median_ref(x)),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("b,n,f,d", AWKWARD)
def test_pairdist_batched_sweep(b, n, f, d):
    x = jax.random.normal(jax.random.PRNGKey(b * d + n + 2), (b, n, d)) * 3
    got = pairdist_pallas_batched(x, block_d=256, interpret=True)
    want = pairdist_ref(x)
    assert got.shape == (b, n, n)
    # atol covers the oracle's own diagonal cancellation noise (its
    # sq_i + sq_i - 2 G_ii leaves ~1e-2 float dust where the kernel is
    # exactly 0) plus blocked-Gram sum reordering
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-2, rtol=1e-5)
    # self-distances are exactly zero (diag of the same accumulated Gram)
    diag = np.asarray(got)[:, np.arange(n), np.arange(n)]
    np.testing.assert_array_equal(diag, np.zeros_like(diag))


def test_batched_matches_vmapped_2d():
    """The explicit [B, n, d] launch equals vmap of the per-lane kernel —
    the equivalence `repro.kernels.batchable` relies on."""
    x = jax.random.normal(KEY, (4, 10, 300))
    b1 = cwtm_pallas_batched(x, 2, block_d=256, interpret=True)
    b2 = jax.vmap(lambda r: cwtm_pallas(r, 2, block_d=256, interpret=True))(x)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-6)


# --------------------------------------------------------------------------
# randk (block compress / decompress / fused momentum)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("d,bs,kb", [(2048, 128, 4), (4096, 256, 7),
                                     (8192, 512, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_randk_roundtrip_sweep(d, bs, kb, dtype):
    nb = d // bs
    g = jax.random.normal(KEY, (d,)).astype(dtype)
    idx = jnp.sort(jax.random.permutation(jax.random.PRNGKey(d), nb)[:kb])
    alpha = float(nb) / kb
    p = block_compress(g, idx, bs, alpha, interpret=True)
    p_ref = block_compress_ref(g, idx, bs, alpha)
    np.testing.assert_allclose(np.asarray(p, np.float32),
                               np.asarray(p_ref, np.float32), rtol=2e-2)
    dn = block_decompress(p, idx, bs, d, interpret=True)
    dn_ref = block_decompress_ref(p_ref, idx, bs, d)
    np.testing.assert_allclose(np.asarray(dn, np.float32),
                               np.asarray(dn_ref, np.float32), rtol=2e-2)


@pytest.mark.parametrize("beta", [0.0, 0.9, 0.99])
def test_momentum_scatter_sweep(beta):
    d, bs, kb = 4096, 256, 5
    nb = d // bs
    row = jax.random.normal(KEY, (d,))
    idx = jnp.sort(jax.random.permutation(jax.random.PRNGKey(1), nb)[:kb])
    payload = jax.random.normal(jax.random.PRNGKey(2), (kb * bs,))
    got = momentum_scatter(row, payload, idx, bs, beta, interpret=True)
    want = momentum_scatter_ref(row, payload, idx, bs, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_compress_unbiased_with_decompress():
    """decompress(compress(g)) is the paper's unbiased estimate (d/k scaled
    selected blocks, zeros elsewhere)."""
    d, bs = 1024, 128
    nb = d // bs
    g = jax.random.normal(KEY, (d,))
    idx = jnp.array([0, 3], jnp.int32)
    alpha = nb / 2
    est = block_decompress(block_compress(g, idx, bs, alpha, interpret=True),
                           idx, bs, d, interpret=True)
    dense = np.zeros(d, np.float32)
    dense[:bs] = np.asarray(g[:bs]) * alpha
    dense[3 * bs:4 * bs] = np.asarray(g[3 * bs:4 * bs]) * alpha
    np.testing.assert_allclose(np.asarray(est), dense, rtol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sq,sk,h,kv,d", [
    (128, 128, 4, 2, 64),
    (256, 256, 4, 1, 128),
    (64, 192, 4, 4, 64),
    (96, 96, 2, 2, 64),     # non-multiple of block -> padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(sq, sk, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (2, sk, kv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (2, sk, kv, d)).astype(dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [32, 96])
def test_flash_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    got = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_flash_q_offset_decode_chunk():
    """Continuation chunk: q at positions [128, 192) against 192 keys."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 64))
    k = jax.random.normal(ks[1], (2, 192, 4, 64))
    v = jax.random.normal(ks[2], (2, 192, 4, 64))
    got = flash_attention(q, k, v, q_offset=128, block_q=64, block_k=64,
                          interpret=True)
    want = attention_ref(q, k, v, q_offset=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_flash_matches_model_attention_path():
    """The XLA attention used by the models equals the kernel's math."""
    from repro.models.layers import causal_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = causal_attention(q, k, v, q_offset=0, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_ops_attention_pads_non_lane_head_dim():
    """ops.attention zero-pads D=64 -> 128 for the kernel and rescales q so
    the softmax temperature stays 1/sqrt(64); must match the XLA reference
    (the head dim every reduced() config uses)."""
    from repro.kernels.flash_attention.ops import attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 96, 4, 64))
    k = jax.random.normal(ks[1], (2, 96, 2, 64))
    v = jax.random.normal(ks[2], (2, 96, 2, 64))
    got = attention(q, k, v, use_pallas=True, interpret=True)
    want = attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)
    # sliding-window + offset through the same padding path
    got_w = attention(q, k, v, window=32, q_offset=64, use_pallas=True,
                      interpret=True)
    want_w = attention(q, k, v, window=32, q_offset=64, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               atol=2e-3)


def test_flash_flag_through_transformer_forward():
    """use_flash_attention=True (interpret mode off-TPU) reproduces the
    chunked-XLA train-mode forward of a reduced dense config within bf16
    accumulation noise."""
    from repro.configs.base import get_arch
    from repro.models import transformer as TR

    cfg_ref = get_arch("stablelm_3b").model.reduced(
        n_layers=2, d_model=256).with_overrides(use_flash_attention=False)
    cfg_flash = cfg_ref.with_overrides(use_flash_attention=True)
    params = TR.model_init(jax.random.PRNGKey(0), cfg_ref)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg_ref.vocab_size)
    batch = {"tokens": tokens}
    loss_ref = float(TR.lm_loss(params, cfg_ref, batch))
    loss_flash = float(TR.lm_loss(params, cfg_flash, batch))
    assert abs(loss_flash - loss_ref) < 1e-2, (loss_flash, loss_ref)
    h_ref, _, _ = TR.forward(params, cfg_ref, batch, mode="train")
    h_flash, _, _ = TR.forward(params, cfg_flash, batch, mode="train")
    np.testing.assert_allclose(np.asarray(h_flash, np.float32),
                               np.asarray(h_ref, np.float32), atol=0.1)
