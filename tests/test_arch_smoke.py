"""Deliverable (f): per assigned architecture, instantiate a REDUCED variant
of the same family (<= 2 layers, d_model <= 512, <= 4 experts) and run one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import forward, lm_loss, model_init
from repro.utils.tree import global_norm, tree_size

KEY = jax.random.PRNGKey(0)


def _reduced(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.model.reduced(n_layers=2, d_model=256)
    return cfg.with_overrides(dtype="float32")


def _batch(cfg, b=2, s=16):
    batch = {}
    if cfg.input_kind == "tokens":
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    else:
        batch["embeddings"] = jax.random.normal(KEY, (b, s, cfg.d_model))
        batch["targets"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeddings"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_constraints(arch_id):
    cfg = _reduced(arch_id)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    # family preserved
    assert cfg.family == get_arch(arch_id).model.family


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward(arch_id):
    cfg = _reduced(arch_id)
    params = model_init(KEY, cfg)
    assert tree_size(params) > 0
    batch = _batch(cfg)
    hidden, _, _ = forward(params, cfg, batch, mode="train")
    assert hidden.shape == (2, 16, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    """One full train step: loss + grads + SGD update, all finite."""
    cfg = _reduced(arch_id)
    params = model_init(KEY, cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch)
    assert np.isfinite(float(loss)), arch_id
    gn = float(global_norm(grads))
    assert np.isfinite(gn) and gn > 0, arch_id
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g,
                                        params, grads)
    loss2 = float(lm_loss(new_params, cfg, batch))
    assert np.isfinite(loss2), arch_id


def test_full_configs_match_assignment():
    expect = {
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "mamba2_1_3b": (48, 2048, None, None, 0, 50280),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "llama32_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen25_3b": (36, 2048, 16, 2, 11008, 151936),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch_id, (L, d, h, kv, ff, v) in expect.items():
        m = get_arch(arch_id).model
        assert m.n_layers == L and m.d_model == d and m.d_ff == ff \
            and m.vocab_size == v, arch_id
        if h is not None:
            assert m.n_heads == h and m.n_kv_heads == kv, arch_id
    # family-specific details
    ds = get_arch("deepseek_v2_lite_16b").model
    assert ds.use_mla and ds.kv_lora_rank == 512 and ds.n_experts == 64 \
        and ds.top_k == 6
    assert get_arch("dbrx_132b").model.n_experts == 16
    assert get_arch("dbrx_132b").model.top_k == 4
    assert get_arch("gemma_2b").model.resolved_head_dim == 256
    assert get_arch("mamba2_1_3b").model.ssm_state == 128
    assert get_arch("zamba2_7b").model.ssm_state == 64
    assert get_arch("zamba2_7b").model.attn_every == 6
    assert get_arch("qwen25_3b").model.qkv_bias
