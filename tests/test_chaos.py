"""Chaos-harness tests: scenario registry, quorum degradation/recovery,
liveness watchdog, mid-round crash recovery, and end-to-end chaos runs."""

import dataclasses
import time

import numpy as np
import pytest

from repro.core.sweep import grid_scenarios, quadratic_testbed
from repro.serve import (
    CHAOS_REGISTRY, ByzantineRobustServer, ChaosScenario, ClientPool,
    FaultSpec, RetryPolicy, ServeConfig, ServeTimeout, get_chaos,
    run_chaos, run_service,
)
from repro.serve.chaos import describe_chaos

D = 24
ROUNDS = 8


def _cfg(**kw):
    kw.setdefault("n_honest", 10)
    kw.setdefault("f", 3)
    return grid_scenarios(("rosdhb",), ("alie",), ("cwtm",), **kw)[0].cfg


def _testbed(cfg):
    return quadratic_testbed(cfg.n_workers, d=D)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------


def test_chaos_registry_contents():
    for name in ("fault-free", "drop-storm", "dup-flood", "corrupt-burst",
                 "partition-heal", "reset-storm", "straggler-degrade",
                 "kill-restart", "combined"):
        assert name in CHAOS_REGISTRY
        assert get_chaos(name).name == name
    assert "drop-storm" in describe_chaos()
    with pytest.raises(ValueError, match="unknown chaos scenario"):
        get_chaos("volcano")


def test_fault_spec_validates_rates():
    with pytest.raises(ValueError, match="outside"):
        FaultSpec(drop=1.5)
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(delay_s=-1.0)
    assert not FaultSpec().any_faults()
    assert FaultSpec(corrupt=0.1).any_faults()
    assert FaultSpec(partitions=((0, 1, (0,)),)).any_faults()


# --------------------------------------------------------------------------
# end-to-end chaos scenarios (small, fast cuts)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["drop-storm", "dup-flood",
                                  "corrupt-burst", "reset-storm"])
def test_chaos_scenarios_serve_through_faults(name):
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    res = run_chaos(cfg, params0, batch_fn, loss_fn, get_chaos(name),
                    ROUNDS, seed=0)
    assert res.all_rounds_terminated()
    assert res.step_traces == [1]
    assert sum(res.injected.values()) > 0       # chaos actually happened
    assert all(np.isfinite(res.final_params))


def test_kill_restart_resumes_bitwise():
    """A mid-round crash + checkpoint restore on a clean transport must be
    invisible: same final parameters as the uncrashed run, one compile per
    server instance."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    base = run_chaos(cfg, params0, batch_fn, loss_fn,
                     get_chaos("fault-free"), ROUNDS, seed=0)
    kr = run_chaos(cfg, params0, batch_fn, loss_fn,
                   get_chaos("kill-restart"), ROUNDS, seed=0)
    assert kr.restarts == 1
    assert kr.step_traces == [1, 1]
    np.testing.assert_array_equal(kr.final_params, base.final_params)


def test_combined_scenario_converges_and_terminates():
    cfg = _cfg()
    loss_fn, params0, batch_fn, targets = _testbed(cfg)
    base = run_chaos(cfg, params0, batch_fn, loss_fn,
                     get_chaos("fault-free"), 12, seed=0)
    cb = run_chaos(cfg, params0, batch_fn, loss_fn, get_chaos("combined"),
                   12, seed=0)
    assert cb.all_rounds_terminated() and cb.restarts == 1
    assert all(t == 1 for t in cb.step_traces)
    w0 = base.final_params[:D]
    w1 = cb.final_params[:D]
    t = np.asarray(targets)[cfg.f:]
    l0 = 0.5 * np.mean(np.sum((w0[None] - t) ** 2, axis=1))
    l1 = 0.5 * np.mean(np.sum((w1[None] - t) ** 2, axis=1))
    assert abs(l1 - l0) / max(abs(l0), 1e-12) < 0.25  # small-cut tolerance


# --------------------------------------------------------------------------
# graceful quorum degradation
# --------------------------------------------------------------------------


def test_quorum_degrades_and_recovers():
    """A partition forces wall-clock rounds -> quorum steps down; the heal
    brings quorum rounds back -> quorum steps back up. Both transitions
    are logged with bounds [2f+1, configured]."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sc = ChaosScenario(
        "test-degrade", "partition window drives degradation",
        faults=FaultSpec(partitions=((1, 4, (9, 10, 11, 12)),)),
        timeout_s=0.1, staleness_window=2, degrade_after=1,
        recover_after=1, retry=RetryPolicy(max_attempts=2,
                                           backoff_base_s=0.0))
    res = run_chaos(cfg, params0, batch_fn, loss_fn, sc, 8, seed=0)
    trans = res.summaries[-1]["quorum_transitions"]
    reasons = [t["reason"] for t in trans]
    assert "degrade" in reasons and "recover" in reasons
    for t in trans:
        assert 2 * cfg.f + 1 <= t["new"] <= cfg.n_workers
    # the quorum histogram shows rounds fired at more than one level
    assert len(res.summaries[-1]["quorum_histogram"]) > 1
    assert res.all_rounds_terminated()


def test_degradation_floor_is_2f_plus_1():
    from repro.serve import RoundBuffer
    buf = RoundBuffer(n_clients=13, f=3, quorum=8, timeout_s=0.1)
    buf.set_quorum(7)                       # the floor itself is fine
    with pytest.raises(ValueError, match="floor"):
        buf.set_quorum(6)
    assert buf.base_quorum == 8 and buf.quorum == 7


def test_degradation_off_by_default():
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sc = ChaosScenario(
        "test-no-degrade", "timeout rounds but degradation off",
        faults=FaultSpec(partitions=((0, 8, (12,)),)),
        timeout_s=0.05, staleness_window=2, degrade_after=0,
        retry=RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    res = run_chaos(cfg, params0, batch_fn, loss_fn, sc, 4, seed=0)
    assert res.summaries[-1]["quorum_transitions"] == []


# --------------------------------------------------------------------------
# liveness watchdog
# --------------------------------------------------------------------------


def test_watchdog_fails_stalled_round_loudly():
    """No updates + no round timeout: without the watchdog this would hang
    to the caller's full deadline; with it, waiters fail fast and the
    event is recorded unresolved."""
    cfg = _cfg()
    _, params0, _, _ = _testbed(cfg)
    server = ByzantineRobustServer(
        cfg, params0, ServeConfig(watchdog_s=0.1), seed=0)
    server.start()
    try:
        t0 = time.perf_counter()
        with pytest.raises(ServeTimeout) as ei:
            server.wait_round(0, timeout=30.0)
        assert time.perf_counter() - t0 < 5.0   # failed fast, not at 30s
        assert ei.value.reason == "watchdog"
        wd = server.metrics.watchdog_summary()
        assert wd["fired"] == 1 and wd["unresolved"] == 1
    finally:
        server.stop()


def test_watchdog_event_resolves_when_round_fires():
    """The round stalls past watchdog_s but then completes: the event is
    marked resolved and serving continues."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(
        cfg, params0, ServeConfig(watchdog_s=0.15), seed=0)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn)
    server.start()
    try:
        ann = server.announce(timeout=10.0)
        time.sleep(0.3)                         # let the watchdog fire
        for s in pool.round_payloads(ann):
            server.submit(s.update)
        res = server.wait_round(0, timeout=10.0)
        assert res.n_updates == cfg.n_workers
        wd = server.metrics.watchdog_summary()
        assert wd == {"fired": 1, "resolved": 1, "unresolved": 0}
    finally:
        server.stop()


# --------------------------------------------------------------------------
# mid-round crash recovery (unit level)
# --------------------------------------------------------------------------


def test_mid_round_checkpoint_restores_announcement_and_rows(tmp_path):
    """A checkpoint taken mid-round carries the open round's announcement
    keys and buffered rows; restore rebuilds the SAME announcement (no
    key-chain re-split) and re-feeds the rows."""
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn)
    server.start()
    try:
        ann = server.announce(timeout=10.0)
        sched = pool.round_payloads(ann)
        for s in sched[:5]:
            server.submit(s.update)
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            with server._cond:
                if server._buffer.count == 5:
                    break
            time.sleep(0.01)
        path = server.save_checkpoint(str(tmp_path / "midround"))
    finally:
        server.stop()

    restored = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=77)
    assert restored.restore(path) == 0
    ann2 = restored.announce(timeout=0)  # already open, no wait needed
    assert ann2.round_id == ann.round_id
    np.testing.assert_array_equal(ann2.mask_key, ann.mask_key)
    np.testing.assert_array_equal(ann2.atk_key, ann.atk_key)
    np.testing.assert_array_equal(ann2.params, ann.params)
    with restored._cond:
        assert restored._buffer.count == 5
    restored.start()
    try:
        for s in sched[5:]:
            restored.submit(s.update)
        res = restored.wait_round(0, timeout=10.0)
        assert res.n_updates == cfg.n_workers
    finally:
        restored.stop()


def test_boundary_checkpoint_still_restores_next_round(tmp_path):
    """The pre-existing boundary semantics survive the tree extension:
    checkpoint_every checkpoints restore the NEXT round via the normal
    key-chain split (covered bit-for-bit by test_serve.py's kill-and-
    resume test; here we just pin the round arithmetic)."""
    import glob
    import os
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(checkpoint_every=2, checkpoint_dir=str(tmp_path))
    s = ByzantineRobustServer(cfg, params0, serve, seed=0)
    run_service(s, ClientPool(loss_fn, params0, cfg, batch_fn), 4)
    ckpt = sorted(glob.glob(os.path.join(str(tmp_path), "*.npz")))[-1]
    s2 = ByzantineRobustServer(cfg, params0, serve, seed=1)
    rid = s2.restore(ckpt.replace(".npz", ""))
    assert rid == 4
    with s2._cond:
        assert s2._buffer.count == 0            # boundary: nothing in flight
        assert s2._ann.round_id == 4


# --------------------------------------------------------------------------
# chaos over TCP (one fast end-to-end cut)
# --------------------------------------------------------------------------


def test_chaos_over_tcp_with_faults():
    cfg = _cfg()
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sc = dataclasses.replace(get_chaos("drop-storm"), transport="tcp")
    res = run_chaos(cfg, params0, batch_fn, loss_fn, sc, 6, seed=0)
    assert res.all_rounds_terminated()
    assert res.step_traces == [1]
