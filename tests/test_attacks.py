"""Byzantine attack behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks as A


def _honest(h=8, d=6, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (h, d)) + 2.0


def test_alie_is_mean_minus_z_std():
    x = _honest()
    byz = A.alie(x, f=3, z=1.5)
    expected = jnp.mean(x, 0) - 1.5 * jnp.std(x, 0)
    assert byz.shape == (3, 6)
    np.testing.assert_allclose(np.asarray(byz[0]), np.asarray(expected),
                               rtol=1e-5)


def test_alie_z_formula():
    # n=19, f=9 (the paper's extreme case): s = floor(19/2+1)-9 = 1
    z = A._alie_z(19, 9)
    assert z > 0.5  # strong shift available near half Byzantine
    # small f => little room to shift the median
    assert A._alie_z(10, 2) == pytest.approx(0.0, abs=1e-6)


def test_signflip_foe_direction():
    x = _honest()
    mu = jnp.mean(x, 0)
    assert jnp.allclose(A.sign_flip(x, 1)[0], -mu)
    assert jnp.allclose(A.foe(x, 1, scale=10.0)[0], -10.0 * mu)
    assert jnp.allclose(A.ipm(x, 1, eps=0.5)[0], -0.5 * mu)


def test_mimic_copies_target():
    x = _honest()
    assert jnp.allclose(A.mimic(x, 2, target=3)[1], x[3])


def test_apply_attack_dispatch_and_f0():
    x = _honest()
    for name in ["alie", "signflip", "ipm", "foe", "mimic", "zero"]:
        out = A.apply_attack(A.AttackConfig(name=name), x, 2,
                             key=jax.random.PRNGKey(0))
        assert out.shape == (2, 6)
    out = A.apply_attack(A.AttackConfig(name="alie"), x, 0)
    assert out.shape == (0, 6)


def test_gauss_needs_key():
    x = _honest()
    out = A.apply_attack(A.AttackConfig(name="gauss", scale=0.1), x, 2,
                         key=jax.random.PRNGKey(1))
    assert out.shape == (2, 6)
    assert bool(jnp.all(jnp.isfinite(out)))
