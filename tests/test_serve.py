"""Streaming parameter-server tests: server <-> simulator bit-for-bit
parity, quorum/timeout/staleness edge cases, wire accounting, checkpoint
kill-and-resume."""

import glob
import os

import numpy as np
import pytest

import jax

from repro.adversary import registry
from repro.core import Simulator
from repro.core import algorithms as alg
from repro.core import wire as W
from repro.core.sweep import grid_scenarios, quadratic_testbed
from repro.serve import (
    ByzantineRobustServer, ClientBehavior, ClientPool, RoundBuffer,
    ServeConfig, mask_id, run_service,
)
from repro.serve.protocol import ClientUpdate

D = 32
ROUNDS = 12


def _testbed(cfg):
    return quadratic_testbed(cfg.n_workers, d=D)


def _run_sim(cfg, loss_fn, params0, batch_fn, rounds, seed=0):
    sim = Simulator(loss_fn, params0, cfg)
    final, _ = sim.rollout(sim.init(seed), batch_fn, rounds)
    return np.asarray(final.params_flat), final


def _run_serve(cfg, loss_fn, params0, batch_fn, rounds, seed=0,
               serve=None, behavior=None):
    server = ByzantineRobustServer(cfg, params0, serve or ServeConfig(),
                                   seed=seed)
    pool = ClientPool(loss_fn, params0, cfg, batch_fn, behavior=behavior)
    results = run_service(server, pool, rounds)
    return server, pool, results


# --------------------------------------------------------------------------
# server <-> simulator bit-for-bit parity
# --------------------------------------------------------------------------

# every attack x aggregator cell of the registry's stateless-linear scenario
_REGISTRY_CELLS = {s.label: s for s in
                   registry.expand_scenario("stateless-linear")}


@pytest.mark.parametrize("label", sorted(_REGISTRY_CELLS))
def test_server_matches_simulator_registry_cells(label):
    """Full participation + zero timeout + seeded pool: the streaming
    server's parameter trajectory IS ``Simulator.rollout``'s, bit for bit,
    for every attack x aggregator cell of the registry scenario."""
    cfg = _REGISTRY_CELLS[label].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sim_params, sim_final = _run_sim(cfg, loss_fn, params0, batch_fn, ROUNDS)
    server, _, results = _run_serve(cfg, loss_fn, params0, batch_fn, ROUNDS)
    np.testing.assert_array_equal(sim_params, np.asarray(server.params_flat))
    np.testing.assert_array_equal(np.asarray(sim_final.server.momentum),
                                  np.asarray(server.server_state.momentum))
    assert server.step_traces == 1
    assert all(r.fired_by == "quorum" and r.n_updates == cfg.n_workers
               for r in results)


@pytest.mark.parametrize("algo", alg.SERVE_ALGORITHMS)
@pytest.mark.parametrize("attack", ["alie", "signflip"])
def test_server_matches_simulator_cross_algo(algo, attack):
    """Parity holds for every serveable algorithm (incl. the bankless DGD
    rules, whose serve path reuses the momentum slot as a wire bank).

    rosdhb/robust_dgd are bit-for-bit. dgd's direction is a plain mean
    DIRECTLY over the compressed wire, and inside the fused simulator
    program XLA hoists the unbiasedness scalar across that mean
    (``mean(alpha*g*mask) -> alpha*mean(g*mask)``) — a rewrite the serve
    split cannot see because the pool materialises the wire at the program
    boundary. That reassociation is a 1-ulp effect, so dgd is pinned to a
    few-ulp tolerance instead."""
    cfg = grid_scenarios((algo,), (attack,), ("cwtm",),
                         n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sim_params, _ = _run_sim(cfg, loss_fn, params0, batch_fn, ROUNDS, seed=3)
    server, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, ROUNDS,
                              seed=3)
    got = np.asarray(server.params_flat)
    if algo == "dgd":
        np.testing.assert_allclose(sim_params, got, rtol=1e-6, atol=1e-7)
    else:
        np.testing.assert_array_equal(sim_params, got)


def test_server_matches_simulator_stateful_attack():
    """The pool carries stateful adversaries' AttackState (mimic) through
    the same dispatch the simulator uses — parity must still be exact."""
    cfg = grid_scenarios(("rosdhb",), ("mimic",), ("cwtm",),
                         n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    sim_params, _ = _run_sim(cfg, loss_fn, params0, batch_fn, ROUNDS)
    server, pool, _ = _run_serve(cfg, loss_fn, params0, batch_fn, ROUNDS)
    assert pool.attack_state is not None
    np.testing.assert_array_equal(sim_params, np.asarray(server.params_flat))


def test_wire_accounting_matches_simulator():
    """protocol <-> Simulator.payload_bytes_per_round can never disagree:
    both go through repro.core.wire."""
    for algo in alg.ALGO_BANK:
        for local in (False, True):
            cfg = grid_scenarios(
                (algo,), ("alie",), ("cwtm",), n_honest=10, f=3,
                ratio=0.25, local=local)[0].cfg
            loss_fn, params0, _, _ = _testbed(cfg)
            sim = Simulator(loss_fn, params0, cfg)
            per = W.per_worker_payload_bytes(algo, sim.d, cfg.sparsifier)
            assert sim.payload_bytes_per_round() == per * cfg.n_workers
            assert alg.algo_payload_bytes(cfg, sim.d) == per


def test_serve_round_payload_bytes_accounted():
    cfg = _REGISTRY_CELLS[sorted(_REGISTRY_CELLS)[0]].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, 4)
    sim = Simulator(loss_fn, params0, cfg)
    assert (server.metrics.summary()["uplink_bytes"]
            == sim.payload_bytes_per_round() * 4)


# --------------------------------------------------------------------------
# quorum / timeout / staleness edge cases
# --------------------------------------------------------------------------


def test_quorum_below_2f_plus_1_raises():
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    _, params0, _, _ = _testbed(cfg)
    with pytest.raises(ValueError, match="2f\\+1"):
        ByzantineRobustServer(cfg, params0, ServeConfig(quorum=2 * cfg.f))
    with pytest.raises(ValueError, match="2f\\+1"):
        RoundBuffer(n_clients=13, f=3, quorum=6)


def test_dasha_rejected_loudly():
    cfg = grid_scenarios(("dasha",), n_honest=10, f=3)[0].cfg
    _, params0, _, _ = _testbed(cfg)
    with pytest.raises(ValueError, match="stale"):
        ByzantineRobustServer(cfg, params0)
    with pytest.raises(ValueError, match="streaming"):
        alg.make_wire_fn(cfg)


def test_timeout_fires_partial_round():
    """Quorum unreachable (2 clients always drop) + wall-clock timeout:
    rounds fire by timeout with the partial participation that arrived."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=cfg.n_workers, timeout_s=0.03)
    # two fixed clients always arrive too late (beyond the window), so the
    # full-n quorum is unreachable and only the clock can fire the round
    beh = ClientBehavior(stragglers=(11, 12), straggle_rounds=5)
    server, _, results = _run_serve(cfg, loss_fn, params0, batch_fn, 5,
                                    serve=serve, behavior=beh)
    assert all(r.fired_by == "timeout" for r in results)
    assert all(r.n_updates == cfg.n_workers - 2 for r in results)
    assert server.step_traces == 1


def test_zero_timeout_below_quorum_never_fires():
    buf = RoundBuffer(n_clients=13, f=3, quorum=13, timeout_s=0.0)
    u = ClientUpdate(client_id=5, round_id=0, mask_id=0,
                     values=np.zeros(4), payload_bytes=1)
    buf._mask_ids[0] = 0
    assert buf.add(u, now=0.0) == "accepted"
    assert not buf.ready(now=1e9)  # no clock: quorum only


def test_byzantine_all_late_drop_policy():
    """All f byzantine clients always late + stale_policy='drop': every
    round aggregates exactly the honest clients; byzantine rows never
    enter a round."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=cfg.n_workers - cfg.f, timeout_s=0.05,
                        stale_policy="drop")
    beh = ClientBehavior(stragglers=tuple(range(cfg.f)), straggle_rounds=2)
    server, _, results = _run_serve(cfg, loss_fn, params0, batch_fn, 6,
                                    serve=serve, behavior=beh)
    for r in results:
        assert r.n_updates == cfg.n_workers - cfg.f
        assert all(c >= cfg.f for c in r.client_ids)
    dec = server.metrics.summary()["ingest_decisions"]
    assert dec.get("stale_dropped", 0) > 0


def test_staleness_window_discount_accepts_late():
    """Late-by-1 updates inside the window are accepted with staleness 1
    under the discount policy."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=cfg.n_workers - 2, timeout_s=0.05,
                        staleness_window=2, stale_policy="discount")
    beh = ClientBehavior(stragglers=(11, 12), straggle_rounds=1)
    server, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, 8,
                              serve=serve, behavior=beh)
    hist = server.metrics.summary()["staleness_histogram"]
    assert hist.get("1", 0) > 0


def test_buffer_staleness_and_duplicate_rules():
    buf = RoundBuffer(n_clients=13, f=3, quorum=13, timeout_s=0.0,
                      staleness_window=1, stale_policy="discount")
    mk = lambda cid, rid: ClientUpdate(  # noqa: E731
        client_id=cid, round_id=rid, mask_id=rid, values=np.zeros(4),
        payload_bytes=1)
    for r in range(4):
        buf._mask_ids[r] = r
    buf.open(2, now=0.0, mask_id=2)
    buf._mask_ids.update({0: 0, 1: 1, 3: 3})
    assert buf.add(mk(0, 2), 0.0) == "accepted"       # fresh
    assert buf.add(mk(1, 1), 0.0) == "accepted"       # 1 late, in window
    assert buf.add(mk(2, 0), 0.0) == "stale_dropped"  # beyond window
    assert buf.add(mk(0, 2), 0.0) == "duplicate"      # same freshness
    assert buf.add(mk(1, 2), 0.0) == "replaced"       # fresher than stale
    assert buf.add(mk(3, 3), 0.0) == "future"         # next round, held
    assert buf.add(mk(99, 2), 0.0) == "bad_client"
    bad = ClientUpdate(client_id=4, round_id=2, mask_id=777,
                       values=np.zeros(4), payload_bytes=1)
    assert buf.add(bad, 0.0) == "bad_mask"
    assert buf.count == 2  # clients 0 and 1 (3's update is held as future)
    refed = buf.open(3, now=1.0, mask_id=3)
    assert [(u.client_id, s) for u, s in refed] == [(3, "accepted")]


def test_staleness_exactly_at_window_boundary():
    """k == staleness_window is IN the window (accepted, discounted);
    k == window + 1 is the first dropped lateness — the boundary is
    inclusive, pinned here so it can never silently flip."""
    buf = RoundBuffer(n_clients=13, f=3, quorum=13, timeout_s=0.0,
                      staleness_window=3, stale_policy="discount")
    for r in range(8):
        buf._mask_ids[r] = r
    buf.open(5, now=0.0, mask_id=5)
    buf._mask_ids.update({r: r for r in range(8)})
    at_boundary = ClientUpdate(client_id=1, round_id=2, mask_id=2,
                               values=np.zeros(4), payload_bytes=1)
    past_boundary = ClientUpdate(client_id=2, round_id=1, mask_id=1,
                                 values=np.zeros(4), payload_bytes=1)
    assert buf.add(at_boundary, 0.0) == "accepted"       # k = 3 = window
    assert buf.rows()[1].staleness == 3
    assert buf.add(past_boundary, 0.0) == "stale_dropped"  # k = 4


def test_beta_pow_underflow_at_large_staleness():
    """beta^k in float32 underflows to exactly 0.0 (not NaN/inf) at large
    k: an absurdly stale update inside an absurdly wide window contributes
    NOTHING to the aggregate instead of poisoning it. The batcher computes
    the discount exactly like this (np.float32 beta ** int staleness)."""
    beta = np.float32(0.9)
    with np.errstate(under="ignore"):
        tiny = beta ** 400          # 0.9^400 ~ 5e-19: denormal-ish, finite
        zero = beta ** 5000         # far below float32 denormal range
    assert np.isfinite(tiny) and tiny >= 0
    assert zero == np.float32(0.0) and not np.isnan(zero)
    # the buffer itself accepts the huge-k update when the window allows
    buf = RoundBuffer(n_clients=13, f=3, quorum=13, timeout_s=0.0,
                      staleness_window=5000, stale_policy="discount")
    buf.open(5000, now=0.0, mask_id=0)
    buf._mask_ids[0] = 0
    u = ClientUpdate(client_id=0, round_id=0, mask_id=0,
                     values=np.ones(4), payload_bytes=1)
    assert buf.add(u, 0.0) == "accepted"
    assert buf.rows()[0].staleness == 5000


def test_quorum_exactly_2f_plus_1_with_f_clients_silent():
    """quorum = 2f+1 (the robustness floor) with all f byzantine clients
    permanently silent: every round still fires BY QUORUM from honest
    updates alone — the floor is reachable without any byzantine report."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=2 * cfg.f + 1, timeout_s=0.0)
    # silent = scheduled beyond any window, with drop policy: never lands
    beh = ClientBehavior(stragglers=tuple(range(cfg.f)),
                         straggle_rounds=10_000)
    server, _, results = _run_serve(cfg, loss_fn, params0, batch_fn, 6,
                                    serve=serve, behavior=beh)
    assert len(results) == 6
    for r in results:
        assert r.fired_by == "quorum"
        assert r.n_updates >= 2 * cfg.f + 1
        assert all(c >= cfg.f for c in r.client_ids)   # honest-only rounds
    assert server.step_traces == 1


def test_round_decision_histograms_surface_classifications():
    """Satellite: the per-round classification counters (duplicate/stale/
    future/bad_mask...) show up as histograms in the metrics summary."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=cfg.n_workers - 2, timeout_s=0.05,
                        staleness_window=2)
    beh = ClientBehavior(stragglers=(11, 12), straggle_rounds=1)
    server, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, 8,
                              serve=serve, behavior=beh)
    s = server.metrics.summary()
    hists = s["decision_round_histograms"]
    assert "accepted" in hists
    # every status that was observed at all has a per-round histogram
    for status in s["ingest_decisions"]:
        assert status in hists
        total = sum(k_count * v for k_str, v in hists[status].items()
                    for k_count in [int(k_str)])
        assert total == s["ingest_decisions"][status]
    # each fired round records the quorum it fired under
    assert sum(s["quorum_histogram"].values()) == s["rounds"]


def test_mask_id_is_stable():
    k = jax.random.PRNGKey(7)
    assert mask_id(np.asarray(k)) == mask_id(np.asarray(k))
    assert mask_id(np.asarray(k)) != mask_id(
        np.asarray(jax.random.PRNGKey(8)))


# --------------------------------------------------------------------------
# checkpoint kill-and-resume
# --------------------------------------------------------------------------


def test_checkpoint_kill_and_resume_identical(tmp_path):
    """Kill after 6 rounds (checkpoint_every=3), restore into a FRESH
    server (wrong seed, overwritten by the checkpoint), continue to 12:
    bit-for-bit the uninterrupted 12-round run (and the simulator's)."""
    cfg = _REGISTRY_CELLS[sorted(_REGISTRY_CELLS)[0]].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    straight, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, 12)

    td = str(tmp_path)
    serve = ServeConfig(checkpoint_every=3, checkpoint_dir=td)
    sA = ByzantineRobustServer(cfg, params0, serve, seed=0)
    run_service(sA, ClientPool(loss_fn, params0, cfg, batch_fn), 6)

    ckpt = sorted(glob.glob(os.path.join(td, "*.npz")))[-1]
    sB = ByzantineRobustServer(cfg, params0, serve, seed=1234)
    assert sB.restore(ckpt.replace(".npz", "")) == 6
    run_service(sB, ClientPool(loss_fn, params0, cfg, batch_fn), 6)

    np.testing.assert_array_equal(np.asarray(straight.params_flat),
                                  np.asarray(sB.params_flat))
    sim_params, _ = _run_sim(cfg, loss_fn, params0, batch_fn, 12)
    np.testing.assert_array_equal(sim_params, np.asarray(sB.params_flat))


def test_restore_after_start_raises(tmp_path):
    cfg = _REGISTRY_CELLS[sorted(_REGISTRY_CELLS)[0]].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(checkpoint_every=2, checkpoint_dir=str(tmp_path))
    s = ByzantineRobustServer(cfg, params0, serve, seed=0)
    run_service(s, ClientPool(loss_fn, params0, cfg, batch_fn), 2)
    ckpt = glob.glob(os.path.join(str(tmp_path), "*.npz"))[0]
    s2 = ByzantineRobustServer(cfg, params0, serve, seed=0).start()
    with pytest.raises(RuntimeError, match="before start"):
        s2.restore(ckpt.replace(".npz", ""))
    s2.stop()


# --------------------------------------------------------------------------
# service behaviour
# --------------------------------------------------------------------------


def test_one_compile_across_participation_levels():
    """The acceptance gate: one server instance driven at full, dropping,
    and late participation must compile its step exactly once."""
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    serve = ServeConfig(quorum=cfg.n_workers - 3, timeout_s=0.05,
                        staleness_window=2)
    server = ByzantineRobustServer(cfg, params0, serve, seed=0)
    for beh in (None, ClientBehavior(drop_prob=0.3, seed=1),
                ClientBehavior(late_prob=0.4, seed=2)):
        pool = ClientPool(loss_fn, params0, cfg, batch_fn, behavior=beh)
        run_service(server, pool, 5, stop=False)
    server.stop()
    assert server.step_traces == 1
    levels = set(r.n_updates
                 for r in server.metrics.rounds)
    assert len(levels) > 1  # the gate actually saw multiple levels


def test_wait_round_times_out_loudly_below_quorum():
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, _, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    server.start()
    try:
        with pytest.raises(TimeoutError, match="quorum"):
            server.wait_round(0, timeout=0.2)
    finally:
        server.stop()


def test_submit_rejects_bad_shape():
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    _, params0, _, _ = _testbed(cfg)
    server = ByzantineRobustServer(cfg, params0, ServeConfig(), seed=0)
    bad = ClientUpdate(client_id=0, round_id=0, mask_id=0,
                       values=np.zeros(3), payload_bytes=1)
    with pytest.raises(ValueError, match="shape"):
        server.submit(bad)


def test_metrics_throughput_sane():
    cfg = grid_scenarios(n_honest=10, f=3)[0].cfg
    loss_fn, params0, batch_fn, _ = _testbed(cfg)
    server, _, _ = _run_serve(cfg, loss_fn, params0, batch_fn, 10)
    s = server.metrics.summary()
    assert s["rounds"] == 10
    assert s["updates_accepted"] == 10 * cfg.n_workers
    assert s["updates_per_sec"] > 0
    assert s["latency_p99_ms"] >= s["latency_p50_ms"] > 0
