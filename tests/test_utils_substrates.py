"""Tree-flattening property tests (hypothesis), optimizers, data pipeline,
and sharding-rule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.utils import tree as T


# --------------------------------------------------------------------------
# tree ravel/unravel
# --------------------------------------------------------------------------


@given(st.lists(st.integers(1, 7), min_size=1, max_size=5),
       st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_ravel_roundtrip(sizes, pad_to):
    tree = {f"p{i}": jnp.arange(s, dtype=jnp.float32) * (i + 1)
            for i, s in enumerate(sizes)}
    spec = T.make_flat_spec(tree, pad_to=pad_to)
    flat = T.tree_ravel(tree, spec)
    assert flat.shape == (spec.padded_size,)
    assert spec.padded_size % pad_to == 0
    back = T.tree_unravel(flat, spec)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


@given(st.integers(1, 5), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_stacked_ravel_roundtrip(n, leaves):
    tree = {f"w{i}": jax.random.normal(jax.random.PRNGKey(i),
                                       (n, 2 + i, 3)) for i in range(leaves)}
    unstacked = jax.tree_util.tree_map(lambda l: l[0], tree)
    spec = T.make_flat_spec(unstacked, pad_to=8)
    flat = T.stacked_ravel(tree, spec)
    assert flat.shape == (n, spec.padded_size)
    back = T.stacked_unravel(flat, spec)
    for k in tree:
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(tree[k]),
                                   rtol=1e-6)


def test_flat_spec_on_shape_structs():
    tree = {"a": jax.ShapeDtypeStruct((3, 4), jnp.float32),
            "b": jax.ShapeDtypeStruct((5,), jnp.bfloat16)}
    spec = T.make_flat_spec(tree, pad_to=16)
    assert spec.size == 17 and spec.padded_size == 32


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------


@pytest.mark.parametrize("make", ["sgd", "heavy_ball", "adamw"])
def test_optimizers_minimise_quadratic(make):
    from repro import optim
    opt = {"sgd": optim.sgd(0.1), "heavy_ball": optim.heavy_ball(0.1),
           "adamw": optim.adamw(0.05)}[make]
    params = {"x": jnp.ones(4) * 5.0}
    state = opt.init(params)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        upd, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_cosine_schedule():
    from repro.optim import cosine_schedule
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-5)


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------


def test_synthetic_mnist_shapes_and_heterogeneity():
    from repro.data import SyntheticMNIST
    homo = SyntheticMNIST(n_workers=4, per_worker=500, alpha_het=1e6, seed=0)
    het = SyntheticMNIST(n_workers=4, per_worker=500, alpha_het=0.3, seed=0)
    assert homo.images.shape == (4, 500, 28, 28, 1)

    def label_skew(ds):
        props = np.stack([np.bincount(ds.labels[w], minlength=10) / 500
                          for w in range(4)])
        return float(props.std(0).mean())

    assert label_skew(het) > 2 * label_skew(homo)


def test_batch_fn_stacking():
    from repro.data import SyntheticMNIST
    ds = SyntheticMNIST(n_workers=3, per_worker=100, seed=1)
    b = ds.worker_batches(8)(0)
    assert b["images"].shape == (3, 8, 28, 28, 1)
    assert b["labels"].shape == (3, 8)


# --------------------------------------------------------------------------
# sharding rules (AbstractMesh — no devices needed)
# --------------------------------------------------------------------------


def _abstract_mesh(sizes, names):
    # jax >= 0.5 signature is (axis_sizes, axis_names); 0.4.x takes a single
    # tuple of (name, size) pairs
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_param_spec_rules():
    from repro.sharding.partitioning import param_spec
    # attention projection: TP on output dim
    assert param_spec("blocks/attn/wq/w", (64, 2048, 4096), MESH,
                      fsdp=False) == P(None, None, "model")
    # fsdp adds data on the input dim
    assert param_spec("blocks/attn/wq/w", (64, 2048, 4096), MESH,
                      fsdp=True) == P(None, "data", "model")
    # wo transposed
    assert param_spec("blocks/attn/wo/w", (64, 4096, 2048), MESH,
                      fsdp=False) == P(None, "model", None)
    # moe expert banks: experts over model
    assert param_spec("blocks/moe/wi", (26, 64, 2048, 1408), MESH,
                      fsdp=False) == P(None, "model", None, None)
    # norms replicated
    assert param_spec("blocks/norm1/scale", (64, 2048), MESH,
                      fsdp=False) == P(None, None)
    # indivisible dims are dropped, not mis-sharded
    assert param_spec("blocks/attn/wk/w", (2, 100, 30), MESH,
                      fsdp=True) == P(None, None, None)


def test_embed_and_head_specs():
    from repro.sharding.partitioning import param_spec
    assert param_spec("embed", (256000, 2048), MESH, fsdp=False) == \
        P("model", None)
    # mamba vocab 50280 % 16 != 0 -> vocab axis dropped
    assert param_spec("embed", (50280, 2048), MESH, fsdp=False) == \
        P(None, None)
    assert param_spec("lm_head", (2048, 151936), MESH, fsdp=False) == \
        P(None, "model")


def test_batch_and_bank_specs():
    from repro.sharding.partitioning import bank_spec, batch_spec, dp_axes
    assert dp_axes(MESH3) == ("pod", "data")
    assert batch_spec(MESH, (256, 4096)) == P(("data",), None)
    assert batch_spec(MESH3, (32, 8, 4096), worker_dim=True) == \
        P(("pod", "data"), None, None)
    assert batch_spec(MESH, (1, 8192)) == P(None, None)  # indivisible
    # bank coordinate tiling is MODEL-MAJOR (see partitioning.server_axes)
    assert bank_spec(MESH3) == P(None, ("model", "pod", "data"))


def test_cache_spec_avoids_seq_dim():
    from repro.sharding.partitioning import cache_spec
    # [B, S, KV, hd]: model on the trailing head_dim, batch over dp
    assert cache_spec(MESH, (128, 32768, 32, 128), batch=128) == \
        P(("data",), None, None, "model")
    # stacked layer dim first: batch identified by value; seq NEVER sharded
    assert cache_spec(MESH, (88, 128, 32768, 8, 128), batch=128) == \
        P(None, ("data",), None, None, "model")
    # nothing divisible (batch 4 < 16, heads/hd indivisible) -> fully
    # replicated; the seq dim is never chosen despite being divisible
    assert cache_spec(MESH, (4, 32768, 3, 100), batch=4) == \
        P(None, None, None, None)


def test_checkpoint_roundtrip(tmp_path):
    from repro import checkpoint as ckpt
    tree = {"layer": {"w": np.arange(6.0).reshape(2, 3),
                      "b": np.zeros(3)},
            "step_arr": np.asarray(7)}
    p = str(tmp_path / "t.npz")
    ckpt.save(p, tree, metadata={"note": "x"}, step=11)
    back = ckpt.restore(p, tree)
    np.testing.assert_array_equal(back["layer"]["w"], tree["layer"]["w"])
    assert ckpt.latest_step(p) == 11
