"""One-program grid engine tests (plan/execute tentpole).

The switch-bank + traced-scenario fusion must reproduce the per-scenario
compiled programs cell for cell; the plan layer must partition grids into
maximal fusible banks; the sharded executor must match the single-device
path with pad rows masked out; and the in-scan eval snapshots must
reproduce the legacy eval protocol.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, ScenarioParams,
    Simulator, SparsifierConfig, bytes_to_threshold,
    grid_scenarios, plan_grid, quadratic_testbed, rollout_over_seeds,
    run_scenarios, stack_batches,
)
from repro.core.sweep import Scenario, fused_grid_rollout

N, F, D, STEPS = 13, 3, 32, 20


def _testbed():
    return quadratic_testbed(N, D)


def _cfg(algo="rosdhb", attack="alie", agg="cwtm", ratio=0.2, kind="randk",
         pre_nnm=True):
    return AlgorithmConfig(
        name=algo, n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind=kind, ratio=ratio),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=pre_nnm),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))


# --------------------------------------------------------------------------
# plan layer
# --------------------------------------------------------------------------


def test_plan_grid_fuses_algo_x_attack_x_aggregator():
    scenarios = grid_scenarios(
        ["rosdhb", "dasha"], ["alie", "signflip", "foe"], ["cwtm", "median"],
        n_honest=10, f=3, ratio=0.1)
    plan = plan_grid(scenarios)
    # the whole cross-algorithm product is ONE maximal bank
    assert plan.n_programs == 1 and not plan.singles
    b = plan.banks[0]
    assert b.n_cells == len(scenarios) == plan.n_cells == 12
    # executable bank config: algorithm bank + attack bank + switch bank,
    # each restricted to the branches the grid actually uses
    assert b.cfg.name == "bank"
    assert b.cfg.bank == ("rosdhb", "dasha")
    assert b.cfg.attack.name == "bank"
    assert b.cfg.attack.bank == ("linear",)  # only linear-family cells
    assert b.cfg.aggregator.name == "bank"
    assert set(b.cfg.aggregator.bank) == {("cwtm", True), ("median", True)}
    # per-cell traced algorithm data: branch index + hyperparameters + gamma
    assert set(b.algo_idx) == {0, 1}
    assert all(hp[0] == 0.9 and hp[1] == 0.0
               for hp, i in zip(b.hparams, b.algo_idx)
               if i == 0)  # rosdhb cells carry beta, inert mvr_a
    assert all(hp[0] == 0.0 and hp[1] == pytest.approx(0.1)
               for hp, i in zip(b.hparams, b.algo_idx) if i == 1)  # dasha: a
    assert all(hp[2] == 1.0 - hp[0] and hp[3] == 1.0 - hp[1]
               for hp in b.hparams)  # precomputed complements
    assert b.gammas == (0.05,) * 12


def test_plan_grid_cross_algo_false_keeps_per_algorithm_banks():
    """The legacy one-bank-per-algorithm partition survives as the
    equivalence baseline (cross_algo=False)."""
    scenarios = grid_scenarios(
        ["rosdhb", "dasha"], ["alie", "signflip", "foe"], ["cwtm", "median"],
        n_honest=10, f=3, ratio=0.1)
    plan = plan_grid(scenarios, cross_algo=False)
    assert plan.n_programs == 2 and not plan.singles
    assert sorted(b.cfg.name for b in plan.banks) == ["dasha", "rosdhb"]
    assert all(b.n_cells == 6 for b in plan.banks)
    for b in plan.banks:
        assert b.algo_idx is None and b.hparams is None and b.gammas is None
        assert b.cfg.attack.name == "bank"
        assert b.cfg.aggregator.name == "bank"


def test_plan_grid_none_attacks_and_singletons_fall_back():
    # stateful attacks (mimic/gauss) now fuse — see test_adversary.py; only
    # 'none' attacks and singleton groups stay per-scenario programs
    scenarios = grid_scenarios(["rosdhb"], ["alie", "none"],
                               ["cwtm"], n_honest=10, f=3)
    plan = plan_grid(scenarios)
    assert not plan.banks and len(plan.singles) == 2
    assert plan_grid(scenarios, fuse=False).n_programs == 2


def test_plan_grid_traces_ratio_only_for_traceable_kinds():
    def sc(kind, ratio):
        cfg = _cfg(attack="alie", kind=kind, ratio=ratio)
        return Scenario(label=f"{kind}/{ratio}", cfg=cfg)

    def sc2(kind, ratio):
        cfg = _cfg(attack="foe", kind=kind, ratio=ratio)
        return Scenario(label=f"{kind}/{ratio}/foe", cfg=cfg)

    # bernoulli: ratios become traced data -> ONE bank
    plan = plan_grid([sc("bernoulli", 0.1), sc2("bernoulli", 0.5)])
    assert plan.n_programs == 1
    assert plan.banks[0].ratios == (0.1, 0.5)
    # randk: static-shape k -> ratio stays config, no fusion across ratios
    plan = plan_grid([sc("randk", 0.1), sc2("randk", 0.5)])
    assert not plan.banks and len(plan.singles) == 2
    # equal ratios need no tracing even for bernoulli
    plan = plan_grid([sc("bernoulli", 0.1), sc2("bernoulli", 0.1)])
    assert plan.n_programs == 1 and plan.banks[0].ratios is None


# --------------------------------------------------------------------------
# execute layer: fused bank == per-scenario programs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["rosdhb", "dasha", "robust_dgd"])
def test_fused_bank_matches_per_scenario_rollouts(algo):
    """Acceptance core: the one-program bank (traced attack coeffs +
    aggregator switch) reproduces every per-scenario compiled program."""
    loss_fn, params0, batch_fn, _ = _testbed()
    ratio = 1.0 if algo == "robust_dgd" else 0.2
    scenarios = grid_scenarios([algo], ["alie", "signflip", "zero"],
                               ["cwtm", "median"], n_honest=N - F, f=F,
                               ratio=ratio)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1
    bank = plan.banks[0]
    seeds = [0, 1]
    batches = stack_batches(batch_fn, STEPS)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    states, metrics = fused_grid_rollout(sim, bank.scenario_params(), seeds,
                                         batches, shard=False)
    assert sim.round_traces == 1  # ONE compiled program for the whole bank
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        ref_states, ref_metrics = rollout_over_seeds(ref, seeds, batches)
        np.testing.assert_allclose(
            np.asarray(states.params_flat[c]),
            np.asarray(ref_states.params_flat),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


def test_fused_traced_ratio_matches_static_ratio():
    """bernoulli keep-ratios as traced data == static-config ratios."""
    loss_fn, params0, batch_fn, _ = _testbed()
    batches = stack_batches(batch_fn, STEPS)
    seeds = [0, 1]
    ratios = (0.1, 0.5, 1.0)
    scenarios = [Scenario(label=f"r{r}",
                          cfg=_cfg(kind="bernoulli", ratio=r))
                 for r in ratios]
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].ratios == ratios
    bank = plan.banks[0]
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    _, metrics = fused_grid_rollout(sim, bank.scenario_params(), seeds,
                                    batches, shard=False)
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        _, ref_metrics = rollout_over_seeds(ref, seeds, batches)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


@pytest.mark.slow
def test_acceptance_grid_is_one_program_and_matches_unfused():
    """ISSUE acceptance: rosdhb x {alie,signflip,ipm,foe,zero} x
    {cwtm,median,geomed} x 4 seeds executes as ONE compiled program and
    matches the unfused rollout_over_seeds results."""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = grid_scenarios(
        ["rosdhb"], ["alie", "signflip", "ipm", "foe", "zero"],
        ["cwtm", "median", "geomed"], n_honest=N - F, f=F, ratio=0.1)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == 15
    seeds = [0, 1, 2, 3]
    batches = stack_batches(batch_fn, STEPS)
    bank = plan.banks[0]
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    _, metrics = fused_grid_rollout(sim, bank.scenario_params(), seeds,
                                    batches, shard=False)
    assert sim.round_traces == 1
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        _, ref_metrics = rollout_over_seeds(ref, seeds, batches)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


def test_run_scenarios_bank_fusion_matches_unfused_rows():
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = grid_scenarios(["rosdhb"], ["alie", "foe"],
                               ["cwtm", "median"], n_honest=N - F, f=F,
                               ratio=0.25)
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1], steps=12)
    fused = run_scenarios(scenarios, fuse_attacks=True, shard=False, **kw)
    unfused = run_scenarios(scenarios, fuse_attacks=False, **kw)
    assert [(r["scenario"], r["seed"]) for r in fused] == \
        [(r["scenario"], r["seed"]) for r in unfused]
    for rf, ru in zip(fused, unfused):
        np.testing.assert_allclose(rf["final_loss"], ru["final_loss"],
                                   rtol=1e-5, err_msg=rf["scenario"])
        np.testing.assert_allclose(rf["min_loss"], ru["min_loss"], rtol=1e-5)


def test_mixed_ratio_bank_rows_carry_per_cell_comm_bytes():
    """Inside a traced-ratio bank every cell must report ITS ratio's byte
    cost, not the bank config's static ratio."""
    loss_fn, params0, batch_fn, _ = _testbed()
    ratios = (0.125, 0.5)
    scenarios = [Scenario(label=f"r{r}", cfg=_cfg(kind="bernoulli", ratio=r))
                 for r in ratios]
    assert plan_grid(scenarios).n_programs == 1  # fused despite the ratios
    rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=[0], steps=8, shard=False)
    by_label = {r["scenario"]: r for r in rows}
    b_small = by_label["r0.125"]["comm_bytes"]
    b_big = by_label["r0.5"]["comm_bytes"]
    assert b_small < b_big
    assert b_big == pytest.approx(b_small * (0.5 / 0.125), rel=0.01)


def test_fused_grid_rollout_rejects_empty_and_ragged_params():
    loss_fn, params0, batch_fn, _ = _testbed()
    sim = Simulator(loss_fn=loss_fn, params0=params0,
                    cfg=_cfg(attack="linear"))
    with pytest.raises(ValueError, match="no traced components"):
        fused_grid_rollout(sim, ScenarioParams(), [0], batch_fn, steps=2)
    ragged = ScenarioParams(attack_coeffs=jnp.zeros((2, 2)),
                            agg_idx=jnp.zeros((3,), jnp.int32))
    with pytest.raises(ValueError, match="inconsistent"):
        fused_grid_rollout(sim, ragged, [0], batch_fn, steps=2)


# --------------------------------------------------------------------------
# in-scan eval (snapshot carry)
# --------------------------------------------------------------------------


def test_rollout_with_snapshots_matches_eval_round_params():
    loss_fn, params0, batch_fn, _ = _testbed()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg())
    eval_rounds = [0, 5, 10, 19]
    st, ms, snaps = sim.rollout_with_snapshots(sim.init(0), batch_fn,
                                               eval_rounds, steps=STEPS)
    assert snaps.shape == (len(eval_rounds), sim.spec.padded_size)
    # reference: per-round loop, capturing params after each eval round
    ref = sim.init(0)
    want = {}
    for t in range(STEPS):
        ref, _ = sim._round(ref, batch_fn(t))
        if t in eval_rounds:
            want[t] = np.asarray(ref.params_flat)
    for i, t in enumerate(eval_rounds):
        np.testing.assert_allclose(np.asarray(snaps[i]), want[t],
                                   rtol=1e-5, atol=1e-7, err_msg=f"round {t}")
    np.testing.assert_allclose(np.asarray(st.params_flat),
                               np.asarray(ref.params_flat),
                               rtol=1e-5, atol=1e-7)


def test_rollout_with_snapshots_rejects_unsorted_or_duplicate_rounds():
    """Rows are written chronologically by a slot counter, so an unsorted
    or duplicated schedule would silently misalign the snapshot buffer."""
    loss_fn, params0, batch_fn, _ = _testbed()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg())
    for bad in ([5, 3], [2, 2, 7], [-8, 1], [0, 10]):
        with pytest.raises(ValueError, match="strictly increasing"):
            sim.rollout_with_snapshots(sim.init(0), batch_fn, bad, steps=10)


def test_run_single_scan_matches_legacy_history_with_eval():
    """Satellite: in-scan eval vs legacy run history equivalence (eval
    metrics included)."""
    loss_fn, params0, batch_fn, tg = _testbed()
    opt = np.asarray(tg[F:]).mean(0)
    sim = Simulator(
        loss_fn=loss_fn, params0=params0, cfg=_cfg(),
        eval_fn=lambda p, b: {"dist": jnp.linalg.norm(p["w"] - b["opt"])})
    kw = dict(steps=23, eval_every=5, eval_batch={"opt": opt})
    st_a, h_a = sim.run_per_round(sim.init(0), batch_fn, **kw)
    st_b, h_b = sim.run(sim.init(0), batch_fn, **kw)
    assert h_a["step"] == h_b["step"] == [0, 5, 10, 15, 20, 22]
    assert h_a["comm_bytes"] == h_b["comm_bytes"]
    for k in ("loss", "dist"):
        np.testing.assert_allclose(h_a[k], h_b[k], rtol=1e-5, err_msg=k)
    np.testing.assert_allclose(np.asarray(st_a.params_flat),
                               np.asarray(st_b.params_flat),
                               rtol=1e-5, atol=1e-7)
    # early stop truncates the history at the same eval round
    thresh = h_a["dist"][2]
    stop = lambda m: m["dist"] <= thresh  # noqa: E731
    _, h_c = sim.run_per_round(sim.init(0), batch_fn, stop_fn=stop, **kw)
    _, h_d = sim.run(sim.init(0), batch_fn, stop_fn=stop, **kw)
    assert h_c["step"] == h_d["step"]
    assert len(h_d["step"]) < len(h_b["step"])


def test_run_pays_one_compile_regardless_of_eval_schedule():
    """The chunk-boundary recompiles ({1, eval_every, remainder} lengths)
    are gone: one run with eval = one round-body trace."""
    loss_fn, params0, batch_fn, _ = _testbed()
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=_cfg())
    sim.run(sim.init(0), batch_fn, steps=23, eval_every=5)
    assert sim.round_traces == 1


# --------------------------------------------------------------------------
# bytes_to_threshold: arbitrary leading batch axes (satellite)
# --------------------------------------------------------------------------


def test_bytes_to_threshold_3d_grid_output():
    traj = np.asarray([5.0, 3.0, 1.0, 0.5, 0.4])
    grid = np.stack([np.stack([traj, traj * 10]),
                     np.stack([traj / 10, traj + 10])])
    out = bytes_to_threshold(grid, 100, 1.0)
    assert out.shape == (2, 2)
    np.testing.assert_array_equal(out, [[300.0, np.inf],
                                        [100.0, np.inf]])


def test_bytes_to_threshold_never_crosses_is_inf_everywhere():
    v = np.full((3, 2, 4), 9.0)
    out = bytes_to_threshold(v, 7, 1.0)
    assert out.shape == (3, 2)
    assert np.all(np.isinf(out))
    # rising-metric mode on 3-D as well
    out = bytes_to_threshold(v, 7, 1.0, mode=">=")
    np.testing.assert_array_equal(out, np.full((3, 2), 7.0))


def test_bytes_to_threshold_rejects_scalar():
    with pytest.raises(ValueError, match="round axis"):
        bytes_to_threshold(np.float32(1.0), 7, 1.0)


# --------------------------------------------------------------------------
# sharded execution (forced multi-device subprocess; device count is fixed
# at jax init, so the sharded path needs its own process)
# --------------------------------------------------------------------------

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    assert len(jax.devices()) == 4
    from repro.core import (Simulator, grid_scenarios, plan_grid,
                            quadratic_testbed, run_scenarios, stack_batches)
    from repro.core.sweep import fused_grid_rollout

    loss_fn, params0, batch_fn, _ = quadratic_testbed(13, 16)
    scenarios = grid_scenarios(["rosdhb"], ["alie", "signflip", "foe"],
                               ["cwtm", "median"], n_honest=10, f=3,
                               ratio=0.1)
    # 6 cells x 3 seeds = 18 rows; 18 % 4 != 0 exercises pad-row masking
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1, 2], steps=10)
    sharded = run_scenarios(scenarios, shard=True, **kw)
    single = run_scenarios(scenarios, shard=False, **kw)
    assert len(sharded) == len(single) == 18  # pad rows masked out
    for rs, r1 in zip(sharded, single):
        assert rs["scenario"] == r1["scenario"] and rs["seed"] == r1["seed"]
        np.testing.assert_allclose(rs["final_loss"], r1["final_loss"],
                                   rtol=1e-5, err_msg=rs["scenario"])
    # the sharded bank is still ONE compiled program
    bank = plan_grid(scenarios).banks[0]
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    batches = stack_batches(batch_fn, 10)
    states, _ = fused_grid_rollout(sim, bank.scenario_params(), [0, 1, 2],
                                   batches, shard=True)
    assert sim.round_traces == 1
    assert np.asarray(states.params_flat).shape[:2] == (6, 3)
    # cross-algorithm bank + fused sharded eval: 4 algos x 2 attacks x
    # 3 seeds = 24 rows over 4 devices, sharded == single-device rows
    import jax.numpy as jnp
    opt = None
    loss_fn, params0, batch_fn, tg = quadratic_testbed(13, 16)
    opt = np.asarray(tg[3:]).mean(0)
    eval_fn = lambda p, b: {"dist": jnp.linalg.norm(p["w"] - b["opt"])}
    xalgo = grid_scenarios(["rosdhb", "dasha", "robust_dgd", "dgd"],
                           ["alie", "foe"], ["cwtm"], n_honest=10, f=3,
                           ratio=0.1)
    assert plan_grid(xalgo).n_programs == 1
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1, 2], steps=10, eval_fn=eval_fn,
              eval_batch={"opt": jnp.asarray(opt)})
    sharded = run_scenarios(xalgo, shard=True, **kw)
    single = run_scenarios(xalgo, shard=False, **kw)
    assert len(sharded) == len(single) == 24
    for rs, r1 in zip(sharded, single):
        assert rs["scenario"] == r1["scenario"]
        np.testing.assert_allclose(rs["final_loss"], r1["final_loss"],
                                   rtol=1e-5, err_msg=rs["scenario"])
        np.testing.assert_allclose(rs["dist"], r1["dist"], rtol=1e-5)
    print("SHARDED-SWEEP-OK")
""")


@pytest.mark.slow
def test_sharded_sweep_parity_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED-SWEEP-OK" in r.stdout
