"""Model zoo: forward/grad finiteness per family + decode==full-forward
consistency for every cache kind (attention, ring-buffer sliding window,
MLA latent, SSM state, hybrid, VLM)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (cache_init, forward, lm_loss, model_init)
from repro.models.config import ModelConfig
from repro.utils.tree import global_norm

KEY = jax.random.PRNGKey(0)
F32 = dict(dtype="float32")


def _mk(name, **kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=128)
    base.update(kw)
    return ModelConfig(name=name, **base, **F32)


CONFIGS = {
    "dense": _mk("dense", family="dense", qkv_bias=True),
    "geglu_mqa": _mk("geglu", family="dense", n_kv_heads=1, mlp="geglu",
                     head_dim=32, tie_embeddings=True),
    "window": _mk("window", family="dense", sliding_window=8),
    "moe": _mk("moe", family="moe", n_experts=4, top_k=2,
               n_shared_experts=1, first_k_dense=1, n_layers=3,
               capacity_factor=8.0),
    "mla_moe": _mk("mla", family="moe", n_kv_heads=4, n_experts=4, top_k=2,
                   capacity_factor=8.0, use_mla=True, kv_lora_rank=32,
                   qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    "ssm": _mk("ssm", family="ssm", ssm_state=16, ssm_head_dim=32,
               ssm_chunk=8),
    "hybrid": _mk("hybrid", family="hybrid", n_kv_heads=4, ssm_state=16,
                  ssm_head_dim=32, ssm_chunk=8, attn_every=2, n_layers=5),
    "vlm": _mk("vlm", family="vlm", cross_attn_every=2, n_layers=4,
               n_image_tokens=8),
    "audio": _mk("audio", family="audio", n_kv_heads=4,
                 input_kind="embeddings", mlp="gelu", norm="layernorm"),
}


def _batch(cfg, b=2, s=16, with_next=False):
    sl = s + 1 if with_next else s
    batch = {}
    if cfg.input_kind == "tokens":
        batch["tokens"] = jax.random.randint(KEY, (b, sl), 0, cfg.vocab_size)
    else:
        batch["embeddings"] = jax.random.normal(KEY, (b, sl, cfg.d_model))
        batch["targets"] = jax.random.randint(KEY, (b, sl), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeddings"] = jax.random.normal(
            KEY, (b, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", list(CONFIGS))
def test_forward_and_grad(name):
    cfg = CONFIGS[name]
    params = model_init(KEY, cfg)
    batch = _batch(cfg)
    hidden, _, aux = forward(params, cfg, batch, mode="train")
    assert hidden.shape == (2, 16, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(global_norm(grads)))


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_full_forward(name):
    cfg = CONFIGS[name]
    params = model_init(KEY, cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s, with_next=True)

    def sub(d, sl):
        out = {}
        for k, v in d.items():
            if k == "image_embeddings":
                out[k] = v
            else:
                out[k] = v[:, sl]
        return out

    full, _, _ = forward(params, cfg, batch, mode="train", remat=False)
    caches = cache_init(cfg, b, max_len=s + 1, dtype=jnp.float32)
    pre, caches, _ = forward(params, cfg, sub(batch, slice(0, s)),
                             mode="prefill", pos=0, caches=caches)
    dec, caches, _ = forward(params, cfg, sub(batch, slice(s, s + 1)),
                             mode="decode", pos=s, caches=caches)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :s]),
                               atol=2e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, s]),
                               atol=2e-2, rtol=1e-2)


def test_ring_buffer_multi_step_decode():
    cfg = CONFIGS["window"]
    params = model_init(KEY, cfg)
    b, s, extra = 2, 10, 5
    toks = jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, {"tokens": toks}, mode="train",
                         remat=False)
    caches = cache_init(cfg, b, max_len=s + extra, dtype=jnp.float32)
    _, caches, _ = forward(params, cfg, {"tokens": toks[:, :s]},
                           mode="prefill", pos=0, caches=caches)
    for i in range(extra):
        h, caches, _ = forward(params, cfg,
                               {"tokens": toks[:, s + i:s + i + 1]},
                               mode="decode", pos=s + i, caches=caches)
        np.testing.assert_allclose(np.asarray(h[:, 0]),
                                   np.asarray(full[:, s + i]), atol=2e-2,
                                   rtol=1e-2)


def test_moe_router_aux_loss_positive():
    cfg = CONFIGS["moe"]
    params = model_init(KEY, cfg)
    _, _, aux = forward(params, cfg, _batch(cfg), mode="train")
    assert float(aux["moe_loss"]) > 0.0


def test_hybrid_shared_attention_is_shared():
    """Zamba2 semantics: ONE attention block's weights reused per group."""
    cfg = CONFIGS["hybrid"]
    params = model_init(KEY, cfg)
    # the shared block exists once, not stacked per group
    wq = params["shared_attn"]["attn"]["wq"]["w"]
    assert wq.ndim == 2


def test_loss_decreases_tiny_training():
    cfg = CONFIGS["dense"]
    params = model_init(KEY, cfg)
    batch = _batch(cfg, b=4, s=32)
    loss0 = float(lm_loss(params, cfg, batch))
    g = jax.grad(lm_loss)(params, cfg, batch)
    params = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)
    loss1 = float(lm_loss(params, cfg, batch))
    assert loss1 < loss0
