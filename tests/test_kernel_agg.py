"""Kernel-backed aggregator bank vs the jnp rules, branch for branch.

``use_pallas=True`` on the CPU test host resolves to interpret mode
(`repro.core.aggregators.resolve_kernel_backend`), so these tests execute
the real Pallas kernel bodies and gate the ISSUE-7 acceptance: every
``(name, pre_nnm)`` branch of the bank matches the jnp rule to rtol 1e-5
at batched grid-engine shapes, including inside a fused ``lax.switch``
under ``vmap`` + ``jit`` (the exact hot path of ``repro.core.sweep``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as G

KEY = jax.random.PRNGKey(0)
B, N, F, D = 5, 13, 3, 300  # n odd, d not a multiple of the 128-lane tile


def _grid(b=B, n=N, d=D, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, d)) * scale


def _pair(name, pre, f=F, **kw):
    cj = G.AggregatorConfig(name=name, f=f, pre_nnm=pre, use_pallas=False,
                            **kw)
    ck = G.AggregatorConfig(name=name, f=f, pre_nnm=pre, use_pallas=True,
                            **kw)
    return G.make_aggregator(cj), G.make_aggregator(ck)


def _assert_close(yj, yk, rtol=1e-5):
    scale = float(jnp.max(jnp.abs(yj))) + 1e-12
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yj),
                               atol=rtol * scale, rtol=rtol)


@pytest.mark.parametrize("name", G.BANK_NAMES)
@pytest.mark.parametrize("pre", [False, True])
def test_make_aggregator_branch_parity(name, pre):
    """Every (name, pre_nnm) combination, vmapped over the fused axis."""
    if name == "mean" and pre:
        pytest.skip("NNM composition skips mean (make_aggregator rule)")
    x = _grid(seed=hash((name, pre)) % 1000)
    agg_j, agg_k = _pair(name, pre)
    yj = jax.jit(jax.vmap(agg_j))(x)
    yk = jax.jit(jax.vmap(agg_k))(x)
    _assert_close(yj, yk)


@pytest.mark.parametrize("name", G.KERNEL_RULES)
def test_unbatched_parity(name):
    """The per-lane [n, d] entry point (no vmap) also dispatches right."""
    x = _grid(b=1, seed=42)[0]
    agg_j, agg_k = _pair(name, False)
    _assert_close(agg_j(x), agg_k(x))


@pytest.mark.parametrize("f", [0, 1, (N - 1) // 2])
def test_edge_f_parity(f):
    """f=0 (cwtm == mean) and n-2f=1 (single surviving rank)."""
    x = _grid(b=2, seed=f)
    for name in ("cwtm", "median", "krum"):
        agg_j, agg_k = _pair(name, False, f=f)
        _assert_close(jax.vmap(agg_j)(x), jax.vmap(agg_k)(x))


def test_bank_switch_parity_full():
    """The fused-bank hot path: lax.switch over every DEFAULT_BANK branch
    under vmap + jit, kernel backend vs jnp backend, every branch index
    exercised."""
    cj = G.AggregatorConfig(name="bank", f=F, use_pallas=False)
    ck = G.AggregatorConfig(name="bank", f=F, use_pallas=True)
    bank_j = jax.jit(jax.vmap(G.make_aggregator_bank(cj), in_axes=(0, 0)))
    bank_k = jax.jit(jax.vmap(G.make_aggregator_bank(ck), in_axes=(0, 0)))
    nb = len(G.DEFAULT_BANK)
    x = _grid(b=nb, d=256, seed=7)
    for shift in range(2):  # two index layouts so each lane sees 2 branches
        idx = (jnp.arange(nb) + shift) % nb
        _assert_close(bank_j(x, idx), bank_k(x, idx))


def test_bank_kernel_outlier_robustness():
    """Kernel-backed robust branches shrug off planted outliers exactly
    like the jnp branches do (not just numerically close on benign data)."""
    x = _grid(b=1, seed=3)[0]
    x = x.at[:F].set(1e6)
    for name in ("cwtm", "median", "krum"):
        _, agg_k = _pair(name, True)
        out = agg_k(x)
        assert float(jnp.max(jnp.abs(out))) < 100.0, name


def test_backend_labels():
    assert G.kernel_backend_label(False) == "jnp"
    expect = "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"
    assert G.kernel_backend_label(True) == expect
