"""Server-round algorithm tests on quadratic losses: convergence of each
algorithm, Byzantine resilience, the global-vs-local sparsification gap, and
Theorem-1 hyperparameter schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, SparsifierConfig,
    apply_direction, init_state, server_round, theorem1_hparams,
)

D = 48


def _targets(n, key=0, spread=0.1):
    k = jax.random.PRNGKey(key)
    return jax.random.normal(k, (n, D)) * spread + jnp.ones(D)


def _run(cfg, steps=600, seed=2, targets=None):
    tg = _targets(cfg.n_workers) if targets is None else targets
    st = init_state(cfg, D)
    th = jnp.zeros(D)
    k = jax.random.PRNGKey(seed)

    @jax.jit
    def one(th, st, k):
        k, sk = jax.random.split(k)
        r, st, _ = server_round(cfg, st, th[None, :] - tg, sk)
        return apply_direction(th, r, cfg.gamma), st, k

    for _ in range(steps):
        th, st, k = one(th, st, k)
    honest_opt = jnp.mean(tg[cfg.f:], axis=0)
    return float(jnp.linalg.norm(th - honest_opt))


@pytest.mark.parametrize("name,ratio,gamma", [
    ("rosdhb", 0.2, 0.1),
    ("dasha", 0.2, 0.1),
    ("robust_dgd", 1.0, 0.1),
    ("dgd", 0.2, 0.1),
])
def test_convergence_no_attack(name, ratio, gamma):
    cfg = AlgorithmConfig(
        name=name, n_workers=10, f=0, gamma=gamma, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=AggregatorConfig(name="cwtm", f=1),
        attack=AttackConfig(name="none"))
    assert _run(cfg) < 0.25


@pytest.mark.parametrize("attack", ["alie", "signflip", "foe", "ipm",
                                    "mimic", "zero"])
def test_rosdhb_resists_attacks(attack):
    f = 3
    cfg = AlgorithmConfig(
        name="rosdhb", n_workers=10, f=f, gamma=0.1, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.2),
        aggregator=AggregatorConfig(name="cwtm", f=f, pre_nnm=True),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))
    assert _run(cfg) < 0.5


def test_naive_dgd_breaks_under_foe():
    f = 3
    cfg = AlgorithmConfig(
        name="dgd", n_workers=10, f=f, gamma=0.1, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=0.2),
        aggregator=AggregatorConfig(name="mean"),
        attack=AttackConfig(name="foe", scale=10.0))
    d = _run(cfg, steps=300)
    assert not np.isfinite(d) or d > 2.0


def test_global_beats_local_sparsification():
    """Theorem 1 vs Theorem 2: coordinated masks should converge closer at
    equal budget (averaged over seeds)."""
    def dist(local, seed):
        cfg = AlgorithmConfig(
            name="rosdhb", n_workers=10, f=2, gamma=0.08, beta=0.9,
            sparsifier=SparsifierConfig(kind="randk", ratio=0.1, local=local),
            aggregator=AggregatorConfig(name="cwtm", f=2, pre_nnm=True),
            attack=AttackConfig(name="alie", z=1.5))
        return _run(cfg, steps=500, seed=seed)

    g = np.mean([dist(False, s) for s in range(3)])
    l = np.mean([dist(True, s) for s in range(3)])
    assert g < l


def test_theorem1_hparams():
    gamma, beta = theorem1_hparams(L=2.0, ratio=0.1)
    assert gamma == pytest.approx(0.1 / (23200 * 2.0))
    assert beta == pytest.approx(np.sqrt(1 - 24 * gamma * 2.0))
    # resolved_beta matches the schedule
    cfg = AlgorithmConfig(gamma=gamma, beta=None, smoothness_L=2.0)
    assert cfg.resolved_beta() == pytest.approx(beta)


def test_momentum_dtype_bank():
    cfg = AlgorithmConfig(name="rosdhb", n_workers=4, momentum_dtype="bfloat16")
    st = init_state(cfg, 16)
    assert st.momentum.dtype == jnp.bfloat16
    r, st2, _ = server_round(cfg, st, jnp.ones((4, 16)), jax.random.PRNGKey(0))
    assert st2.momentum.dtype == jnp.bfloat16
    assert r.shape == (16,)


def test_server_state_counts_steps():
    cfg = AlgorithmConfig(name="rosdhb", n_workers=4)
    st = init_state(cfg, 8)
    _, st, _ = server_round(cfg, st, jnp.ones((4, 8)), jax.random.PRNGKey(0))
    _, st, _ = server_round(cfg, st, jnp.ones((4, 8)), jax.random.PRNGKey(1))
    assert int(st.step) == 2
