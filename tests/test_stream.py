"""Streaming rollout engine: the prefetched ring-buffer pipeline and the
while-loop-of-scan-chunks early-exit program must reproduce the materialised
``lax.scan`` reference bit for bit (tentpole of the streaming-rollouts PR).
"""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AlgorithmConfig, AggregatorConfig, AttackConfig, Simulator,
    SparsifierConfig, quadratic_testbed, stack_batches,
)
from repro.core import sweep as SW
from repro.core import simulator as sim_lib
from repro.data import ChunkPrefetcher, batch_bytes, split_chunks

N, F, D, STEPS = 13, 3, 48, 50


def _sim(algo, attack="alie", agg=None, ratio=0.2, eval_fn=None):
    loss_fn, params0, batch_fn, tg = quadratic_testbed(N, D)
    agg = agg or ("mean" if algo == "dgd" else "cwtm")
    cfg = AlgorithmConfig(
        name=algo, n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(
            kind="randk", ratio=1.0 if algo == "robust_dgd" else ratio),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=(agg != "mean")),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))
    return Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg,
                     eval_fn=eval_fn), batch_fn


# --------------------------------------------------------------------------
# streaming == materialised, bit for bit
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo,attack", [
    ("rosdhb", "alie"),
    ("robust_dgd", "foe"),
    ("dgd", "signflip"),
])
@pytest.mark.parametrize("chunk,depth", [(16, 2), (10, 4), (50, 1)])
def test_streaming_matches_rollout_bitwise(algo, attack, chunk, depth):
    """Params, momentum AND every per-round metric must be exactly equal —
    the chunk program embeds the identical round body, so any drift is a
    wiring bug, not float noise."""
    sim, batch_fn = _sim(algo, attack=attack)
    batches = stack_batches(batch_fn, STEPS)
    st_ref, ms_ref = sim.rollout(sim.init(0), batches)
    st_s, ms_s, info = sim.rollout_streaming(
        sim.init(0), batches, chunk_size=chunk, prefetch_depth=depth)
    assert info["rounds_run"] == STEPS and not info["early_exit"]
    np.testing.assert_array_equal(np.asarray(st_s.params_flat),
                                  np.asarray(st_ref.params_flat))
    np.testing.assert_array_equal(np.asarray(st_s.server.momentum),
                                  np.asarray(st_ref.server.momentum))
    assert int(st_s.server.step) == STEPS
    for k in ms_ref:
        np.testing.assert_array_equal(np.asarray(ms_s[k]),
                                      np.asarray(ms_ref[k]), err_msg=k)


def test_streaming_callable_source_matches():
    """batch_fn streamed through the prefetch thread == pre-stacked array."""
    sim, batch_fn = _sim("rosdhb")
    st_ref, _ = sim.rollout(sim.init(1), stack_batches(batch_fn, STEPS))
    st_s, _, info = sim.rollout_streaming(
        sim.init(1), batch_fn, steps=STEPS, chunk_size=16, prefetch_depth=3)
    np.testing.assert_array_equal(np.asarray(st_s.params_flat),
                                  np.asarray(st_ref.params_flat))
    assert info["host_high_water_bytes"] <= \
        (info["prefetch_depth"] + 1) * info["chunk_bytes"]


def test_streaming_fused_bank_under_execute_plan():
    """A cross-algorithm fused bank streamed chunk-by-chunk returns the
    exact rows of the materialised plan execution."""
    loss_fn, params0, batch_fn, _ = quadratic_testbed(N, D)
    scen = SW.grid_scenarios(["rosdhb", "robust_dgd", "dgd"],
                             ["alie", "signflip"], ["cwtm"],
                             n_honest=N - F, f=F, ratio=0.2)
    plan = SW.plan_grid(scen)
    assert plan.banks, "expected at least one fused bank"
    batches = stack_batches(batch_fn, STEPS)
    ref = SW.execute_plan(plan, loss_fn=loss_fn, params0=params0,
                          batches=batches, seeds=[0, 1], shard=False)
    got = SW.execute_plan(plan, loss_fn=loss_fn, params0=params0,
                          batches=batches, seeds=[0, 1], shard=False,
                          streaming=True, stream_chunk_size=16,
                          prefetch_depth=2)
    assert set(ref) == set(got)
    for lbl in ref:
        for a, b in zip(ref[lbl], got[lbl]):
            assert a == b, (lbl, a, b)


def test_streaming_seed_vmap_singles_match():
    sim, batch_fn = _sim("rosdhb")
    batches = stack_batches(batch_fn, STEPS)
    st_ref, ms_ref = SW.rollout_over_seeds(sim, [0, 1, 2], batches)
    st_s, ms_s = SW.rollout_over_seeds_streaming(
        sim, [0, 1, 2], batches, chunk_size=16, prefetch_depth=2)
    np.testing.assert_array_equal(np.asarray(st_s.params_flat),
                                  np.asarray(st_ref.params_flat))
    for k in ms_ref:
        np.testing.assert_array_equal(np.asarray(ms_s[k]),
                                      np.asarray(ms_ref[k]), err_msg=k)


# --------------------------------------------------------------------------
# early exit at tau
# --------------------------------------------------------------------------


def test_early_exit_matches_truncated_fixed_run():
    """Exit at the first chunk boundary past the tau crossing; the metric
    prefix equals the fixed-length run truncated at that boundary."""
    chunk = 8
    sim, batch_fn = _sim("rosdhb")
    batches = stack_batches(batch_fn, STEPS)
    _, ms_ref = sim.rollout(sim.init(0), batches)
    loss_ref = np.asarray(ms_ref["loss"])
    tau = float(loss_ref[23])  # crossed mid-trajectory
    st_s, ms_s, info = sim.rollout_streaming(
        sim.init(0), batches, chunk_size=chunk, prefetch_depth=2,
        tau=tau, tau_metric="loss", tau_mode="<=")
    assert info["early_exit"]
    r = info["rounds_run"]
    assert r % chunk == 0 and r < STEPS
    # first chunk boundary at-or-after the true crossing round
    first_hit = int(np.argmax(loss_ref <= tau))
    assert (first_hit // chunk) * chunk < r <= STEPS
    assert loss_ref[r - 1] <= tau
    np.testing.assert_array_equal(np.asarray(ms_s["loss"]), loss_ref[:r])
    assert int(st_s.server.step) == r
    assert info["last_metric"] == pytest.approx(float(loss_ref[r - 1]))


def test_early_exit_eval_metric_path():
    """tau against eval_fn metrics (accuracy-style '>=' crossing)."""
    eval_fn = lambda p, b: {"gap": -jnp.linalg.norm(  # noqa: E731
        p["w"] - b["target"].mean(0))}
    sim, batch_fn = _sim("rosdhb", eval_fn=eval_fn)
    batches = stack_batches(batch_fn, STEPS)
    eval_batch = batch_fn(0)
    st_s, ms, info = sim.rollout_streaming(
        sim.init(0), batches, chunk_size=10, prefetch_depth=2,
        tau=-3.0, tau_metric="gap", eval_batch=eval_batch)
    assert info["tau_mode"] == ">="
    if info["early_exit"]:
        assert info["rounds_run"] < STEPS
        assert info["last_metric"] >= -3.0


def test_tau_never_crossed_runs_full_length():
    sim, batch_fn = _sim("rosdhb")
    batches = stack_batches(batch_fn, STEPS)
    _, _, info = sim.rollout_streaming(
        sim.init(0), batches, chunk_size=16, prefetch_depth=2,
        tau=-1.0, tau_metric="loss", tau_mode="<=")  # loss never negative
    assert not info["early_exit"] and info["rounds_run"] == STEPS


# --------------------------------------------------------------------------
# prefetcher behaviour
# --------------------------------------------------------------------------


def test_prefetch_depth_one_starves_but_completes():
    """depth=1 with a slow producer: correct results, no deadlock."""
    sim, batch_fn = _sim("rosdhb")

    def slow_fn(t):
        time.sleep(0.02)
        return batch_fn(t)

    st_ref, _ = sim.rollout(sim.init(0), stack_batches(batch_fn, 24))
    st_s, _, info = sim.rollout_streaming(
        sim.init(0), slow_fn, steps=24, chunk_size=4, prefetch_depth=1)
    np.testing.assert_array_equal(np.asarray(st_s.params_flat),
                                  np.asarray(st_ref.params_flat))
    assert info["rounds_run"] == 24
    assert info["host_high_water_bytes"] <= 2 * info["chunk_bytes"]


def test_prefetcher_close_unblocks_producer():
    """Consumer abandons the stream while the producer is blocked on a full
    queue: close() must not hang and the thread must die."""
    def batch_fn(t):
        return {"x": np.zeros((64,), np.float32) + t}

    pf = ChunkPrefetcher(batch_fn, steps=100, chunk_size=2, prefetch_depth=1)
    pf.take(1)
    time.sleep(0.1)  # let the producer refill + block on the next put
    pf.close()
    assert not pf._thread.is_alive()


def test_prefetcher_propagates_producer_error():
    def bad_fn(t):
        if t >= 4:
            raise RuntimeError("boom at t=4")
        return {"x": np.zeros((8,), np.float32)}

    pf = ChunkPrefetcher(bad_fn, steps=12, chunk_size=2, prefetch_depth=2)
    with pytest.raises(RuntimeError, match="producer thread failed"):
        # drain until the error surfaces
        for _ in range(6):
            pf.take(1, timeout=10.0)
    pf.close()


def test_prefetcher_chunk_order_and_exhaustion():
    def batch_fn(t):
        return {"t": np.asarray([t], np.int64)}

    with ChunkPrefetcher(batch_fn, steps=10, chunk_size=3,
                         prefetch_depth=2) as pf:
        seen = []
        while True:
            got = pf.take(2)
            if not got:
                break
            for c in got:
                seen.extend(np.asarray(c["t"]).ravel().tolist())
    assert seen == list(range(9))  # 3 full chunks; tail round 9 not streamed
    assert pf.remainder == 1


def test_split_chunks_and_batch_bytes():
    batches = {"a": np.zeros((10, 3), np.float32),
               "b": np.zeros((10, 2), np.int32)}
    chunks = split_chunks(batches, 4)
    assert len(chunks) == 2
    assert chunks[1]["a"].shape == (4, 3)
    assert batch_bytes({"a": np.zeros((5,), np.float32)}) == 20


# --------------------------------------------------------------------------
# stack_batches guard
# --------------------------------------------------------------------------


def test_stack_batches_raises_over_budget():
    big = lambda t: {"x": np.zeros((1024, 1024), np.float32)}  # 4 MiB/round
    with pytest.raises(ValueError, match="rollout_streaming"):
        sim_lib.stack_batches(big, steps=100, max_bytes=16 * 1024 ** 2)
    # under budget: fine
    out = sim_lib.stack_batches(big, steps=2, max_bytes=16 * 1024 ** 2)
    assert out["x"].shape == (2, 1024, 1024)


def test_stack_batches_env_override(monkeypatch):
    big = lambda t: {"x": np.zeros((1024,), np.float32)}
    monkeypatch.setenv("REPRO_STACK_BYTES_LIMIT", "1024")
    with pytest.raises(ValueError, match="REPRO_STACK_BYTES_LIMIT"):
        sim_lib.stack_batches(big, steps=10)
    monkeypatch.setenv("REPRO_STACK_BYTES_LIMIT", "0")  # 0 disables
    out = sim_lib.stack_batches(big, steps=10)
    assert out["x"].shape == (10, 1024)


# --------------------------------------------------------------------------
# transformer streaming (reduced stablelm_3b)
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_transformer_table1_streaming_slow():
    """Full transformer-table1 cut through the streaming sweep: every
    registry cell (rosdhb + robust_dgd x alie/signflip) completes with
    finite losses and a sane accuracy column."""
    from repro.adversary.registry import expand_scenario, get_spec
    from repro.core.sweep import _transformer_testbed, run_scenarios

    spec = get_spec("transformer-table1")
    loss_fn, params0, batch_fn, eval_fn, eval_batch = \
        _transformer_testbed(spec.n_workers)
    scen = expand_scenario("transformer-table1")
    rows = run_scenarios(scen, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=[0], steps=16,
                         eval_fn=eval_fn, eval_batch=eval_batch,
                         shard=False, streaming=True, stream_chunk_size=4,
                         prefetch_depth=2)
    assert len(rows) == len(spec.algos) * len(spec.attacks)
    for r in rows:
        assert np.isfinite(r["final_loss"]), r
        assert 0.0 <= r["acc"] <= 1.0, r
