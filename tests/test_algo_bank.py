"""Algorithm-bank tests (the one-program Table-1 tentpole).

The ``lax.switch`` algorithm bank over the unified ``ServerState`` must
reproduce every per-algorithm compiled program cell for cell; non-dasha
branches must leave the padded ``mirror``/``prev_grad`` slots bit-for-bit
untouched across a scan; the fused sharded eval must match the per-cell
eval; and each algorithm's uplink must be priced under its own wire format.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGO_BANK, AlgorithmConfig, AggregatorConfig, AttackConfig,
    ScenarioParams, Simulator, SparsifierConfig, algo_index,
    algo_payload_bytes, grid_scenarios, init_state, plan_grid,
    StateLayout, quadratic_testbed, rollout_over_seeds, run_scenarios,
    server_round, stack_batches,
)
from repro.core import compression as C
from repro.core.sweep import fused_grid_eval, fused_grid_rollout

N, F, D, STEPS = 13, 3, 24, 10
SEEDS = (0, 1)


def _testbed():
    return quadratic_testbed(N, D)


def _cfg(algo, attack="alie", agg="cwtm", ratio=0.2):
    return AlgorithmConfig(
        name=algo, n_workers=N, f=F, gamma=0.05, beta=0.9,
        sparsifier=SparsifierConfig(kind="randk", ratio=ratio),
        aggregator=AggregatorConfig(name=agg, f=F, pre_nnm=True),
        attack=AttackConfig(name=attack, z=1.5 if attack == "alie" else None))


def _grid(algos, attacks=("alie", "foe"), aggs=("cwtm", "median")):
    return grid_scenarios(algos, attacks, aggs, n_honest=N - F, f=F,
                          ratio=0.2, gamma=0.05)


# --------------------------------------------------------------------------
# unified state
# --------------------------------------------------------------------------


def test_init_state_is_uniformly_shaped_under_full_layout():
    """Under the full StateLayout every algorithm (and the bank itself)
    carries the same state shape — the precondition for switching between
    them on traced data inside a mixed bank. By DEFAULT only dasha (and
    banks containing it) resolves to the full layout; dasha-free configs
    prune mirror/prev_grad to ``None`` (no pytree leaves)."""
    full = StateLayout.full()

    def full_cfg(algo):
        return dataclasses.replace(_cfg(algo), state_layout=full)

    ref = jax.tree_util.tree_map(
        lambda l: (l.shape, l.dtype), init_state(full_cfg("rosdhb"), D))
    for algo in ALGO_BANK:
        got = jax.tree_util.tree_map(
            lambda l: (l.shape, l.dtype), init_state(full_cfg(algo), D))
        assert got == ref, algo
    bank_cfg = dataclasses.replace(_cfg("rosdhb"), name="bank",
                                   bank=ALGO_BANK)
    assert bank_cfg.resolved_state_layout() == full  # dasha branch present
    got = jax.tree_util.tree_map(
        lambda l: (l.shape, l.dtype), init_state(bank_cfg, D))
    assert got == ref
    st = init_state(full_cfg("dgd"), D)
    assert st.mirror.shape == st.momentum.shape == (N, D)
    assert st.prev_grad.shape == (N, D) and st.prev_grad.dtype == jnp.float32
    # the default layout for dasha-free configs is the pruned carry
    for algo in ("rosdhb", "dgd", "robust_dgd"):
        st = init_state(_cfg(algo), D)
        assert st.mirror is None and st.prev_grad is None, algo
        assert st.momentum.shape == (N, D)
    assert init_state(_cfg("dasha"), D).mirror is not None


def test_init_state_rejects_unknown_algorithm():
    with pytest.raises(ValueError, match="unknown algorithm"):
        init_state(dataclasses.replace(_cfg("rosdhb"), name="sgd"), D)


@pytest.mark.parametrize("algo", ["rosdhb", "dgd", "robust_dgd"])
@pytest.mark.parametrize("seed", [0, 3])
def test_padded_slots_inert_across_standalone_scan(algo, seed):
    """Property: non-dasha update rules leave the padded mirror/prev_grad
    slots bit-for-bit untouched across a whole scan (layout forced to full
    width — the default pruned carry has no such slots at all, pinned in
    tests/test_state_layout.py)."""
    loss_fn, params0, batch_fn, _ = _testbed()
    cfg = dataclasses.replace(_cfg(algo), state_layout=StateLayout.full())
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=cfg)
    st0 = sim.init(seed)
    st, _ = sim.rollout(st0, batch_fn, steps=STEPS)
    assert int(st.server.step) == STEPS
    if algo == "rosdhb":  # sanity: the slots rosdhb owns DO move
        assert not np.array_equal(np.asarray(st.server.momentum),
                                  np.asarray(st0.server.momentum))
    np.testing.assert_array_equal(np.asarray(st.server.mirror),
                                  np.asarray(st0.server.mirror))
    np.testing.assert_array_equal(np.asarray(st.server.prev_grad),
                                  np.asarray(st0.server.prev_grad))


def test_padded_slots_inert_inside_fused_bank():
    """The same property through the lax.switch bank: non-dasha cells of a
    cross-algorithm program keep exact zeros in mirror/prev_grad while the
    dasha cell actually uses them."""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = _grid(ALGO_BANK, attacks=("alie",), aggs=("cwtm",))
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1
    bank = plan.banks[0]
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    batches = stack_batches(batch_fn, STEPS)
    states, _ = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                   batches, shard=False)
    mirror = np.asarray(states.server.mirror)      # [cells, seeds, n, d]
    prev = np.asarray(states.server.prev_grad)
    for c, sc in enumerate(bank.scenarios):
        if sc.cfg.name == "dasha":
            assert np.any(mirror[c] != 0.0) and np.any(prev[c] != 0.0)
        else:
            np.testing.assert_array_equal(mirror[c],
                                          np.zeros_like(mirror[c]),
                                          err_msg=sc.label)
            np.testing.assert_array_equal(prev[c], np.zeros_like(prev[c]),
                                          err_msg=sc.label)


# --------------------------------------------------------------------------
# bank vs standalone parity (ISSUE acceptance core)
# --------------------------------------------------------------------------


def test_cross_algo_bank_matches_standalone_all_four_algorithms():
    """All four algorithms x 2 attacks x 2 aggregators execute as ONE
    compiled program whose cells match the standalone per-scenario
    rollouts (14 cells: dgd collapses both aggregators to its single mean
    cell per attack)."""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = _grid(ALGO_BANK)
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1 and plan.banks[0].n_cells == 14
    bank = plan.banks[0]
    assert bank.cfg.name == "bank" and set(bank.cfg.bank) == set(ALGO_BANK)
    batches = stack_batches(batch_fn, STEPS)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    states, metrics = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                         batches, shard=False)
    assert sim.round_traces == 1  # ONE compiled program for Table 1
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        ref_states, ref_metrics = rollout_over_seeds(ref, SEEDS, batches)
        np.testing.assert_allclose(
            np.asarray(states.params_flat[c]),
            np.asarray(ref_states.params_flat),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


def test_cross_algo_bank_matches_per_algorithm_banks():
    """Cross-algorithm fusion == the legacy per-algorithm banks on the same
    grid (the bench_sweep gate's equivalence baseline, in miniature)."""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = _grid(("rosdhb", "dasha"), attacks=("alie", "signflip"),
                      aggs=("cwtm",))
    batches = stack_batches(batch_fn, STEPS)

    def losses(plan):
        out = {}
        for bank in plan.banks:
            sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
            _, metrics = fused_grid_rollout(sim, bank.scenario_params(),
                                            SEEDS, batches, shard=False)
            for c, sc in enumerate(bank.scenarios):
                out[sc.label] = np.asarray(metrics["loss"][c])
        return out

    cross = plan_grid(scenarios)
    per_algo = plan_grid(scenarios, cross_algo=False)
    assert cross.n_programs == 1 and per_algo.n_programs == 2
    got, want = losses(cross), losses(per_algo)
    assert got.keys() == want.keys()
    for label in got:
        np.testing.assert_allclose(got[label], want[label], rtol=1e-5,
                                   atol=1e-7, err_msg=label)


@pytest.mark.parametrize("algo", ALGO_BANK)
def test_single_algo_bank_is_bitwise_equal_to_legacy_bank(algo):
    """A single-algorithm cross bank (1-entry switch, traced
    hparams/gamma) reproduces the legacy per-algorithm bank BIT-FOR-BIT —
    the precomputed hparams complements make the traced constants exactly
    the ones the static path folds in. (Multi-branch switches may drift by
    an ulp where XLA fuses across branches; see bench_sweep's gate.)"""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = _grid((algo,), attacks=("alie", "foe"), aggs=("cwtm",))
    batches = stack_batches(batch_fn, STEPS)

    def run(plan):
        bank = plan.banks[0]
        sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
        st, m = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                   batches, shard=False)
        return {sc.label: np.asarray(st.params_flat[c])
                for c, sc in enumerate(bank.scenarios)}

    cross = run(plan_grid(scenarios))
    legacy = run(plan_grid(scenarios, cross_algo=False))
    for label in cross:
        np.testing.assert_array_equal(cross[label], legacy[label],
                                      err_msg=label)


def test_traced_gamma_matches_static_gamma():
    """Mixed step sizes join the fusion axis: per-cell traced gamma must
    reproduce the static-config runs."""
    loss_fn, params0, batch_fn, _ = _testbed()
    batches = stack_batches(batch_fn, STEPS)
    cells = [dataclasses.replace(_cfg("rosdhb"), gamma=g)
             for g in (0.02, 0.08)]
    from repro.core.sweep import Scenario
    scenarios = [Scenario(label=f"g{c.gamma}", cfg=c) for c in cells]
    plan = plan_grid(scenarios)
    assert plan.n_programs == 1
    bank = plan.banks[0]
    assert bank.gammas == (0.02, 0.08)
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg)
    _, metrics = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                    batches, shard=False)
    for c, sc in enumerate(bank.scenarios):
        ref = Simulator(loss_fn=loss_fn, params0=params0, cfg=sc.cfg)
        _, ref_metrics = rollout_over_seeds(ref, SEEDS, batches)
        np.testing.assert_allclose(
            np.asarray(metrics["loss"][c]), np.asarray(ref_metrics["loss"]),
            rtol=1e-5, atol=1e-7, err_msg=sc.label)


def test_bank_requires_traced_selectors():
    """Loud errors: a bank config without the traced algo_idx/hparams must
    fail fast, not silently fall back."""
    cfg = dataclasses.replace(_cfg("rosdhb", attack="none"), name="bank",
                              bank=("rosdhb", "dgd"), f=0)
    st = init_state(cfg, 8)
    grads = jnp.ones((N, 8))
    with pytest.raises(ValueError, match="algorithm bank needs a traced"):
        server_round(cfg, st, grads, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="hyperparameters"):
        server_round(cfg, st, grads, jax.random.PRNGKey(0),
                     scenario=ScenarioParams(
                         algo_idx=jnp.zeros((), jnp.int32)))
    with pytest.raises(ValueError, match="not a branch"):
        algo_index("sgd")


# --------------------------------------------------------------------------
# fused sharded eval
# --------------------------------------------------------------------------


def test_fused_grid_eval_matches_per_cell_eval():
    loss_fn, params0, batch_fn, tg = _testbed()
    opt = np.asarray(tg[F:]).mean(0)
    eval_fn = lambda p, b: {"dist": jnp.linalg.norm(p["w"] - b["opt"])}  # noqa: E731
    eval_batch = {"opt": jnp.asarray(opt)}
    scenarios = _grid(("rosdhb", "dgd"), attacks=("alie",), aggs=("cwtm",))
    bank = plan_grid(scenarios).banks[0]
    sim = Simulator(loss_fn=loss_fn, params0=params0, cfg=bank.cfg,
                    eval_fn=eval_fn)
    batches = stack_batches(batch_fn, STEPS)
    states, _ = fused_grid_rollout(sim, bank.scenario_params(), SEEDS,
                                   batches, shard=False)
    emet = fused_grid_eval(sim, states, eval_batch, shard=False)
    assert emet["dist"].shape == (2, len(SEEDS))
    # reference: evaluate each final state individually
    from repro.utils import tree as T
    for c in range(2):
        for s in range(len(SEEDS)):
            params = T.tree_unravel(states.params_flat[c, s], sim.spec)
            want = eval_fn(params, eval_batch)["dist"]
            np.testing.assert_allclose(np.asarray(emet["dist"][c, s]),
                                       np.asarray(want), rtol=1e-6)


def test_run_scenarios_fused_eval_matches_unfused_rows():
    loss_fn, params0, batch_fn, tg = _testbed()
    opt = np.asarray(tg[F:]).mean(0)
    eval_fn = lambda p, b: {"dist": jnp.linalg.norm(p["w"] - b["opt"])}  # noqa: E731
    scenarios = _grid(("rosdhb", "dasha"), attacks=("alie",), aggs=("cwtm",))
    kw = dict(loss_fn=loss_fn, params0=params0, batches=batch_fn,
              seeds=[0, 1], steps=STEPS, eval_fn=eval_fn,
              eval_batch={"opt": jnp.asarray(opt)}, shard=False)
    fused = run_scenarios(scenarios, fuse_attacks=True, **kw)
    unfused = run_scenarios(scenarios, fuse_attacks=False, **kw)
    assert [(r["scenario"], r["seed"]) for r in fused] == \
        [(r["scenario"], r["seed"]) for r in unfused]
    for rf, ru in zip(fused, unfused):
        np.testing.assert_allclose(rf["dist"], ru["dist"], rtol=1e-5,
                                   err_msg=rf["scenario"])


# --------------------------------------------------------------------------
# per-algorithm uplink accounting
# --------------------------------------------------------------------------


def test_algo_payload_bytes_wire_formats():
    d, ratio = 1000, 0.1
    k = max(1, int(round(ratio * d)))
    idx_b = C.index_bytes(d)
    global_sp = SparsifierConfig(kind="randk", ratio=ratio, local=False)
    local_sp = SparsifierConfig(kind="randk", ratio=ratio, local=True)

    def cfg(algo, sp):
        return dataclasses.replace(_cfg(algo), sparsifier=sp)

    # rosdhb/dgd: k values; coordinated global mask = shared PRNG, no indices
    assert algo_payload_bytes(cfg("rosdhb", global_sp), d) == 4 * k
    assert algo_payload_bytes(cfg("dgd", global_sp), d) == 4 * k
    # local sparsification must identify its coordinates
    assert algo_payload_bytes(cfg("rosdhb", local_sp), d) == (4 + idx_b) * k
    # robust_dgd: raw gradients, sparsifier irrelevant
    assert algo_payload_bytes(cfg("robust_dgd", global_sp), d) == 4 * d
    assert algo_payload_bytes(cfg("robust_dgd", local_sp), d) == 4 * d
    # dasha: independent per-worker compressors -> always indices
    assert algo_payload_bytes(cfg("dasha", global_sp), d) == (4 + idx_b) * k
    assert algo_payload_bytes(cfg("dasha", local_sp), d) == (4 + idx_b) * k
    # bank configs have no single wire format
    with pytest.raises(ValueError, match="no single wire format"):
        algo_payload_bytes(dataclasses.replace(cfg("rosdhb", global_sp),
                                               name="bank"), d)


def test_result_rows_use_per_algorithm_wire_format():
    """Inside one fused Table-1 bank, every cell's comm_bytes must follow
    ITS algorithm's wire format, not a shared formula."""
    loss_fn, params0, batch_fn, _ = _testbed()
    scenarios = _grid(ALGO_BANK, attacks=("alie",), aggs=("cwtm",))
    assert plan_grid(scenarios).n_programs == 1
    rows = run_scenarios(scenarios, loss_fn=loss_fn, params0=params0,
                         batches=batch_fn, seeds=[0], steps=4, shard=False)
    by_algo = {r["algo"]: r for r in rows}
    k = max(1, int(round(0.2 * D)))
    assert by_algo["rosdhb"]["comm_bytes"] == 4 * k * N * 4
    assert by_algo["dgd"]["comm_bytes"] == by_algo["rosdhb"]["comm_bytes"]
    assert by_algo["robust_dgd"]["comm_bytes"] == 4 * D * N * 4
    assert by_algo["dasha"]["comm_bytes"] == (4 + C.index_bytes(D)) * k * N * 4
    assert by_algo["robust_dgd"]["ratio"] == 1.0  # effective, not config
